"""The Supervisor: spawn, probe, kill, freeze, and restart worker
processes; publish the membership view the transport masks read.

One worker process per measure node (`cluster/worker.py`).  The worker
binds an ephemeral port and prints a JSON registration line; the
supervisor reads it and dials back over the TCP `SocketChannel` mode
(versioned handshake asserting the peer really is that node, bounded
reconnect).  Topology edges whose SOURCE is a supervised node get a
`WorkerChannel` — a `Channel` whose send/recv is an echo round trip
through the worker, so a delivered payload genuinely crossed two process
boundaries — and everything above the Channel API (`NetworkTransport`,
retries, breakers, ledgers, the serving engine) runs unchanged.

Supervision is TICK-driven, not wall-clock-driven: `tick(t)` runs as the
transport's `on_tick` hook at the top of every round/request, so
scheduled kills/freezes (a `ChaosSchedule` with node_kill/node_freeze
windows) are realised with REAL SIGKILL/SIGSTOP/SIGCONT at deterministic
points, and the membership ladder (`cluster/membership.py`) advances as a
function of tick-stamped observations.  What stays wall-clock is only
detection I/O (probe timeouts against a frozen process) — outcomes, and
therefore masks and trajectories, are deterministic per tick.
"""
from __future__ import annotations

import json
import os
import select
import signal
import subprocess
import sys
import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.cluster import proto
from repro.cluster.membership import DOWN, HeartbeatMonitor, MembershipView
from repro.transport.channel import Channel, ChannelError, SocketChannel

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))


class WorkerHandle:
    """One supervised process: Popen + connected channel + request tags."""

    def __init__(self, node: str):
        self.node = node
        self.proc: Optional[subprocess.Popen] = None
        self.channel: Optional[SocketChannel] = None
        self.port: Optional[int] = None
        self.frozen = False
        self.lock = threading.Lock()      # serialises request/response I/O
        self._tag = 0

    def next_tag(self) -> int:
        self._tag += 1
        return self._tag

    def alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None


class WorkerChannel(Channel):
    """A topology edge riding a worker process: send() ships the frame to
    the worker as an ECHO request, recv() awaits the tagged reply — so the
    payload crosses the process boundary twice, and a dead or frozen
    worker fails the edge exactly like a lossy link (typed ChannelError /
    recv timeout), which the EdgeTransport's retry/breaker machinery
    already knows how to price."""

    kind = "cluster"

    def __init__(self, supervisor: "Supervisor", node: str):
        self._sup = supervisor
        self._node = node
        self._pending: Optional[int] = None

    def send(self, frame: bytes) -> None:
        self._pending = self._sup._echo_send(self._node, frame)

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        tag, self._pending = self._pending, None
        if tag is None:
            return None
        return self._sup._echo_recv(self._node, tag, timeout)

    def close(self) -> None:
        pass                               # the supervisor owns the socket


class Supervisor:
    """Spawn one worker per node; keep them alive; answer for their health.

    nodes               the measure-node names to supervise.
    seed                heartbeat phase stream (membership.HeartbeatMonitor).
    chaos               a ChaosSchedule whose node_kill/node_freeze windows
                        this supervisor REALISES with SIGKILL/SIGSTOP at
                        tick boundaries (also consulted to route scheduled
                        restarts around the backoff ladder).
    heartbeat_interval / suspect_after / dead_after / backoff_*
                        the membership ladder's parameters, in ticks.
    io_timeout          per-probe / per-echo-slice socket timeout (seconds)
                        — the only wall-clock knob; it bounds how long a
                        frozen worker can stall one transmission.
    """

    def __init__(self, nodes: Sequence[str], *, seed: int = 0, chaos=None,
                 heartbeat_interval: int = 1, suspect_after: int = 1,
                 dead_after: int = 2, backoff_base: int = 1,
                 backoff_mult: int = 2, backoff_cap: int = 8,
                 stable_after: int = 4, io_timeout: float = 0.25,
                 spawn_timeout: float = 30.0, python: Optional[str] = None):
        self.nodes = list(nodes)
        self.chaos = chaos
        self.monitor = HeartbeatMonitor(
            self.nodes, seed=seed, interval=heartbeat_interval,
            suspect_after=suspect_after, dead_after=dead_after,
            backoff_base=backoff_base, backoff_mult=backoff_mult,
            backoff_cap=backoff_cap, stable_after=stable_after)
        self.handles: Dict[str, WorkerHandle] = {
            n: WorkerHandle(n) for n in self.nodes}
        self.io_timeout = io_timeout
        self.spawn_timeout = spawn_timeout
        self._python = python or sys.executable
        self._lock = threading.RLock()
        self._started = False
        self.respawns = 0
        self.last_tick: Optional[int] = None

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "Supervisor":
        with self._lock:
            for node in self.nodes:
                self._spawn(node, tick=0)
            self._started = True
        return self

    def stop(self) -> None:
        with self._lock:
            self._started = False
            for h in self.handles.values():
                if h.channel is not None:
                    try:
                        h.channel.send(proto.pack_msg(proto.OP_EXIT, 0))
                    except ChannelError:
                        pass
                    h.channel.close()
                    h.channel = None
                if h.proc is not None:
                    if h.frozen:
                        self._signal(h, signal.SIGCONT)
                        h.frozen = False
                    h.proc.terminate()
            for h in self.handles.values():
                if h.proc is not None:
                    try:
                        h.proc.wait(timeout=5.0)
                    except subprocess.TimeoutExpired:
                        h.proc.kill()
                        h.proc.wait()
                    if h.proc.stdout is not None:
                        h.proc.stdout.close()
                    h.proc = None

    def __enter__(self) -> "Supervisor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- spawning -----------------------------------------------------------

    def _spawn(self, node: str, tick: int) -> None:
        h = self.handles[node]
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in [_SRC, env.get("PYTHONPATH", "")] if p)
        h.proc = subprocess.Popen(
            [self._python, "-m", "repro.cluster.worker", "--node", node],
            stdout=subprocess.PIPE, text=True, env=env)
        try:
            info = json.loads(self._read_registration(h.proc))
        except Exception:
            h.proc.kill()
            h.proc.wait()
            raise
        if info.get("node") != node:
            h.proc.kill()
            h.proc.wait()
            raise ChannelError(f"worker registered as {info.get('node')!r}, "
                               f"expected {node!r}")
        h.port = int(info["port"])
        h.channel = SocketChannel.connect(
            info.get("host", "127.0.0.1"), h.port, name="supervisor",
            expect_peer=node, timeout=self.spawn_timeout)
        h.frozen = False
        self.monitor.note_joined(node, tick)

    def _read_registration(self, proc: subprocess.Popen) -> str:
        deadline = time.monotonic() + self.spawn_timeout
        assert proc.stdout is not None
        while time.monotonic() < deadline:
            ready, _, _ = select.select([proc.stdout], [], [], 0.1)
            if ready:
                line = proc.stdout.readline()
                if line:
                    return line
                raise ChannelError("worker exited before registering")
            if proc.poll() is not None:
                raise ChannelError(
                    f"worker died during spawn (rc={proc.returncode})")
        raise ChannelError("worker registration timed out")

    def _respawn(self, node: str, tick: int) -> None:
        h = self.handles[node]
        if h.channel is not None:
            h.channel.close()
            h.channel = None
        if h.proc is not None:
            if h.proc.stdout is not None:
                h.proc.stdout.close()
            h.proc = None
        self._spawn(node, tick)
        self.respawns += 1

    # -- faults (real signals) ---------------------------------------------

    @staticmethod
    def _signal(h: WorkerHandle, sig: int) -> None:
        try:
            os.kill(h.proc.pid, sig)
        except (OSError, AttributeError):
            pass

    def kill(self, node: str) -> None:
        """SIGKILL the worker NOW (an unscheduled death: the next tick's
        poll walks the membership ladder and pays restart backoff)."""
        with self._lock:
            h = self.handles[node]
            if h.proc is None:
                return
            if h.frozen:
                self._signal(h, signal.SIGCONT)
                h.frozen = False
            self._signal(h, signal.SIGKILL)
            try:
                h.proc.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                pass

    def freeze(self, node: str) -> None:
        with self._lock:
            h = self.handles[node]
            if h.alive() and not h.frozen:
                self._signal(h, signal.SIGSTOP)
                h.frozen = True

    def thaw(self, node: str) -> None:
        with self._lock:
            h = self.handles[node]
            if h.alive() and h.frozen:
                self._signal(h, signal.SIGCONT)
                h.frozen = False

    # -- the supervision tick ----------------------------------------------

    def tick(self, t: int) -> None:
        """Advance supervision to tick `t`: realise the chaos schedule with
        real signals, reap exits, run due heartbeats, restart what the
        ladder allows.  Runs as the transport's `on_tick` hook, BEFORE any
        of tick t's transmissions — so a scheduled kill at t already masks
        t's votes, exactly like the inline chaos path."""
        with self._lock:
            if not self._started:
                return
            self.last_tick = t
            for node in self.nodes:
                h = self.handles[node]
                want_dead = self.chaos is not None \
                    and self.chaos.node_dead(node, t)
                want_frozen = self.chaos is not None \
                    and self.chaos.node_frozen(node, t)
                # 1) realise the schedule
                if want_dead and h.alive():
                    self.kill(node)
                if h.alive():
                    if want_frozen and not h.frozen:
                        self._signal(h, signal.SIGSTOP)
                        h.frozen = True
                    elif not want_frozen and h.frozen:
                        self._signal(h, signal.SIGCONT)
                        h.frozen = False
                # 2) reap deaths (scheduled or not)
                if h.proc is not None and h.proc.poll() is not None \
                        and self.monitor.nodes[node].status != DOWN:
                    self.monitor.note_exit(node, t, scheduled=want_dead)
                    if h.channel is not None:
                        h.channel.close()
                        h.channel = None
                # 3) restart what is due (never inside a scheduled window)
                if not want_dead and not h.alive() \
                        and self.monitor.due_restart(node, t):
                    self._respawn(node, t)
                # 4) probe on the seeded cadence
                elif h.alive() and self.monitor.beat_due(node, t):
                    self.monitor.observe(node, t, self._ping(node))
            self.monitor.tick_stability(t)

    # -- health / membership ------------------------------------------------

    def membership(self) -> MembershipView:
        with self._lock:
            return self.monitor.view()

    def is_down(self, name: str, tick: int = 0) -> bool:
        """The transport's `node_down` hook: a node this supervisor does
        not own is never down on its account."""
        with self._lock:
            if name not in self.handles:
                return False
            return self.monitor.is_down(name)

    def events(self) -> List[tuple]:
        with self._lock:
            return list(self.monitor.events)

    # -- the data path ------------------------------------------------------

    def edge_channels(self, topo) -> Dict[str, Channel]:
        """{edge_key: WorkerChannel} for every edge whose source is a
        supervised node (the transport falls back to loopback for the
        rest — relay/fuse hops stay in the serving process)."""
        return {e.key: WorkerChannel(self, e.src)
                for e in topo.edges if e.src in self.handles}

    def _echo_send(self, node: str, frame: bytes) -> int:
        h = self.handles[node]
        with h.lock:
            if h.channel is None or not h.alive():
                raise ChannelError(f"worker {node} is down")
            tag = h.next_tag()
            h.channel.send(proto.pack_msg(proto.OP_ECHO, tag, frame))
            return tag

    def _echo_recv(self, node: str, tag: int,
                   timeout: Optional[float]) -> Optional[bytes]:
        return self._await_reply(node, proto.OP_ECHO_REPLY, tag,
                                 self.io_timeout if timeout is None
                                 else min(timeout, self.io_timeout))

    def _await_reply(self, node: str, want_op: int, tag: int,
                     timeout: float) -> Optional[bytes]:
        h = self.handles[node]
        with h.lock:
            if h.channel is None:
                return None
            deadline = time.monotonic() + timeout
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                try:
                    frame = h.channel.recv(remaining)
                except ChannelError:
                    return None
                if frame is None:
                    if h.channel.eof:
                        return None
                    continue
                op, rtag, payload = proto.unpack_msg(frame)
                if rtag != tag or op != want_op:
                    continue               # stale reply from a thawed worker
                return payload

    def _ping(self, node: str) -> bool:
        h = self.handles[node]
        try:
            with h.lock:
                if h.channel is None or not h.alive():
                    return False
                tag = h.next_tag()
                h.channel.send(proto.pack_msg(proto.OP_PING, tag))
            return self._await_reply(node, proto.OP_PONG, tag,
                                     self.io_timeout) is not None
        except ChannelError:
            return False
