"""The supervisor<->worker message protocol, layered inside the channel
layer's length-prefixed frames: a 1-byte opcode, an 8-byte request tag,
and the payload.

Tags let the supervisor discard STALE replies: a worker thawed after a
SIGSTOP flushes the echoes/pongs it owed from ticks that have already been
written off, and the tag mismatch identifies them as history rather than
answers to the current request.

Standard library only — this module is imported by spawned worker
processes, which must stay light (no jax, no repro.core)."""
from __future__ import annotations

import struct
from typing import Tuple

OP_PING = 1        # liveness probe              -> OP_PONG, same tag
OP_PONG = 2
OP_ECHO = 3        # payload round-trip          -> OP_ECHO_REPLY, same tag
OP_ECHO_REPLY = 4
OP_EXIT = 5        # graceful shutdown (no reply)

_MSG = struct.Struct("<Bq")


def pack_msg(op: int, tag: int, payload: bytes = b"") -> bytes:
    return _MSG.pack(op, tag) + payload


def unpack_msg(frame: bytes) -> Tuple[int, int, bytes]:
    if len(frame) < _MSG.size:
        raise ValueError(f"short cluster message ({len(frame)} bytes)")
    op, tag = _MSG.unpack_from(frame, 0)
    return op, tag, frame[_MSG.size:]
