"""Multi-process worker plane: supervised measure-node processes.

`repro/cluster` turns the transport's fault model from simulation into a
system: every measure node of a topology becomes a REAL OS process (a
`worker` serving the echo/heartbeat protocol over the TCP `SocketChannel`
mode), a `Supervisor` spawns/monitors/restarts them and publishes a
membership view, and `cluster_transport`/`Cluster` wire the worker
channels under an unchanged `NetworkTransport` — so a SIGKILL'd worker
costs INL exactly the votes it owned until the supervisor restores it.

Exports resolve lazily (PEP 562): `python -m repro.cluster.worker` must
NOT import the supervisor side (which pulls the core ledgers -> jax) —
the worker itself needs only the channel layer.
"""
import importlib

_EXPORTS = {
    "OP_PING": "proto", "OP_PONG": "proto", "OP_ECHO": "proto",
    "OP_ECHO_REPLY": "proto", "OP_EXIT": "proto",
    "pack_msg": "proto", "unpack_msg": "proto",
    "UP": "membership", "SUSPECT": "membership", "DOWN": "membership",
    "HeartbeatMonitor": "membership", "MembershipView": "membership",
    "NodeHealth": "membership",
    "Supervisor": "supervisor", "WorkerChannel": "supervisor",
    "WorkerHandle": "supervisor",
    "Cluster": "transport", "cluster_transport": "transport",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    value = getattr(importlib.import_module(f"{__name__}.{mod}"), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
