"""Elastic membership as PURE state machines: no processes, no sockets,
no wall clock.

The `Supervisor` owns real subprocesses, but every supervision DECISION —
when to probe, when a silent node becomes suspect, when suspect becomes
dead, when a dead node's restart is due, how the backoff escalates — lives
here as a function of (tick, observation), so the whole
miss-threshold -> suspect -> dead -> restart-backoff -> rejoin ladder is
unit-testable without spawning anything, and two supervisors fed the same
observation sequence publish the same membership views.

Heartbeat cadence is SEEDED per node: each node probes every `interval`
ticks at a phase drawn from a counter-seeded rng, so probes spread across
ticks instead of thundering together, yet replay identically for a given
seed.

Standard library + numpy only (worker processes never import this, but
the monitor must not drag jax into the supervisor's hot path either)."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

UP = "up"
SUSPECT = "suspect"          # missed probes, still counted until dead
DOWN = "down"


@dataclass
class NodeHealth:
    """One node's supervision record (mutable; owned by the monitor)."""
    name: str
    status: str = DOWN                  # nodes join by announcing themselves
    incarnation: int = 0                # bumped on every (re)join
    misses: int = 0                     # consecutive failed probes
    restarts: int = 0
    backoff_level: int = 0
    restart_due: Optional[int] = None   # tick a restart becomes allowed
    down_since: Optional[int] = None
    up_since: Optional[int] = None


@dataclass(frozen=True)
class MembershipView:
    """An immutable snapshot the transport masks read from."""
    version: int
    status: Tuple[Tuple[str, str], ...]          # (name, UP/SUSPECT/DOWN)
    incarnations: Tuple[Tuple[str, int], ...]

    def is_down(self, name: str) -> bool:
        return dict(self.status).get(name, DOWN) == DOWN

    def mask(self, names: Sequence[str]) -> np.ndarray:
        """(J,) bool: which of `names` may vote (UP or SUSPECT — a suspect
        node keeps its vote until declared dead, exactly like the paper's
        partial-fusion semantics keep a slow link's vote until it misses
        the deadline)."""
        st = dict(self.status)
        return np.array([st.get(n, DOWN) != DOWN for n in names], bool)


class HeartbeatMonitor:
    """The supervision ladder for a fixed node set.

    interval / seed    probe cadence: node n is probed at ticks where
                       (tick - phase_n) % interval == 0, phase_n seeded.
    suspect_after      consecutive misses before UP -> SUSPECT.
    dead_after         consecutive misses before -> DOWN (>= suspect_after).
    backoff_base/_mult/_cap
                       restart delay in TICKS after an unscheduled death:
                       min(base * mult**level, cap), level escalating per
                       death and resetting once the node stays up
                       `stable_after` ticks.
    """

    def __init__(self, nodes: Sequence[str], *, seed: int = 0,
                 interval: int = 1, suspect_after: int = 1,
                 dead_after: int = 2, backoff_base: int = 1,
                 backoff_mult: int = 2, backoff_cap: int = 8,
                 stable_after: int = 4):
        if dead_after < suspect_after:
            raise ValueError("dead_after must be >= suspect_after")
        self.interval = int(interval)
        self.suspect_after = int(suspect_after)
        self.dead_after = int(dead_after)
        self.backoff_base = int(backoff_base)
        self.backoff_mult = int(backoff_mult)
        self.backoff_cap = int(backoff_cap)
        self.stable_after = int(stable_after)
        self.nodes: Dict[str, NodeHealth] = {
            n: NodeHealth(name=n) for n in nodes}
        self._phase = {
            n: int(np.random.default_rng((seed, i)).integers(self.interval))
            for i, n in enumerate(nodes)}
        self.version = 0
        self.events: list = []          # (tick, node, transition) audit trail

    # -- probe cadence ------------------------------------------------------

    def beat_due(self, name: str, tick: int) -> bool:
        return (tick - self._phase[name]) % self.interval == 0

    # -- observations -------------------------------------------------------

    def _transition(self, h: NodeHealth, status: str, tick: int) -> None:
        if h.status == status:
            return
        self.events.append((tick, h.name, f"{h.status}->{status}"))
        h.status = status
        self.version += 1

    def observe(self, name: str, tick: int, ok: bool) -> None:
        """One probe result.  A pong clears the miss count (and rejoins a
        node that was declared dead while merely frozen — same
        incarnation, it never restarted); silence walks the ladder."""
        h = self.nodes[name]
        if ok:
            h.misses = 0
            if h.status != UP:
                if h.status == DOWN:
                    h.restart_due = None       # it answered: not dead
                    h.down_since = None
                    h.up_since = tick
                self._transition(h, UP, tick)
            self._maybe_stabilise(h, tick)
            return
        h.misses += 1
        if h.status == UP and h.misses >= self.suspect_after:
            self._transition(h, SUSPECT, tick)
        if h.status == SUSPECT and h.misses >= self.dead_after:
            self._mark_down(h, tick)

    def note_exit(self, name: str, tick: int,
                  scheduled: bool = False) -> None:
        """The worker PROCESS is gone (waitpid said so).  Scheduled exits
        (a chaos kill window) restart as soon as the window allows — the
        schedule owns the timing; unscheduled exits pay the capped
        exponential backoff, escalating on a crash loop."""
        h = self.nodes[name]
        if h.status != DOWN:
            self._mark_down(h, tick)
        if scheduled:
            h.restart_due = tick
        elif h.restart_due is None:
            delay = min(self.backoff_base
                        * self.backoff_mult ** h.backoff_level,
                        self.backoff_cap)
            h.restart_due = tick + delay
            h.backoff_level += 1

    def _mark_down(self, h: NodeHealth, tick: int) -> None:
        h.down_since = tick
        h.up_since = None
        self._transition(h, DOWN, tick)

    def due_restart(self, name: str, tick: int) -> bool:
        h = self.nodes[name]
        return (h.status == DOWN and h.restart_due is not None
                and tick >= h.restart_due)

    def note_joined(self, name: str, tick: int) -> None:
        """A (re)spawned worker completed its handshake."""
        h = self.nodes[name]
        h.incarnation += 1
        h.restarts += 1 if h.incarnation > 1 else 0
        h.misses = 0
        h.restart_due = None
        h.down_since = None
        h.up_since = tick
        self._transition(h, UP, tick)

    def _maybe_stabilise(self, h: NodeHealth, tick: int) -> None:
        if (h.backoff_level and h.up_since is not None
                and tick - h.up_since >= self.stable_after):
            h.backoff_level = 0

    def tick_stability(self, tick: int) -> None:
        """Decay restart backoff for nodes that have stayed up."""
        for h in self.nodes.values():
            if h.status == UP:
                self._maybe_stabilise(h, tick)

    # -- snapshots ----------------------------------------------------------

    def view(self) -> MembershipView:
        return MembershipView(
            version=self.version,
            status=tuple((n, h.status) for n, h in self.nodes.items()),
            incarnations=tuple((n, h.incarnation)
                               for n, h in self.nodes.items()))

    def is_down(self, name: str) -> bool:
        h = self.nodes.get(name)
        return h is None or h.status == DOWN
