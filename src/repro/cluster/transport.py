"""Wire a Supervisor's worker processes under a NetworkTransport.

`cluster_transport` is the whole trick: edges sourced at supervised nodes
get `WorkerChannel`s, the supervisor's `tick` becomes the transport's
`on_tick` hook (supervision advances at the top of every round/request,
deterministically in tick time), and its membership view backs the
`node_down` mask hook.  Everything else — retries, breakers, chaos draws,
both ledgers, `run_scheme(..., transport=)`, the serving engine — is the
unchanged PR-8 transport, which is why a fault-free 3-process run is
bit-identical to the in-process one: the fault draws are pure functions
of (seed, domain, tick, edge, attempt) and never see the channel kind.

`Cluster` bundles the common case as a context manager:

    with Cluster(cfg, topology=star, seed=0, chaos=sched) as cl:
        curve = run_scheme("inl", views, labels, cfg,
                           epochs=2, transport=cl.transport)
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.cluster.supervisor import Supervisor
from repro.core import topology as topology_lib
from repro.transport.network import NetworkTransport
from repro.transport.policy import DEFAULT_RETRY, RetryPolicy


def cluster_transport(supervisor: Supervisor, topo, cfg, *, seed: int = 0,
                      policy: RetryPolicy = DEFAULT_RETRY,
                      breaker="default", chaos=None, adaptive=None,
                      meter=None) -> NetworkTransport:
    """A NetworkTransport whose supervised edges cross process boundaries.

    Pass the SAME ChaosSchedule to the supervisor and here: the supervisor
    realises node windows with real signals, the transport consults them
    for deterministic masks — one schedule, two enforcement points."""
    topo = topology_lib.resolve(topo, cfg)
    return NetworkTransport(
        topo, cfg, seed=seed, policy=policy, breaker=breaker, chaos=chaos,
        channels=supervisor.edge_channels(topo), meter=meter,
        adaptive=adaptive, on_tick=supervisor.tick,
        node_down=supervisor.is_down)


class Cluster:
    """Supervisor + transport over a topology's measure nodes, as one
    context manager (workers spawn on __enter__, die on __exit__)."""

    def __init__(self, cfg, topology=None, *, seed: int = 0, chaos=None,
                 policy: RetryPolicy = DEFAULT_RETRY, breaker="default",
                 adaptive=None, meter=None, nodes: Optional[Sequence[str]] = None,
                 **supervisor_kwargs):
        self.topo = topology_lib.resolve(topology, cfg)
        self.cfg = cfg
        self.seed = seed
        self.chaos = chaos
        self._policy = policy
        self._breaker = breaker
        self._adaptive = adaptive
        self._meter = meter
        self.supervisor = Supervisor(
            list(nodes) if nodes is not None else self.topo.view_nodes(),
            seed=seed, chaos=chaos, **supervisor_kwargs)
        self.transport: Optional[NetworkTransport] = None

    def __enter__(self) -> "Cluster":
        self.supervisor.start()
        self.transport = cluster_transport(
            self.supervisor, self.topo, self.cfg, seed=self.seed,
            policy=self._policy, breaker=self._breaker, chaos=self.chaos,
            adaptive=self._adaptive, meter=self._meter)
        return self

    def __exit__(self, *exc) -> None:
        if self.transport is not None:
            self.transport.close()
            self.transport = None
        self.supervisor.stop()
