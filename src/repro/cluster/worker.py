"""The measure-node worker process: `python -m repro.cluster.worker`.

A worker owns its node's end of the byte transport and its own liveness —
nothing else.  The jitted fusion math stays in the supervisor's process
(it is the fusion CENTER; the paper's measure nodes ship bytes, they do
not hold the decoder), so what a SIGKILL here costs the system is exactly
what the paper says a lost node costs: the votes this node's uplink
owned, until the supervisor restores it.

Protocol: bind an ephemeral TCP port, print one JSON registration line
(`{"node", "host", "port", "pid"}`) on stdout for the supervisor to read,
then serve the echo/heartbeat protocol (`cluster/proto.py`) over the
versioned-handshake `SocketChannel` until told to exit.  The worker
re-enters accept() after a disconnect, so a supervisor that lost its
connection (or a restarted supervisor) can re-dial the same incarnation.

Deliberately light: standard library + numpy via the channel layer — no
jax, no repro.core — so a restart costs process-spawn time, not a jax
import.  The worker also watches its parent pid and exits when orphaned,
so a SIGKILL'd supervisor never leaks worker processes.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys

from repro.cluster import proto
from repro.transport.channel import ChannelError, HandshakeError, TcpListener

_ACCEPT_SLICE_S = 0.5       # granularity of the orphan-watch poll


def _orphaned(parent: int) -> bool:
    return os.getppid() != parent


def _serve(chan, parent: int) -> None:
    """Answer one supervisor connection until it closes or we are told
    to exit.  Stale requests queued while the process was SIGSTOPped are
    answered too — the supervisor's tag matching writes them off."""
    try:
        while True:
            try:
                frame = chan.recv(timeout=_ACCEPT_SLICE_S)
            except ChannelError:
                return                       # torn frame / reset: re-accept
            if frame is None:
                if chan.eof or _orphaned(parent):
                    return
                continue                     # idle slice
            op, tag, payload = proto.unpack_msg(frame)
            if op == proto.OP_PING:
                chan.send(proto.pack_msg(proto.OP_PONG, tag))
            elif op == proto.OP_ECHO:
                chan.send(proto.pack_msg(proto.OP_ECHO_REPLY, tag, payload))
            elif op == proto.OP_EXIT:
                raise SystemExit(0)
    finally:
        chan.close()


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="supervised measure-node worker (see repro/cluster)")
    p.add_argument("--node", required=True, help="topology node name")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0,
                   help="listen port (default: ephemeral)")
    args = p.parse_args(argv)

    parent = os.getppid()
    signal.signal(signal.SIGTERM, lambda *_: sys.exit(0))
    listener = TcpListener(args.host, args.port, name=args.node)
    print(json.dumps({"node": args.node, "host": listener.host,
                      "port": listener.port, "pid": os.getpid()}),
          flush=True)
    try:
        while not _orphaned(parent):
            try:
                chan = listener.accept(timeout=_ACCEPT_SLICE_S)
            except (HandshakeError, OSError):
                continue                     # a bad client is not our death
            if chan is not None:
                _serve(chan, parent)
    finally:
        listener.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
