"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs      / (chips * PEAK_FLOPS)
    memory     = HLO_bytes      / (chips * HBM_BW)
    collective = coll_bytes     / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(); collective bytes
are parsed out of the post-SPMD HLO text (cost_analysis does not expose
them).  cost_analysis counts a lax.scan body ONCE (verified empirically), so
the launcher lowers 1-period and 2-period UNROLLED variants to solve

    cost(k periods) = fixed + k * body   =>   total = fixed + n_periods * body

and the same compensation applies to collective bytes.  Known residual
undercount: recurrences *inside* a block (xLSTM time scans, the SSD
inter-chunk scan) stay counted once; they are <10% of block FLOPs for the
assigned configs (dominated by projections) — cross-checked against the
analytic 6ND MODEL_FLOPS column.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

# ---------------------------------------------------------------------------
# Hardware constants — TPU v5e (target platform)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link
    hbm_bytes: float = 16e9           # capacity per chip


HW = Hardware()

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute", "ragged-all-to-all")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(" + "|".join(_COLL_OPS) + r")(-start)?\(")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes per collective op kind in a post-SPMD module.
    '-done' ops are skipped (the '-start' already carries the shape)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLL_OPS}
    out["total"] = 0.0
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _LINE_RE.search(line)
        if not m:
            continue
        b = _shape_bytes(m.group(1))
        out[m.group(2)] += b
        out["total"] += b
    return out


# ---------------------------------------------------------------------------
# Analytic model FLOPs
# ---------------------------------------------------------------------------

def model_flops(cfg, shape_cfg) -> float:
    """6*N*D (train), 2*N*D (prefill), 2*N*B (decode); N = active params."""
    from repro.models import zoo
    n_active = zoo.param_count(cfg, active_only=True)
    if shape_cfg.mode == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n_active * tokens
    if shape_cfg.mode == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape_cfg.global_batch      # decode: 1 token/seq


# ---------------------------------------------------------------------------
# The three terms
# ---------------------------------------------------------------------------

def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int, hw: Hardware = HW) -> Dict[str, float]:
    compute = flops / (chips * hw.peak_flops)
    memory = hbm_bytes / (chips * hw.hbm_bw)
    collective = coll_bytes / (chips * hw.ici_bw)
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant}


_SUGGESTIONS = {
    "compute": ("shard the replicated attention heads (sequence/context "
                "parallelism) or cut recompute from remat to reduce HLO "
                "FLOPs toward the 6ND model floor"),
    "memory": ("reduce activation residency: flash custom-VJP instead of "
               "AD-through-scan, fp8/bf16 intermediates, or larger "
               "microbatching to raise arithmetic intensity"),
    "collective": ("overlap or restructure collectives: all-to-all expert "
                   "dispatch via shard_map, reduce-scatter+all-gather "
                   "(ZeRO) instead of all-reduce, INL-style bottleneck "
                   "compression of cross-boundary activations"),
}


def analyze(record: dict, cfg, shape_cfg, chips: int,
            hw: Hardware = HW) -> dict:
    """record: {'flops', 'hbm_bytes', 'coll_bytes'} (scan-compensated)."""
    terms = roofline_terms(record["flops"], record["hbm_bytes"],
                           record["coll_bytes"], chips, hw)
    mf = model_flops(cfg, shape_cfg)
    terms["model_flops"] = mf
    terms["hlo_flops"] = record["flops"]
    terms["useful_flop_ratio"] = mf / record["flops"] if record["flops"] else 0.0
    terms["suggestion"] = _SUGGESTIONS[terms["dominant"]]
    return terms
