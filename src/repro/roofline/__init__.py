from repro.roofline.analysis import (HW, analyze, collective_bytes,  # noqa
                                     model_flops, roofline_terms)
