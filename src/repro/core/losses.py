"""The in-network-learning loss — eq. (6) of the paper.

    L_s = (1/n) SUM_i [ log Q_phiJ(y_i | u_1..u_J)
          + s * SUM_j ( log Q_phij(y_i | u_j)
                        - log( P_thetaj(u_j|x_j) / Q_psij(u_j) ) ) ]

maximised; we return the NEGATIVE (a minimisation loss) decomposed into its
three terms so tests/benchmarks can assert each independently:

    loss = CE_joint + s * SUM_j ( CE_branch_j + rate_j )

CE_joint   = -log Q(y|u_all)        (the fusion decoder's log-loss)
CE_branch  = -log Q(y|u_j)          (per-node conditional decoders, held at
                                     node J+1 — Remark 1)
rate_j     = log(P(u_j|x_j)/Q(u_j)) (sampled, the paper's estimator) or the
                                     analytic Gaussian KL.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import bottleneck


def xent(logits, labels):
    """Mean -log Q(y) over the batch; labels (B,) int or (B,S) with -1 ignore."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    mask = (labels >= 0)
    safe = jnp.maximum(labels, 0)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)


def inl_loss(joint_logits, branch_logits: Sequence, labels,
             mus: Sequence, logvars: Sequence, us: Sequence,
             *, s: float, priors: Sequence = None,
             rate_estimator: str = "sample", rates: Sequence = None):
    """Eq. (6) as a minimisation objective.  Returns (loss, metrics).

    `rates` — optional precomputed per-row rate terms (one array per node),
    e.g. the second output of the fused cut-layer kernel
    (kernels/ops.cutlayer); when given, the rate is NOT recomputed here and
    `rate_estimator`/`priors` are ignored for the rate term.

    `priors` — per-node prior params for the (unfused) fallback rate: a
    sequence of {"mu", "logvar"} dicts, or ONE stacked dict with (J, d)
    leaves (the layout core/inl.py keeps for the fused kernel)."""
    J = len(branch_logits)
    if isinstance(priors, dict):               # stacked (J, d) -> per node
        priors = [jax.tree.map(lambda x: x[j], priors) for j in range(J)] \
            if priors else [{}] * J
    priors = priors if priors is not None else [{}] * J
    ce_joint = xent(joint_logits, labels)
    ce_branches = [xent(bl, labels) for bl in branch_logits]
    if rates is not None:
        rates = [jnp.mean(r) for r in rates]
    else:
        rates = []
        for j in range(J):
            if rate_estimator == "sample":
                r = bottleneck.rate_sampled(us[j], mus[j], logvars[j],
                                            priors[j])
            else:
                r = bottleneck.rate_analytic(mus[j], logvars[j], priors[j])
            rates.append(jnp.mean(r))
    loss = ce_joint + s * (jnp.sum(jnp.stack(ce_branches))
                           + jnp.sum(jnp.stack(rates)))
    metrics = {
        "loss": loss,
        "ce_joint": ce_joint,
        "ce_branch_mean": jnp.mean(jnp.stack(ce_branches)),
        "rate_mean": jnp.mean(jnp.stack(rates)),
        "rate_total": jnp.sum(jnp.stack(rates)),
    }
    return loss, metrics


def accuracy(logits, labels):
    pred = jnp.argmax(logits, axis=-1)
    mask = labels >= 0
    return ((pred == labels) * mask).sum() / jnp.maximum(mask.sum(), 1)
