"""In-network learning applied to the assigned LLM architectures.

The paper's vertical split, instantiated with transformer-family blocks:
J edge nodes each observe a VIEW of the token stream (its own embedding table
+ view-specific Gaussian feature noise — the LLM analogue of the paper's
noisy CIFAR views), run `inl.encoder_layers` periods of the architecture's
own block pattern, and emit per-token stochastic bottleneck latents u_j of
width `inl.d_bottleneck`.  Node (J+1) concatenates (eq. 5: J * d_bottleneck
== decoder input width == d_model), projects into the remaining stack and
decodes with the LM head.  Eq. (6) applies per token.

Sharding: encoder params/views carry a leading J axis -> sharded over the
first `J` slices of the 'data' mesh axis; only u_j / delta_j cross the
client boundary (the paper's bandwidth argument, now an ICI argument).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bottleneck, linkmodel, losses
from repro.models import layers, transformer, zoo


class INLLLMParams(NamedTuple):
    encoders: dict     # stacked (J, ...): embed + encoder stack + bottleneck head
    decoder: dict      # in_proj + decoder stack + final norm + lm head
    branch_heads: dict # (J, d_b, vocab_pad) per-node decoders (at node J+1)
    priors: dict = {}  # learned per-node Q_psi (J, d_b) mean/logvar; {} = N(0,I)


def encoder_cfg(cfg):
    pat = transformer.block_pattern(cfg)
    # NOTE: moe_impl="gspmd" — the shard_map EP dispatch cannot run under the
    # vmap over J stacked encoders (jax's vmap rule for psum inside shard_map
    # rejects it); the partitioner path is vmap-compatible.
    return dataclasses.replace(
        cfg, num_layers=cfg.inl.encoder_layers * len(pat),
        moe=dataclasses.replace(cfg.moe, first_dense_layers=0),
        moe_impl="gspmd")


def decoder_cfg(cfg):
    pat = transformer.block_pattern(cfg)
    dec_periods = transformer.num_periods(cfg) - cfg.inl.encoder_layers
    assert dec_periods >= 1, f"{cfg.name}: not enough periods for INL split"
    return dataclasses.replace(
        cfg, num_layers=(dec_periods * len(pat)
                         + cfg.moe.first_dense_layers),
        moe_impl="gspmd")


def init(cfg, key):
    J = cfg.inl.num_nodes
    dtype = jnp.dtype(cfg.dtype)
    e_cfg, d_cfg = encoder_cfg(cfg), decoder_cfg(cfg)
    ks = jax.random.split(key, 5)

    def one_encoder(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "embed": layers.embed_init(k1, cfg.vocab_size, cfg.d_model, dtype),
            "stack": transformer.stack_init(k2, e_cfg, dtype),
            "norm": layers.rmsnorm_init(cfg.d_model, dtype),
            "head": bottleneck.head_init(k3, cfg.d_model, cfg.inl.d_bottleneck,
                                         dtype),
        }

    encoders = jax.vmap(one_encoder)(jax.random.split(ks[0], J))
    decoder = {
        "in_proj": layers.dense_init(ks[1], J * cfg.inl.d_bottleneck,
                                     cfg.d_model, dtype=dtype),
        "stack": transformer.stack_init(ks[2], d_cfg, dtype),
        "final_norm": layers.rmsnorm_init(cfg.d_model, dtype),
        "unembed": layers.dense_init(ks[3], cfg.d_model,
                                     layers.pad_vocab(cfg.vocab_size),
                                     dtype=dtype),
    }
    vpad = layers.pad_vocab(cfg.vocab_size)
    bh = (jax.random.normal(ks[4], (J, cfg.inl.d_bottleneck, vpad),
                            jnp.float32) * 0.02).astype(dtype)
    priors = bottleneck.prior_init(cfg.inl.d_bottleneck,
                                   learned=cfg.inl.learned_prior,
                                   num_nodes=J)
    return INLLLMParams(encoders, decoder, {"w": bh}, priors)


def encode(params: INLLLMParams, cfg, tokens, rng, *, train: bool = True,
           rate_estimator: str = "sample", backend: str = "auto"):
    """tokens: (B,S).  Views differ by per-node embedding + feature noise.
    Returns (u, mu, logvar, rate): u/mu/logvar (J, B, S, d_b); rate
    (J, B, S) fp32 from the fused cut-layer kernel (None when train=False).

    The per-node encoders run under vmap, but the cut layer itself —
    sample + link quantizer + rate — is ONE fused kernel launch over all
    J * B * S rows (kernels/ops.cutlayer), with the hand-written eq.-(10)
    backward.  With link_bits <= 8 the int8 wire in `decode` carries the
    quantization instead, so the kernel runs with a full-precision link."""
    J = cfg.inl.num_nodes
    e_cfg = encoder_cfg(cfg)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    noise_keys = jax.random.split(jax.random.fold_in(rng, 0), J)

    def one(enc, nk):
        h = layers.embed(enc["embed"], tokens)
        # view-specific observation noise (sigma grows with node index via key
        # folding is NOT used here: homogeneous sigma keeps nodes exchangeable)
        h = h + (0.1 * jax.random.normal(nk, h.shape, jnp.float32)
                 ).astype(h.dtype)
        h, _, _ = transformer.stack_apply(enc["stack"], e_cfg, h, positions,
                                          mode="train")
        h = layers.rmsnorm(enc["norm"], h, cfg.norm_eps)
        return bottleneck.head_apply(enc["head"], h)

    mu, logvar = jax.vmap(one)(params.encoders, noise_keys)
    bits = cfg.inl.link_bits if cfg.inl.link_bits > 8 else 32
    if train:
        u, rate = bottleneck.fused_sample_rate(
            jax.random.fold_in(rng, 1), mu, logvar, link_bits=bits,
            rate_estimator=rate_estimator, prior=params.priors,
            backend=backend)
    else:
        # deterministic inference cut: same kernel, no-noise mode
        u, _ = bottleneck.fused_sample_rate(
            None, mu, logvar, link_bits=bits, rate_estimator="none",
            backend=backend)
        rate = None
    return u, mu, logvar, rate


def decode(params: INLLLMParams, cfg, u, tokens_shape):
    """u: (J,B,S,d_b) -> (joint_logits, branch_logits).

    The eq.-(5) concatenation is the client->center boundary: with
    link_bits <= 8 it runs over a compressed wire so the client-axis
    all-gather moves small buffers — the paper's bandwidth idea applied to
    the ICI.  link_bits == 8 rides the int8 wire (linkmodel.wire_concat);
    link_bits < 8 bit-packs sub-byte codewords into uint32 lanes
    (linkmodel.packed_wire_concat), 32/link_bits fewer collective bytes.
    Both pin their gathers via launch/sharding.wire_specs."""
    J, B, S, db = u.shape
    d_cfg = decoder_cfg(cfg)
    if cfg.inl.link_bits <= 8:
        from repro.launch.mesh import current_abstract_mesh
        from repro.launch.sharding import wire_specs
        gathered, client = wire_specs(current_abstract_mesh())
        if cfg.inl.link_bits < 8:                    # sub-byte packed wire
            u_cat = linkmodel.packed_wire_concat(u, cfg.inl.link_bits,
                                                 gathered, client)
        else:
            u_cat = linkmodel.wire_concat(u, gathered, client)  # int8 wire
    else:
        u_cat = linkmodel.float_concat(u)                 # eq. (5)
    h = layers.dense(params.decoder["in_proj"], u_cat)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, _, aux = transformer.stack_apply(params.decoder["stack"], d_cfg, h,
                                        positions, mode="train")
    h = layers.rmsnorm(params.decoder["final_norm"], h, cfg.norm_eps)
    return h, aux


def _chunked_inl_ce(params: INLLLMParams, cfg, h, u, labels,
                    chunk: int = 512):
    """Joint + per-branch CE, chunked over the sequence so the (B, S, vocab)
    joint logits and the (J, B, S, vocab) branch logits never materialise
    (at 128k vocab the branch logits alone are petabyte-scale).  Each chunk
    is jax.checkpoint'ed and recomputed in the backward pass."""
    J, B, S, db = u.shape
    chunk = min(chunk, S)
    nch = -(-S // chunk)
    pad = nch * chunk - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hb = jnp.moveaxis(h.reshape(B, nch, chunk, -1), 1, 0)
    ub = jnp.moveaxis(u.reshape(J, B, nch, chunk, db), 2, 0)
    lb = jnp.moveaxis(labels.reshape(B, nch, chunk), 1, 0)

    def ce_sum(logits, lab):
        mask = (lab != -1).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, jnp.maximum(lab, 0)[..., None],
                                 axis=-1)[..., 0]
        return -(ll * mask).sum(), mask.sum()

    @jax.checkpoint
    def body(carry, inp):
        j_nll, b_nll, cnt, hits = carry
        h_c, u_c, lab_c = inp
        joint = layers.dense(params.decoder["unembed"],
                             h_c)[..., :cfg.vocab_size]
        nll, n = ce_sum(joint, lab_c)
        branch = jnp.einsum("jbsd,jdv->jbsv", u_c,
                            params.branch_heads["w"])[..., :cfg.vocab_size]
        bn = ce_sum(branch, lab_c[None])[0]
        hits = hits + ((jnp.argmax(joint, -1) == lab_c)
                       & (lab_c != -1)).sum()
        return (j_nll + nll, b_nll + bn, cnt + n, hits), None

    z = jnp.zeros((), jnp.float32)
    (j_nll, b_nll, cnt, hits), _ = jax.lax.scan(
        body, (z, z, z, jnp.zeros((), jnp.int32)), (hb, ub, lb))
    cnt = jnp.maximum(cnt, 1.0)
    return j_nll / cnt, b_nll / cnt, hits / cnt


def loss_fn(params: INLLLMParams, cfg, batch, rng, *,
            rate_estimator: str = "sample", backend: str = "auto"):
    tokens, labels = batch["tokens"], batch["labels"]
    u, mu, logvar, rates = encode(params, cfg, tokens, rng, train=True,
                                  rate_estimator=rate_estimator,
                                  backend=backend)
    h, moe_aux = decode(params, cfg, u, tokens.shape)
    ce_joint, ce_branch_sum, acc = _chunked_inl_ce(params, cfg, h, u, labels)
    # rates (J,B,S) come from the fused cut-layer kernel — not recomputed
    rate_total = jnp.mean(rates.reshape(cfg.inl.num_nodes, -1),
                          axis=-1).sum()
    loss = ce_joint + cfg.inl.s * (ce_branch_sum + rate_total)
    metrics = {"ce_joint": ce_joint,
               "ce_branch_mean": ce_branch_sum / cfg.inl.num_nodes,
               "rate_mean": rate_total / cfg.inl.num_nodes,
               "rate_total": rate_total, "accuracy": acc}
    if cfg.is_moe:
        loss = loss + cfg.moe.router_aux_weight * moe_aux["lb_loss"] \
                    + cfg.moe.router_z_weight * moe_aux["z_loss"]
    metrics["loss"] = loss
    J = cfg.inl.num_nodes
    metrics["bits_per_token"] = jnp.asarray(
        2 * J * cfg.inl.d_bottleneck * cfg.inl.link_bits, jnp.float32)
    return loss, metrics


def make_train_step(cfg, optimizer):
    def step(params, opt_state, batch, rng):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, rng)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, metrics
    return step


def input_specs(cfg, shape_cfg):
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    i32 = jnp.int32
    return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32)}
