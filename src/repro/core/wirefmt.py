"""Packed wire format — quantized cut-layer latents travel bit-packed.

`core/bandwidth.py` charges the links `link_bits` per latent value (Table I),
but the execution layer used to move the DEQUANTIZED latents: fp32 (or bf16)
buffers over the 'client' all_gather, 4-16x the accounted bytes.  This module
closes that gap: a quantized latent is a `link_bits`-bit codeword index, and
the wire carries those indices packed into uint32 lanes
(`kernels/inl_bottleneck.pack_values` / `unpack_dequant`, jnp oracles in
`kernels/ref.py`), so collective traffic shrinks by `32 / link_bits` against
fp32.  Packing is a pure re-encoding — `unpack(pack(u)) == u` bit-for-bit on
quantizer-grid values — so the packed forward cannot change a trajectory.

Wire formats (the `wire=` option threaded through `Scheme.make_round` /
`make_epoch`, `schemes/runner.py` and `launch/sharding.py`):

    "dense"          the unpacked baseline: quantized VALUES move at their
                     storage dtype (fp32/bf16).  Exactly the pre-existing
                     graph — goldens are pinned to it.
    "packed"         client->server latents travel as packed codewords; the
                     server->client error vectors (eq. 10) stay dense.
                     Trajectories are BIT-IDENTICAL to "dense".
    "packed_duplex"  both directions packed at link_bits: the backward link
                     quantizes each error vector with a per-row dynamic
                     scale (straight-through, the same compression
                     `linkmodel.wire_concat` applies to the LLM cut at
                     int8).  Measured bytes == the paper's symmetric
                     2 b p s closed form exactly; trajectories track the
                     dense path only approximately (the backward link is
                     genuinely lossy — ~1e-4 relative loss drift at 8 bits
                     on the fixture, growing as bits shrink).

Both packed modes require a packable width (1 <= link_bits <= 16).

The differentiable units here are `custom_vjp` wrappers spanning
pack -> collective -> unpack, so gradients never try to flow through integer
codewords: `cut_and_ship` runs the pack-EMITTING fused cut-layer kernel (the
packed buffer is a free third output of the one forward pass) and hands the
cotangent sum to the same fused eq.-(10) backward the dense path uses;
`ship` packs an existing quantized latent (the learned-prior and split-
learning paths).  With `axis_name` the collective is a real `all_gather`
over the packed buffer inside `shard_map` (core/sharded.py); without it the
pack/unpack round trip simulates the wire on one device — same values, same
measured bytes.

Measured bytes come from `jax.eval_shape` over the real wire ops
(`shipped_nbytes` / `round_wire_bytes`).  What is literal vs modeled: the
FORWARD packed buffer is literally the collective payload (`all_gather`
moves the uint32 lanes).  The duplex BACKWARD link is modeled: the paper's
server holds the full error vector and returns q-bit codes to each node,
but in `shard_map` the replicated decoder's partial cotangents must be
summed first, so execution runs `psum_scatter` (dense) THEN quantizes
locally — the values each node receives are exactly the modeled q-bit
link's, and the meter charges that link's packed size, not the simulation
artifact's.  (Without a mesh, forward and backward alike are on-device
round trips simulating the link — same values, same accounting.)  Per-row
fp32 scales of the duplex backward ride the control channel and are
excluded, like packet headers are in the paper's accounting.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import inl_bottleneck as _bn
from repro.kernels import ops, ref

WIRE_FORMATS = ("dense", "packed", "packed_duplex")


def resolve_wire(wire: str, link_bits: int):
    """Validate the wire format against the link width.

    Returns (wire, bwd_bits): bwd_bits is the backward-link code width
    (None = dense fp-valued error vectors)."""
    if wire not in WIRE_FORMATS:
        raise ValueError(f"unknown wire format {wire!r}; "
                         f"known: {WIRE_FORMATS}")
    if wire != "dense" and not 1 <= link_bits <= 16:
        raise ValueError(f"wire={wire!r} needs a packable link width "
                         f"(1 <= link_bits <= 16), got link_bits="
                         f"{link_bits}; use wire='dense' for full-precision "
                         "links")
    return wire, (link_bits if wire == "packed_duplex" else None)


def dyn_quantize(g, bits: int, axis=-1):
    """Dynamic-scale uniform quantizer (value map) for the backward link:
    error vectors are coded on a (2^bits - 1)-level grid over
    [-max|g|, max|g|], the maximum taken over `axis` (default: per row,
    which makes the result identical under any batch/client sharding;
    axis=None gives the per-tensor scale `linkmodel.packed_wire_concat`
    uses).  The single source of truth for the q-bit backward link."""
    gf = g.astype(jnp.float32)
    m = jnp.max(jnp.abs(gf)) if axis is None \
        else jnp.max(jnp.abs(gf), axis=axis, keepdims=True)
    levels = (1 << bits) - 1
    scale = levels / (2.0 * jnp.maximum(m, 1e-12))
    q = jnp.round((jnp.clip(gf, -m, m) + m) * scale) / scale - m
    return q.astype(g.dtype)


def _gather(x, axis_name):
    return jax.lax.all_gather(x, axis_name, axis=0, tiled=True) \
        if axis_name else x


def _scatter(g, axis_name):
    """Transpose of `_gather` — exactly what AD of the dense all_gather
    produces (psum_scatter: each client receives its own summed chunk)."""
    return jax.lax.psum_scatter(g, axis_name, scatter_dimension=0,
                                tiled=True) if axis_name else g


# ---------------------------------------------------------------------------
# ship: an existing quantized latent crosses the wire packed
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def _ship(u, bits, axis_name, bwd_bits, impl, block_t):
    packed = _bn.pack_values(u, link_bits=bits, impl=impl, block_t=block_t)
    packed = _gather(packed, axis_name)
    return _bn.unpack_dequant(packed, u.shape[-1], link_bits=bits,
                              dtype=u.dtype, impl=impl, block_t=block_t)


def _ship_fwd(u, bits, axis_name, bwd_bits, impl, block_t):
    return _ship(u, bits, axis_name, bwd_bits, impl, block_t), None


def _ship_bwd(bits, axis_name, bwd_bits, impl, block_t, res, g):
    delta = _scatter(g, axis_name)
    if bwd_bits is not None:
        delta = dyn_quantize(delta, bwd_bits)
    return (delta,)


_ship.defvjp(_ship_fwd, _ship_bwd)


def ship(u, *, link_bits: int, wire: str = "dense", axis_name=None,
         backend: str = "auto", block_t: int = None):
    """Move a quantized latent u (..., d) across the client->server wire.

    dense: the plain (tiled) all_gather over `axis_name`, or the identity
    without one — the pre-existing graph, bit for bit.  packed: the buffer
    on the wire is uint32 codeword lanes; values are unchanged.  The
    backward returns each client its eq.-(10) error chunk (straight-through;
    packed_duplex additionally quantizes it at link_bits)."""
    wire, bwd_bits = resolve_wire(wire, link_bits)
    if wire == "dense":
        return _gather(u, axis_name)
    return _ship(u, link_bits, axis_name, bwd_bits,
                 ops.resolve_backend(backend), block_t)


def relay_hop(x, *, link_bits: int, wire: str = "dense", dtype=None,
              backend: str = "auto", block_t: int = None):
    """One edge traversal of a multi-hop topology (core/topology.py): a
    relay re-encodes the payload it forwards for ITS outgoing link.

    Forward: straight-through re-quantization of the (already quantized)
    values at this edge's `link_bits` — the identity when the payload is
    already on this grid (the uniform quantizer is idempotent), a genuine
    re-coding when an upstream link was finer — then, for a dense edge
    narrower than fp32, a straight-through round trip through the edge's
    storage `dtype`, and finally the edge's wire encoding (`ship`: packed
    codeword lanes are a lossless re-encoding; "packed_duplex" also
    quantizes the BACKWARD error chunk at `link_bits` on every traversal,
    so a b-hop route's eq.-(10) error vector is b-times link-quantized —
    the multi-hop link model, priced per edge by the topology meter)."""
    wire, _ = resolve_wire(wire, link_bits)
    q = ref.quantize_value(x.astype(jnp.float32), link_bits).astype(x.dtype)
    x = x + jax.lax.stop_gradient(q - x)
    if wire == "dense" and dtype is not None \
            and jnp.dtype(dtype) != x.dtype:
        rt = x.astype(dtype).astype(x.dtype)
        x = x + jax.lax.stop_gradient(rt - x)
    return ship(x, link_bits=link_bits, wire=wire, backend=backend,
                block_t=block_t)


# ---------------------------------------------------------------------------
# cut_and_ship: the fused cut layer with the wire folded into the kernel
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _cut_ship(mu, logvar, eps, bits, mode, axis_name, bwd_bits, impl,
              block_t):
    u, packed, rate = _bn.cutlayer_pack_forward(
        mu, logvar, eps, link_bits=bits, rate_estimator=mode, impl=impl,
        block_t=block_t)
    packed = _gather(packed, axis_name)
    u_shipped = _bn.unpack_dequant(packed, mu.shape[-1], link_bits=bits,
                                   dtype=u.dtype, impl=impl, block_t=block_t)
    return u, rate, u_shipped


def _cut_ship_fwd(mu, logvar, eps, bits, mode, axis_name, bwd_bits, impl,
                  block_t):
    out = _cut_ship(mu, logvar, eps, bits, mode, axis_name, bwd_bits, impl,
                    block_t)
    return out, (mu, logvar, eps)


def _cut_ship_bwd(bits, mode, axis_name, bwd_bits, impl, block_t, res, cts):
    mu, logvar, eps = res
    gu, grate, g_shipped = cts
    delta = _scatter(g_shipped, axis_name)
    if bwd_bits is not None:
        delta = dyn_quantize(delta, bwd_bits)
    return _bn.cutlayer_backward(mu, logvar, eps, gu + delta.astype(gu.dtype),
                                 grate, link_bits=bits, rate_estimator=mode,
                                 impl=impl, block_t=block_t)


_cut_ship.defvjp(_cut_ship_fwd, _cut_ship_bwd)


def cut_and_ship(key, mu, logvar, *, link_bits: int,
                 rate_estimator: str = "sample", wire: str = "dense",
                 axis_name=None, prior: dict = None, eps=None,
                 backend: str = "auto", block_t: int = None):
    """The full cut-layer transaction: sample + quantize + rate + WIRE.

    Returns (u, rate, u_shipped): u (..., d) is the node-local quantized
    latent (branch heads read it in place), rate (...,) the eq.-(6) term,
    and u_shipped what the fusion center receives — all_gathered over
    `axis_name` when given, identical values either way.  wire="dense"
    reproduces `bottleneck.fused_sample_rate` + `all_gather` exactly;
    "packed"/"packed_duplex" run the pack-emitting kernel so the collective
    moves uint32 codeword lanes.  The backward is the same fused eq.-(10)
    split in every mode (duplex additionally quantizes the error chunk).

    key=None is the deterministic cut (eps == 0); sharded callers that
    pre-draw randomness at global shape pass their slice via `eps` instead
    of a key.  `prior` selects the learned-Gaussian-prior rate (that kernel
    pair keeps its own custom VJP, so its wire is the standalone `ship`)."""
    wire, bwd_bits = resolve_wire(wire, link_bits)
    if eps is None:
        eps = (jnp.zeros(mu.shape, jnp.float32) if key is None
               else jax.random.normal(key, mu.shape, jnp.float32))
    elif key is not None:
        raise ValueError("pass either key or eps, not both")
    prior = prior or {}
    if wire == "dense" or prior:
        u, rate = ops.cutlayer(mu, logvar, eps, link_bits=link_bits,
                               rate_estimator=rate_estimator,
                               prior_mu=prior.get("mu"),
                               prior_logvar=prior.get("logvar"),
                               backend=backend, block_t=block_t)
        u_shipped = ship(u, link_bits=link_bits, wire=wire,
                         axis_name=axis_name, backend=backend,
                         block_t=block_t)
        return u, rate, u_shipped
    u, rate, u_shipped = _cut_ship(mu, logvar, eps, link_bits,
                                   rate_estimator, axis_name, bwd_bits,
                                   ops.resolve_backend(backend), block_t)
    return u, rate, u_shipped


# ---------------------------------------------------------------------------
# Measured bytes: what the wire buffers actually occupy
# ---------------------------------------------------------------------------

def _nbytes(sds) -> int:
    return math.prod(sds.shape) * jnp.dtype(sds.dtype).itemsize


def shipped_nbytes(n_vectors: int, d: int, *, link_bits: int,
                   wire: str = "dense", dtype=jnp.float32) -> int:
    """Bytes ONE direction of the wire moves for `n_vectors` d-vectors,
    derived with jax.eval_shape from the op that actually runs (the packed
    buffer from `pack_values`, the dense buffer at its storage dtype)."""
    wire, _ = resolve_wire(wire, link_bits)
    if wire == "dense":
        return _nbytes(jax.ShapeDtypeStruct((n_vectors, d),
                                            jnp.dtype(dtype)))
    # codeword lanes are dtype-independent, so size them at fp32 — the
    # training path packs from the kernel's fp32 internals anyway (a bf16
    # STORED latent only restricts the standalone pack_values re-encode)
    packed = jax.eval_shape(
        lambda x: _bn.pack_values(x, link_bits=link_bits, impl="reference"),
        jax.ShapeDtypeStruct((n_vectors, d), jnp.float32))
    return _nbytes(packed)


def round_wire_bytes(n_vectors: int, d: int, *, link_bits: int,
                     wire: str = "dense", dtype=jnp.float32) -> dict:
    """Measured bytes of one training round's cut-layer exchange:
    activations forward + error vectors backward (§III-C's two directions),
    each at the size its buffer occupies on the MODELED link under `wire`.

    dense: both directions at the storage dtype.  packed: forward codeword
    lanes, backward dense (the error vectors stay full precision).
    packed_duplex: both directions as codeword lanes — the backward size is
    what the q-bit error chunks occupy; see the module docstring for where
    the shard_map execution's dense psum_scatter (a simulation artifact of
    the replicated decoder) diverges from the modeled link."""
    wire, bwd_bits = resolve_wire(wire, link_bits)
    fwd = shipped_nbytes(n_vectors, d, link_bits=link_bits, wire=wire,
                         dtype=dtype)
    if bwd_bits is not None:
        bwd = shipped_nbytes(n_vectors, d, link_bits=bwd_bits, wire="packed",
                             dtype=dtype)
    else:
        bwd = shipped_nbytes(n_vectors, d, link_bits=link_bits, wire="dense",
                             dtype=dtype)
    return {"fwd": fwd, "bwd": bwd, "total": fwd + bwd}
