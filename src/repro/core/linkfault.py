"""Per-edge link models and graceful degradation under unreliable networks.

The paper's comparison (and our reproduction up to PR 5) assumes every
latent arrives intact — the wireless/IoT setting it targets never does
(Gao et al., arXiv:2003.13376 and the hybrid FL/SL wireless optimisation
literature both evaluate under lossy, heterogeneous links).  This module
attaches a fault model to `core/topology.Edge` and gives every scheme a
degrade-gracefully path instead of a crash or silent divergence:

    LinkModel — per-edge unreliability: erasure probability (the whole
        payload of a (round, edge) transmission is lost), a latency
        distribution (latency_ms + jitter_ms * Exp(1) per draw), and a
        bandwidth cap (transmission time = payload bits / bandwidth_bps)
        for straggler modelling against a fusion deadline.

    Delivery masks — deterministic per-(round, edge) fault draws from
        FOLDED PRNG keys: every draw is a pure function of (round rng,
        edge index), so the sharded shard_map rounds, the whole-epoch
        scan, the per-round dispatch loop and host-side metering all see
        the SAME faults (sharded == single-device stays bit-identical).

    partial_fuse — the fusion center's fuse-what-arrived semantics: the
        missing latent chunks are masked out of the eq.-(5) concatenation
        and the surviving ones renormalised by J / n_delivered, so the
        decoder input keeps its magnitude statistics.  Backward, AD then
        routes eq.-(10) error chunks ONLY over the surviving reverse
        edges (a dropped chunk's cotangent is exactly zero) — the paper's
        error-vector split restricted to the links that exist this round.

Activation rule: attaching ANY LinkModel to an edge switches the schemes
onto the fault-aware code paths — a default `LinkModel()` is a modelled
PERFECT link (its masks are constantly all-ones), which the property
tests use to pin the fault path bitwise against the baseline.  A
topology with no LinkModel on any edge (and cfg.edge_dropout == 0, no
fusion deadline) takes the pre-existing code paths untouched, so the
golden trajectories cannot move.

Scheme semantics (wired in core/inl.py, core/sharded.py and
core/schemes/{inl,fl,sl}.py):

    INL  partial fusion as above; node-dropout TRAINING via
         `cfg.edge_dropout` (each view additionally dropped per round
         with that probability, so robustness is learned); stragglers
         via `cfg.fusion_deadline_ms` — views whose route's cumulative
         latency + transmission time misses the deadline are fused as
         missing.
    FL   a dropped client uplink masks that client's weights out of the
         FedAvg average (the server averages the deltas that arrived and
         re-broadcasts; if every upload is lost the round keeps the
         previous model).
    SL   its single client->server boundary either works or the round is
         SKIPPED after `max_link_retries` bounded retries (state carried
         through unchanged) — split learning has no partial-fusion
         reading.

Delivered-vs-offered: `round_fault_charges` splits one round's bandwidth
between what the schedule put on the links (offered — SL retries charge
per attempt) and what the fusion center actually consumed (delivered),
feeding `BandwidthMeter.add_delivered` in the runner.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# Distinct fold_in salts so fault draws can never collide with the round's
# own key consumption (loss_fn splits rng; fold_in derives independently).
_SALT_FAULTS = 0x11_4bed      # per-edge erasure / latency draws
_SALT_DROPOUT = 0x22_4bed     # cfg.edge_dropout training curriculum
_SALT_RETRY = 0x33_4bed       # SL bounded-retry attempt draws

FORCE_ERASURE_ENV = "REPRO_FORCE_ERASURE"


@dataclass(frozen=True)
class LinkModel:
    """Unreliability of one directed link.  Hashable (rides inside the
    frozen `topology.Edge`, which jit treats as a static).

    erasure        P(the whole (round, edge) payload is lost in flight)
    latency_ms     mean propagation latency per traversal
    jitter_ms      scale of the exponential latency tail (stragglers)
    bandwidth_bps  serialisation cap: tx time = payload bits / cap
                   (None = infinitely fast link, latency only)
    """
    erasure: float = 0.0
    latency_ms: float = 0.0
    jitter_ms: float = 0.0
    bandwidth_bps: Optional[float] = None

    def __post_init__(self):
        if not 0.0 <= self.erasure < 1.0:
            raise ValueError(f"erasure must be in [0, 1), got {self.erasure}")
        if self.latency_ms < 0 or self.jitter_ms < 0:
            raise ValueError("latency_ms/jitter_ms must be >= 0, got "
                             f"({self.latency_ms}, {self.jitter_ms})")
        if self.bandwidth_bps is not None and self.bandwidth_bps <= 0:
            raise ValueError(f"bandwidth_bps must be > 0, got "
                             f"{self.bandwidth_bps}")


def forced_erasure(default: float = 0.0) -> float:
    """The REPRO_FORCE_ERASURE override (CI's forced-erasure smoke leg).
    Unset or empty (matrix legs export it blank) means `default`."""
    raw = os.environ.get(FORCE_ERASURE_ENV, "")
    return float(raw) if raw else default


def with_links(topo, link) -> "Topology":
    """A copy of `topo` with LinkModels attached: `link` is one LinkModel
    for every edge, or a {edge_key: LinkModel} dict (missing keys keep the
    edge's current model)."""
    if isinstance(link, LinkModel):
        link = {e.key: link for e in topo.edges}
    unknown = set(link) - {e.key for e in topo.edges}
    if unknown:
        raise ValueError(f"with_links got models for unknown edge(s) "
                         f"{sorted(unknown)}; edges: "
                         f"{[e.key for e in topo.edges]}")
    edges = tuple(replace(e, link=link.get(e.key, e.link))
                  for e in topo.edges)
    return type(topo)(topo.nodes, edges)


# ---------------------------------------------------------------------------
# Activation: which cfg/topology combinations take the fault-aware paths
# ---------------------------------------------------------------------------

def has_link_models(topo) -> bool:
    """True when ANY edge carries a LinkModel — even a perfect one (the
    all-ones-mask property tests rely on a modelled-but-perfect link
    exercising the fault path)."""
    return any(e.link is not None for e in topo.edges)


def deadline_ms(cfg) -> Optional[float]:
    return getattr(cfg, "fusion_deadline_ms", None)


def edge_dropout(cfg) -> float:
    return float(getattr(cfg, "edge_dropout", 0.0) or 0.0)


def active(topo, cfg, *, train: bool) -> bool:
    """Whether a round on (topo, cfg) must run the fault-aware path.  False
    keeps the caller on the pre-fault code bit for bit (goldens)."""
    if has_link_models(topo):
        return True
    return train and edge_dropout(cfg) > 0.0


# ---------------------------------------------------------------------------
# Deterministic draws: pure functions of (round rng, edge index)
# ---------------------------------------------------------------------------

def fault_key(rng):
    """The per-round fault stream, derived WITHOUT disturbing the round's
    own key consumption (loss_fn's split(rng) chain is untouched)."""
    return jax.random.fold_in(rng, _SALT_FAULTS)


def _edge_tx_ms(link: Optional[LinkModel], payload_bits: float) -> float:
    if link is None or link.bandwidth_bps is None:
        return 0.0
    return 1e3 * payload_bits / link.bandwidth_bps


def _edge_draws(key, i: int, link: Optional[LinkModel], shape=()):
    """(erased, latency_ms) draws for edge index `i`: both are deterministic
    in (key, i) — any shard, dispatch mode, or host-side meter folding the
    same round key reproduces them exactly."""
    if link is None:
        return jnp.zeros(shape, bool), jnp.zeros(shape, jnp.float32)
    ke = jax.random.fold_in(key, 2 * i)
    kl = jax.random.fold_in(key, 2 * i + 1)
    erased = (jax.random.uniform(ke, shape) < link.erasure) \
        if link.erasure > 0 else jnp.zeros(shape, bool)
    lat = jnp.full(shape, link.latency_ms, jnp.float32)
    if link.jitter_ms > 0:
        lat = lat + link.jitter_ms * jax.random.exponential(kl, shape)
    return erased, lat


def _route(topo, name: str):
    """Edges from view node `name` to the fuse node, with their declaration
    indices (the fault-draw index space)."""
    idx = {e.key: i for i, e in enumerate(topo.edges)}
    out = []
    cur = name
    while cur != topo.fuse_node:
        e = topo.out_edge(cur)
        out.append((idx[e.key], e))
        cur = e.dst
    return out


def delivery_mask(key, topo, cfg, *, payload_scale: float = 1.0,
                  deadline: Optional[float] = None, dropout: float = 0.0,
                  dropout_key=None, shape=()):
    """The (J,) + shape boolean delivery mask of one fusion: view j is True
    iff every edge on its route survived erasure, its cumulative
    latency + transmission time met `deadline` (store-and-forward per hop;
    None disables the deadline), and it survived the training `dropout`
    draw.  `payload_scale` multiplies each edge's closed-form payload bits
    (batch size for a training round, 1 for a per-request fusion) when a
    bandwidth cap converts them to transmission time."""
    from repro.core import topology as topology_lib
    draws = {}
    for i, e in enumerate(topo.edges):
        erased, lat = _edge_draws(key, i, e.link, shape)
        bits = (payload_scale * len(topo.payload(e))
                * cfg.d_bottleneck * topology_lib.edge_bits(e, cfg))
        draws[i] = (erased, lat + _edge_tx_ms(e.link, bits))
    masks = []
    for j, name in enumerate(topo.view_nodes()):
        ok = jnp.ones(shape, bool)
        t = jnp.zeros(shape, jnp.float32)
        for i, _e in _route(topo, name):
            erased, time_ms = draws[i]
            ok = ok & ~erased
            t = t + time_ms
        if deadline is not None:
            ok = ok & (t <= deadline)
        if dropout > 0.0:
            kd = jax.random.fold_in(
                jax.random.fold_in(dropout_key if dropout_key is not None
                                   else key, _SALT_DROPOUT), j)
            ok = ok & (jax.random.uniform(kd, shape) >= dropout)
        masks.append(ok)
    return jnp.stack(masks)


def round_delivery_mask(rng, topo, cfg, batch_size: int, *, train: bool):
    """The (J,) per-ROUND mask the training paths consume: link erasures +
    the fusion deadline (cfg.fusion_deadline_ms) + the cfg.edge_dropout
    training curriculum.  Pure in (rng, statics) — see module docstring."""
    return delivery_mask(
        fault_key(rng), topo, cfg, payload_scale=float(batch_size),
        deadline=deadline_ms(cfg),
        dropout=edge_dropout(cfg) if train else 0.0)


def sample_delivery_mask(key, topo, cfg, n: int, *,
                         deadline: Optional[float] = None):
    """Per-REQUEST masks for inference under faults: (J, n) — each of the
    n requests draws its own erasures and latencies per edge (payload = a
    single latent per view), judged against `deadline` (defaults to
    cfg.fusion_deadline_ms)."""
    return delivery_mask(fault_key(key), topo, cfg, payload_scale=1.0,
                         deadline=deadline if deadline is not None
                         else deadline_ms(cfg), shape=(n,))


def request_delivery_mask(key, topo, cfg, request_ids, *,
                          deadline: Optional[float] = None):
    """Delivery masks keyed PER REQUEST ID: (J, n) for `request_ids` (n,)
    int32.  Request r's draws are a pure function of (key, r, edge) — unlike
    `sample_delivery_mask`, whose draws depend on the request's POSITION in
    the batch — so a request fused inside a padded 64-wide serving bucket
    sees exactly the faults it would see served alone.  This is the
    bit-exactness contract the continuous-batching serving plane
    (repro/serving) relies on: batch composition and bucket padding cannot
    move any request's fault draw."""
    base = fault_key(key)
    dl = deadline if deadline is not None else deadline_ms(cfg)

    def one(rid):
        return delivery_mask(jax.random.fold_in(base, rid), topo, cfg,
                             payload_scale=1.0, deadline=dl)

    return jnp.moveaxis(jax.vmap(one)(jnp.asarray(request_ids)), 0, 1)


# ---------------------------------------------------------------------------
# Partial fusion: mask the missing chunks, renormalise the survivors
# ---------------------------------------------------------------------------

def partial_fuse(u, mask):
    """Fuse-what-arrived: u (J, B, d) latents as the fusion center would
    receive them, mask (J,) per-round or (J, B) per-sample delivery.
    Missing chunks are zeroed and the survivors scaled by J / n_delivered,
    preserving the eq.-(5) concatenation's magnitude statistics.

    With an all-ones mask this is multiplication by exactly 1.0 — bitwise
    the identity (pinned by tests/test_linkfault.py), so a modelled
    perfect network cannot perturb a trajectory.  Backward, the masked
    multiply zeroes the dropped chunks' cotangents: eq.-(10) error vectors
    flow only over the surviving reverse edges, scaled like the forward.
    An all-dropped fusion yields the zero vector (the decoder sees an
    empty concatenation) — honest, not special-cased."""
    J = u.shape[0]
    m = mask.astype(u.dtype)
    while m.ndim < u.ndim:
        m = m[..., None]                       # (J,1,1) or (J,B,1)
    n = jnp.sum(mask.astype(jnp.float32), axis=0)        # () or (B,)
    scale = (J / jnp.maximum(n, 1.0)).astype(u.dtype)
    if scale.ndim:
        scale = scale[:, None]                 # (B,1) broadcasts over d
    return u * m * scale


# ---------------------------------------------------------------------------
# FL / SL semantics: one client<->server uplink
# ---------------------------------------------------------------------------

def uplink_model(topo) -> LinkModel:
    """FL's weight exchange and SL's cut boundary ride ONE physical
    client<->server uplink; its model is the worst case over the star's
    edges (max erasure / latency / jitter, min bandwidth cap)."""
    links = [e.link for e in topo.edges if e.link is not None]
    if not links:
        return LinkModel()
    caps = [l.bandwidth_bps for l in links if l.bandwidth_bps is not None]
    return LinkModel(
        erasure=max(l.erasure for l in links),
        latency_ms=max(l.latency_ms for l in links),
        jitter_ms=max(l.jitter_ms for l in links),
        bandwidth_bps=min(caps) if caps else None)


def client_delivery_mask(rng, topo, cfg, *, train: bool):
    """FL: which of the J client uploads reached the server this round —
    each client's own uplink erasure plus the training dropout curriculum
    (the weight exchange has no fusion deadline: FedAvg rounds are
    synchronous barriers, not deadline fusions)."""
    return delivery_mask(fault_key(rng), topo, cfg,
                         dropout=edge_dropout(cfg) if train else 0.0)


def attempt_successes(rng, topo, cfg, attempts: int):
    """SL's bounded retry: (attempts,) independent survival draws of the
    single uplink (erasure only — a retry re-sends the same payload).
    The round runs iff ANY attempt succeeds."""
    link = uplink_model(topo)
    key = jax.random.fold_in(fault_key(rng), _SALT_RETRY)
    if link.erasure <= 0:
        return jnp.ones((attempts,), bool)
    return jax.random.uniform(key, (attempts,)) >= link.erasure


def round_success(rng, topo, cfg, attempts: int):
    return jnp.any(attempt_successes(rng, topo, cfg, attempts))


def request_survival(key, topo, cfg, n: int, *,
                     deadline: Optional[float] = None):
    """(n,) per-request survival of the single client->server uplink
    (FL/SL inference): erasure draw + latency-vs-deadline when a deadline
    is configured.  Requests that fail yield no prediction — callers fall
    back to the uninformative uniform distribution."""
    link = uplink_model(topo)
    erased, lat = _edge_draws(fault_key(key), 0, link, (n,))
    ok = ~erased
    dl = deadline if deadline is not None else deadline_ms(cfg)
    if dl is not None:
        bits = cfg.num_clients * cfg.d_bottleneck * cfg.link_bits
        ok = ok & (lat + _edge_tx_ms(link, float(bits)) <= dl)
    return ok


def degrade_probs(probs, ok):
    """Replace failed requests' predictions with the uniform distribution
    (the server answers, but not from this request's data)."""
    C = probs.shape[-1]
    return jnp.where(ok[:, None], probs, jnp.full_like(probs, 1.0 / C))


# ---------------------------------------------------------------------------
# Delivered-vs-offered bandwidth: host-side per-round charges
# ---------------------------------------------------------------------------

def _np(x) -> float:
    return float(jax.device_get(x))


def round_fault_charges(rng, scheme_name: str, topo, cfg, batch_size: int,
                        charges: Dict) -> Tuple[Dict, Dict]:
    """One faulty round's (offered, delivered) bandwidth, mirroring the
    static `charges` structure {edge_key_or_None: (bits, nbytes)}.

    offered — what the schedule put on the links: the nominal charges,
    except SL where every retry re-offers the round's exchange.
    delivered — what the consumer actually used: INL charges each edge the
    fraction of its payload views that reached the fusion on time (their
    eq.-(10) error chunks return over the same surviving edges, so the
    fraction applies to both directions); FL counts the full broadcast
    down plus only the surviving uploads; SL delivers its exchange only
    when an attempt succeeded.  Draws replay the SAME folded keys the
    in-graph masks consume, so the meter and the execution agree round by
    round."""
    if scheme_name in ("inl", "splitfed", "hybrid"):
        # the per-edge payload-fraction rule covers the hybrids too: a
        # dead route loses that client's WHOLE share of the edge's round
        # — its activations leave the fusion and its weight exchange (the
        # FedAvg upload/broadcast, the hybrid sync) never completes
        mask = jax.device_get(round_delivery_mask(
            rng, topo, cfg, batch_size, train=True))
        dlv = {}
        for e in topo.edges:
            pay = topo.payload(e)
            frac = sum(bool(mask[v]) for v in pay) / len(pay)
            bits, nbytes = charges[e.key]
            dlv[e.key] = (bits * frac, nbytes * frac)
        return dict(charges), dlv
    if scheme_name == "fl":
        mask = jax.device_get(client_delivery_mask(rng, topo, cfg,
                                                   train=True))
        J = cfg.num_clients
        frac = (J + int(mask.sum())) / (2.0 * J)   # down full, up masked
        dlv = {k: (b * frac, n * frac) for k, (b, n) in charges.items()}
        return dict(charges), dlv
    if scheme_name == "sl":
        from repro.core import schemes
        attempts = getattr(schemes.get("sl"), "max_link_retries", 2) + 1
        oks = jax.device_get(attempt_successes(rng, topo, cfg, attempts))
        used = int(oks.argmax()) + 1 if oks.any() else attempts
        ok = bool(oks.any())
        off = {k: (b * used, n * used) for k, (b, n) in charges.items()}
        dlv = {k: (b * ok, n * ok) for k, (b, n) in charges.items()}
        return off, dlv
    return dict(charges), dict(charges)
