"""First-class network topology — the inference graph as a declarative API.

The source paper trains the STAR setting (J measurement nodes, one fusion
center); the authors' follow-up (In-Network Learning: Distributed Training
and Inference in Networks, arXiv:2107.03433) generalises INL to arbitrary
networks where intermediate nodes fuse incoming latents with their own
observation and forward the result along multi-hop routes.  This module
makes that graph an explicit object instead of an assumption baked into
`Scheme.make_round` / `core/sharded.py`'s single all_gather:

    Node  — name + role:
              "measure"  holds a view, no incoming links (a leaf sensor)
              "relay"    holds a view AND forwards everything it receives
              "fuse"     the fusion center (node J+1): decodes, no view
    Edge  — a directed link src -> dst carrying its own width
            (`link_bits`, default: cfg.link_bits), wire format
            (`wire`, core/wirefmt.py, default: the round's wire=) and
            storage dtype for dense payloads (`dtype`, default: the
            cfg compute dtype)
    Topology — nodes + edges, validated on construction: exactly one fuse
            node (the single sink), acyclic, every measure node reaches the
            fuse node, and every non-fuse node forwards along exactly ONE
            outgoing edge (multicast duplicates latents and has no eq.-(5)
            reading — rejected).

Every non-fuse node observes a view: `views[j]` feeds `view_nodes()[j]`
(declaration order), so a topology with J view-holding nodes consumes the
same (J, B, H, W, C) multi-view batch the star does and
`cfg.num_clients == num_views()` is enforced (`resolve`).

Execution model (`graph_cut_and_ship` — what `core/inl.py` and
`core/sharded.py` compile the graph to):

  1. every view node encodes its observation and applies the fused cut
     layer (`kernels/ops.cutlayer`) at its OUTGOING edge's width — nodes
     sharing a (link_bits, prior) first hop fold into one kernel launch,
     exactly the star's single launch when the graph is edge-homogeneous;
  2. edges run in topological order: a relay concatenates the latents it
     received with its own (eq. (5) applied per hop) and re-encodes the
     whole payload for its outgoing link — a straight-through
     re-quantization at the edge's width plus the edge's wire encoding
     (`wirefmt.relay_hop`).  On an edge-homogeneous graph the re-coding is
     the identity (the uniform quantizer is idempotent on its own grid),
     so a dense chain/tree reproduces the star's latents bit for bit;
  3. the fuse node receives every view node's latent (possibly re-coded by
     the hops) and decodes the eq.-(5) concatenation as before.  Backward,
     AD routes each error chunk edge-REVERSED through the same hops — the
     eq.-(10) split per link, with "packed_duplex" edges quantizing the
     chunk at every traversal (a genuinely lossier multi-hop error path).

Bandwidth gets a PER-EDGE ledger: an edge's closed-form charge is the
§III-C two-direction count for the payload it carries
(2 * batch * |payload| * d_bottleneck * link_bits), its measured bytes come
from the same `wirefmt.round_wire_bytes` eval_shape accounting the star
uses — and for `star(J)` both sum to the existing Table-I totals exactly
(tests/test_topology.py pins it).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple

ROLES = ("measure", "relay", "fuse")
FUSE = "fuse"                     # canonical name of the fusion-center node


@dataclass(frozen=True)
class Node:
    name: str
    role: str                     # "measure" | "relay" | "fuse"


@dataclass(frozen=True)
class Edge:
    src: str
    dst: str
    link_bits: Optional[int] = None     # None -> cfg.link_bits
    wire: Optional[str] = None          # None -> the round's wire=
    dtype: Optional[str] = None         # None -> cfg compute dtype
    # unreliability model (core/linkfault.LinkModel); None is a PERFECT,
    # unmodelled link.  Attaching any LinkModel — even a perfect default
    # one — routes rounds through the fault-aware scheme paths (delivery
    # masks, partial fusion); it does not change the wire execution, so a
    # star with link models stays on the legacy transport paths.
    link: Optional[object] = None

    @property
    def key(self) -> str:
        return f"{self.src}->{self.dst}"


@dataclass(frozen=True)
class Topology:
    """A validated single-sink routing graph.  Hashable (usable as a jit
    static argument and inside a frozen config)."""
    nodes: Tuple[Node, ...]
    edges: Tuple[Edge, ...]

    def __post_init__(self):
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate node name(s) {dupes} in {names}; "
                             "every node needs a unique name — edge keys "
                             "and the per-view payload map are keyed on it")
        for n in self.nodes:
            if n.role not in ROLES:
                raise ValueError(f"node {n.name!r} has unknown role "
                                 f"{n.role!r}; roles: {ROLES}")
            if not n.name:
                raise ValueError("node names must be non-empty")
        fuse = [n.name for n in self.nodes if n.role == "fuse"]
        if len(fuse) != 1:
            roles = {n.name: n.role for n in self.nodes}
            raise ValueError(f"a topology needs exactly ONE fuse node "
                             f"(the single sink); got "
                             f"{fuse or 'none'} among nodes {roles}")
        known = set(names)
        seen = set()
        out: Dict[str, Edge] = {}
        for e in self.edges:
            if e.src not in known or e.dst not in known:
                missing = sorted({e.src, e.dst} - known)
                raise ValueError(f"edge {e.key} references unknown node(s) "
                                 f"{missing}; declared nodes: "
                                 f"{sorted(known)}")
            if e.src == e.dst:
                raise ValueError(f"self-loop {e.key}")
            if e.key in seen:
                raise ValueError(f"duplicate edge {e.key}")
            seen.add(e.key)
            if e.src in out:
                raise ValueError(
                    f"node {e.src!r} has two outgoing edges ({out[e.src].key}"
                    f", {e.key}); multicast routing duplicates latents and "
                    "has no eq.-(5) reading — every non-fuse node forwards "
                    "along exactly one edge")
            out[e.src] = e
        (fuse_name,) = fuse
        if fuse_name in out:
            raise ValueError(f"the fuse node {fuse_name!r} is the sink; it "
                             f"cannot have an outgoing edge "
                             f"({out[fuse_name].key})")
        indeg = {n.name: 0 for n in self.nodes}
        for e in self.edges:
            indeg[e.dst] += 1
        for n in self.nodes:
            if n.role == "measure" and indeg[n.name]:
                raise ValueError(f"measure node {n.name!r} has incoming "
                                 "edges; sensors are sources — use role="
                                 "'relay' for a fusing forwarder")
            if n.role == "relay" and not indeg[n.name]:
                raise ValueError(f"relay node {n.name!r} receives nothing; "
                                 "use role='measure' for a leaf")
        # single out-edge per node => the graph is a union of paths into the
        # sink iff acyclic; walk each node's unique route and demand it
        # reaches the fuse node without revisiting anything
        for n in self.nodes:
            if n.role == "fuse":
                continue
            cur, hops = n.name, 0
            while cur != fuse_name:
                if cur not in out:
                    raise ValueError(f"node {n.name!r} cannot reach the "
                                     f"fuse node: route dead-ends at "
                                     f"{cur!r}")
                cur = out[cur].dst
                hops += 1
                if hops > len(self.nodes):
                    raise ValueError(f"cycle on the route from {n.name!r} "
                                     "(topologies must be DAGs)")

    # -- structure --------------------------------------------------------

    @property
    def fuse_node(self) -> str:
        return next(n.name for n in self.nodes if n.role == "fuse")

    def view_nodes(self) -> Tuple[str, ...]:
        """View-holding nodes in declaration order: views[j] feeds the j-th
        name here.  Every measure AND relay node observes a view."""
        return tuple(n.name for n in self.nodes if n.role != "fuse")

    def num_views(self) -> int:
        return len(self.view_nodes())

    def out_edge(self, name: str) -> Edge:
        return next(e for e in self.edges if e.src == name)

    def in_edges(self, name: str) -> Tuple[Edge, ...]:
        return tuple(e for e in self.edges if e.dst == name)

    def topo_edges(self) -> Tuple[Edge, ...]:
        """Edges in topological order: an edge appears only after every edge
        into its source (the order hops execute in)."""
        done: set = set()
        ordered = []
        pending = list(self.edges)
        while pending:
            progress = False
            rest = []
            for e in pending:
                if all(i.key in done for i in self.in_edges(e.src)):
                    ordered.append(e)
                    done.add(e.key)
                    progress = True
                else:
                    rest.append(e)
            pending = rest
            if pending and not progress:     # unreachable post-validation
                raise ValueError("cyclic edge set")
        return tuple(ordered)

    def payload(self, edge: Edge) -> Tuple[int, ...]:
        """View indices whose latents `edge` carries: every view node in the
        subtree draining through the edge (the source's own latent last —
        relays append their observation to what they received)."""
        idx = {name: j for j, name in enumerate(self.view_nodes())}
        acc: Tuple[int, ...] = ()
        for e_in in self.in_edges(edge.src):
            acc = acc + self.payload(e_in)
        return acc + (idx[edge.src],)

    def levels(self) -> Tuple[Tuple[str, ...], ...]:
        """Non-fuse nodes grouped by longest hop-distance from a leaf —
        the per-level schedule the hops (and a real multi-host placement)
        execute in."""
        depth: Dict[str, int] = {}
        for e in self.topo_edges():
            ins = [depth[i.src] + 1 for i in self.in_edges(e.src)]
            depth[e.src] = max(ins) if ins else 0
        if not depth:
            return ()
        out = [[] for _ in range(max(depth.values()) + 1)]
        for name in self.view_nodes():
            out[depth[name]].append(name)
        return tuple(tuple(level) for level in out)

    def is_default_star(self) -> bool:
        """True when this topology IS the implicit star the legacy code
        paths assume: every view node a measure node wired straight into
        the fuse node, in declaration order, every edge at the inherited
        (cfg-level) width/wire/dtype.  Those paths stay bit-identical, so
        resolvers dispatch them to the pre-topology code.  LinkModels
        (`Edge.link`) are deliberately NOT considered: they only produce
        delivery masks (core/linkfault.py), so a faulty star still runs
        the legacy transport paths — with partial fusion layered on."""
        fuse = self.fuse_node
        if any(n.role == "relay" for n in self.nodes):
            return False
        views = self.view_nodes()
        if len(self.edges) != len(views):
            return False
        for name, e in zip(views, self.edges):
            if (e.src, e.dst) != (name, fuse):
                return False
            if (e.link_bits, e.wire, e.dtype) != (None, None, None):
                return False
        return True

    def describe(self) -> str:
        levels = " | ".join(",".join(lv) for lv in self.levels())
        return (f"Topology({self.num_views()} views -> {self.fuse_node}; "
                f"levels {levels}; edges "
                f"{[e.key for e in self.topo_edges()]})")


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------

def _per_edge_bits(link_bits, n: int):
    if link_bits is None or isinstance(link_bits, int):
        return (link_bits,) * n
    bits = tuple(link_bits)
    if len(bits) != n:
        raise ValueError(f"need one link_bits per edge ({n}), got {bits}")
    return bits


def star(J: int, *, link_bits=None) -> Topology:
    """The paper's setting: J measure nodes, each one hop from the fusion
    center.  `link_bits` — scalar or per-edge sequence; None inherits
    cfg.link_bits (and keeps the topology on the legacy fast path)."""
    if J < 1:
        raise ValueError(f"star needs J >= 1, got {J}")
    bits = _per_edge_bits(link_bits, J)
    nodes = tuple(Node(f"m{j}", "measure") for j in range(J)) \
        + (Node(FUSE, "fuse"),)
    edges = tuple(Edge(f"m{j}", FUSE, link_bits=bits[j]) for j in range(J))
    return Topology(nodes, edges)


def chain(J: int, *, link_bits=None) -> Topology:
    """A line: m0 -> r1 -> ... -> r{J-1} -> fuse.  Every hop aggregates the
    upstream latents with the local view, so the last link carries all J —
    the bandwidth-extreme opposite of the star."""
    if J < 1:
        raise ValueError(f"chain needs J >= 1, got {J}")
    bits = _per_edge_bits(link_bits, J)
    nodes = (Node("m0", "measure"),) \
        + tuple(Node(f"r{j}", "relay") for j in range(1, J)) \
        + (Node(FUSE, "fuse"),)
    names = [n.name for n in nodes[:-1]] + [FUSE]
    edges = tuple(Edge(names[j], names[j + 1], link_bits=bits[j])
                  for j in range(J))
    return Topology(nodes, edges)


def tree(branching: int, depth: int, *, link_bits=None) -> Topology:
    """A complete `branching`-ary in-tree of view nodes under the fusion
    center: `depth` levels, measure leaves at the bottom, relays above.
    num_views == branching + branching^2 + ... + branching^depth
    (e.g. tree(2, 2) -> 6 views).  `link_bits` — scalar applied to every
    edge, or None to inherit."""
    if branching < 1 or depth < 1:
        raise ValueError(f"tree needs branching >= 1 and depth >= 1, got "
                         f"({branching}, {depth})")
    nodes, edges = [], []

    def grow(parent: str, level: int):
        for i in range(branching):
            name = f"{parent}.{i}" if parent != FUSE else f"t{i}"
            role = "measure" if level == depth else "relay"
            nodes.append(Node(name, role))
            edges.append(Edge(name, parent, link_bits=link_bits))
            if level < depth:
                grow(name, level + 1)

    grow(FUSE, 1)
    nodes.append(Node(FUSE, "fuse"))
    return Topology(tuple(nodes), tuple(edges))


# ---------------------------------------------------------------------------
# Search-facing enumeration (repro/search): named constructor instances
# ---------------------------------------------------------------------------

_NAME_RE = re.compile(r"^(star|chain|tree)\((\d+)(?:,\s*(\d+))?\)$")


def from_name(name: str) -> Topology:
    """Parse a constructor spec — "star(5)", "chain(4)", "tree(2,2)" — into
    the Topology it names.  The inverse of the names `named_topologies`
    emits; the search subsystem's config points carry these strings so a
    whole search space stays hashable/JSON-able."""
    m = _NAME_RE.match(name.replace(" ", ""))
    if not m:
        raise ValueError(f"unparseable topology spec {name!r}; expected "
                         f"star(J), chain(J) or tree(branching,depth)")
    kind, a, b = m.group(1), int(m.group(2)), m.group(3)
    if kind == "tree":
        if b is None:
            raise ValueError(f"tree spec needs two arguments, got {name!r}")
        return tree(a, int(b))
    if b is not None:
        raise ValueError(f"{kind} spec takes one argument, got {name!r}")
    return star(a) if kind == "star" else chain(a)


def named_topologies(J: int, *, families=("star", "chain", "tree")):
    """Every named constructor instance with exactly J view nodes, keyed by
    its `from_name` spec: "star(J)", "chain(J)" (J >= 2 — chain(1) IS
    star(1)), and every complete "tree(b,d)" whose level sum b + b^2 + ...
    + b^d == J with d >= 2 (depth-1 trees are stars, branching-1 trees are
    chains — the degenerate spellings collapse into the canonical family,
    so the search space never trains one graph twice)."""
    out = {}
    if "star" in families:
        out[f"star({J})"] = star(J)
    if "chain" in families and J >= 2:
        out[f"chain({J})"] = chain(J)
    if "tree" in families:
        for b in range(2, J):
            views, d = 0, 0
            while views < J:
                d += 1
                views += b ** d
            if views == J and d >= 2:
                out[f"tree({b},{d})"] = tree(b, d)
    return out


# ---------------------------------------------------------------------------
# Resolution against a config
# ---------------------------------------------------------------------------

def resolve(topology: Optional[Topology], cfg) -> Topology:
    """The topology a round runs: the explicit argument, else cfg.topology,
    else the implicit `star(cfg.num_clients)`.  Validates the view count
    against cfg."""
    topo = topology if topology is not None \
        else getattr(cfg, "topology", None)
    if topo is None:
        return star(cfg.num_clients)
    if topo.num_views() != cfg.num_clients:
        raise ValueError(
            f"topology has {topo.num_views()} view nodes "
            f"{list(topo.view_nodes())} but cfg.num_clients == "
            f"{cfg.num_clients}; every measure/relay node observes one of "
            "the J views")
    return topo


def nontrivial(topology: Optional[Topology], cfg) -> Optional[Topology]:
    """`resolve`, then None when the result is the default star — callers
    dispatch None to the pre-topology code paths, which stay bit-identical
    (golden trajectories included)."""
    topo = resolve(topology, cfg)
    return None if topo.is_default_star() else topo


def require_star(topology: Optional[Topology], cfg, *, scheme: str):
    """Schemes whose exchange has no multi-hop reading (FL's weight
    transfer, SL's single client->server boundary) accept `topology=` for
    interface parity but only run the star."""
    topo = nontrivial(topology, cfg)
    if topo is not None:
        relays = [n.name for n in topo.nodes if n.role == "relay"]
        custom = [e.key for e in topo.edges
                  if (e.link_bits, e.wire, e.dtype) != (None, None, None)]
        detail = []
        if relays:
            detail.append(f"relay node(s) {relays}")
        if custom:
            detail.append(f"per-edge transport override(s) on {custom}")
        if not detail:
            detail.append(f"non-star edge(s) "
                          f"{[e.key for e in topo.edges]}")
        raise ValueError(
            f"scheme {scheme!r} runs the star topology only (its exchange "
            f"is a single client<->server transaction) but the given "
            f"topology has {'; '.join(detail)}; multi-hop graphs are an "
            "INL execution concept")


def edge_bits(edge: Edge, cfg) -> int:
    return cfg.link_bits if edge.link_bits is None else edge.link_bits


def edge_wire(edge: Edge, default: str) -> str:
    return default if edge.wire is None else edge.wire


def edge_dtype(edge: Edge, cfg):
    from repro.core import paper_model
    if edge.dtype is None:
        return paper_model.compute_dtype(cfg)
    try:
        return paper_model.COMPUTE_DTYPES[edge.dtype]
    except KeyError:
        raise ValueError(f"edge {edge.key} has unknown dtype {edge.dtype!r};"
                         f" known: {sorted(paper_model.COMPUTE_DTYPES)}"
                         ) from None


# ---------------------------------------------------------------------------
# Per-edge bandwidth: closed forms and measured bytes
# ---------------------------------------------------------------------------

def round_edge_bits(topo: Topology, cfg, batch_size: int) -> Dict[str, float]:
    """Closed-form §III-C charge of ONE training round, per edge: the
    forward activations and backward error vectors for every latent the
    edge carries — 2 * batch * |payload| * d_bottleneck * link_bits.

    For `star(J)` at inherited widths the J single-latent edges sum to
    exactly `bandwidth.inl_epoch_bits(J*d_b, batch*J, J, cfg.link_bits)`,
    the existing Table-I total."""
    return {e.key: float(2 * batch_size * len(topo.payload(e))
                         * cfg.d_bottleneck * edge_bits(e, cfg))
            for e in topo.topo_edges()}


def round_edge_wire_bytes(topo: Topology, cfg, batch_size: int, *,
                          wire: str = "dense") -> Dict[str, float]:
    """MEASURED bytes of one round, per edge: what the edge's wire encoding
    actually occupies for its payload (core/wirefmt.round_wire_bytes over
    the real pack/ship ops), both directions."""
    from repro.core import wirefmt
    out = {}
    for e in topo.topo_edges():
        n_vec = batch_size * len(topo.payload(e))
        out[e.key] = float(wirefmt.round_wire_bytes(
            n_vec, cfg.d_bottleneck, link_bits=edge_bits(e, cfg),
            wire=edge_wire(e, wire), dtype=edge_dtype(e, cfg))["total"])
    return out


def round_bits(topo: Topology, cfg, batch_size: int) -> float:
    return float(sum(round_edge_bits(topo, cfg, batch_size).values()))


def round_wire_bytes(topo: Topology, cfg, batch_size: int, *,
                     wire: str = "dense") -> float:
    return float(sum(round_edge_wire_bytes(topo, cfg, batch_size,
                                           wire=wire).values()))


# ---------------------------------------------------------------------------
# Graph execution: the compiled sequence of cut + hop launches
# ---------------------------------------------------------------------------

def first_hop_groups(topo: Topology, cfg):
    """View nodes grouped by their outgoing edge's link width — each group
    is ONE fused `ops.cutlayer` launch.  Returns (groups, gid_of_view):
    groups is a tuple of (gid, link_bits); gid_of_view a tuple assigning
    every view index its group.  Edge-homogeneous graphs (the default) have
    a single group — the star's one-launch hot path, unchanged."""
    by_bits: Dict[int, int] = {}
    gid_of_view = []
    for name in topo.view_nodes():
        b = edge_bits(topo.out_edge(name), cfg)
        gid_of_view.append(by_bits.setdefault(b, len(by_bits)))
    groups = tuple((gid, b) for b, gid in sorted(by_bits.items(),
                                                 key=lambda kv: kv[1]))
    return groups, tuple(gid_of_view)


def graph_cut_and_ship(topo: Topology, cfg, mu, logvar, eps, *,
                       rate_estimator: str = "sample", wire: str = "dense",
                       prior: dict = None, backend: str = "auto",
                       axis_name=None, group_ids=None):
    """Compile-and-run the inference graph on stacked latents.

    mu/logvar/eps: (J, B, d) per-view-node encoder outputs (J_local rows
    inside a shard_map body).  Returns (u, rate, u_fused):

      u        (J, B, d)  each node's OWN cut-layer output (first-hop
                          width) — branch heads and the rate read this;
      rate     (J, B)     the eq.-(6) rate term per node;
      u_fused  (J, B, d)  the latents as the fuse node RECEIVES them after
                          every hop's re-coding, in view-node order —
                          eq. (5) concatenates them (all J rows when
                          `axis_name` gathers over a 'client' mesh axis).

    Stage 1 runs one fused cutlayer per first-hop width group (ONE launch
    for edge-homogeneous graphs).  Heterogeneous groups run per group: on
    the single-device path (group_ids=None) each launch takes exactly its
    group's row slice (static indices — no wasted compute); inside
    shard_map pass the (J_local,) `group_ids` slice and every launch runs
    the full local block with a per-node mask select, which is
    SPMD-uniform across shards.  Stage 2
    gathers over `axis_name` when given (the fan-in collective) and then
    applies every edge in topological order via `wirefmt.relay_hop`:
    straight-through re-quantization at the edge's width + the edge's wire
    encoding, to exactly the payload rows the edge carries.  Backward, AD
    reverses the edge sequence — each node's error chunk traverses its
    route's hops transposed (duplex edges quantize it per hop).

    On a mesh the hops run replicated on the post-gather buffer: the
    VALUES are exactly the modeled multi-hop network's, while the physical
    collective stays one all_gather (per-edge point-to-point placement is
    the multi-host follow-up; the per-edge meter charges the modeled
    links, same convention as the duplex backward in core/wirefmt.py)."""
    import jax
    import jax.numpy as jnp

    from repro.core import wirefmt
    from repro.kernels import ops

    prior = prior or {}
    groups, gid_of_view = first_hop_groups(topo, cfg)
    pmu, plv = prior.get("mu"), prior.get("logvar")
    if len(groups) == 1:
        u, rate = ops.cutlayer(mu, logvar, eps, link_bits=groups[0][1],
                               rate_estimator=rate_estimator, prior_mu=pmu,
                               prior_logvar=plv, backend=backend)
    elif group_ids is None:
        # single-device: group membership is static — each launch takes
        # exactly its rows (no masked recompute of the full block)
        u = jnp.zeros(mu.shape, mu.dtype)
        rate = jnp.zeros(mu.shape[:-1], jnp.float32)
        for gid, bits in groups:
            idx = jnp.asarray([j for j, g in enumerate(gid_of_view)
                               if g == gid], jnp.int32)
            ug, rg = ops.cutlayer(
                mu[idx], logvar[idx], eps[idx], link_bits=bits,
                rate_estimator=rate_estimator,
                prior_mu=None if pmu is None else pmu[idx],
                prior_logvar=None if plv is None else plv[idx],
                backend=backend)
            u = u.at[idx].set(ug)
            rate = rate.at[idx].set(rg)
    else:
        # shard_map: the same program must run on every shard, so every
        # launch covers the full local block and the per-node mask selects
        u = rate = None
        for gid, bits in groups:
            ug, rg = ops.cutlayer(mu, logvar, eps, link_bits=bits,
                                  rate_estimator=rate_estimator,
                                  prior_mu=pmu, prior_logvar=plv,
                                  backend=backend)
            sel = group_ids == gid
            u = ug if u is None else jnp.where(sel[:, None, None], ug, u)
            rate = rg if rate is None else jnp.where(sel[:, None], rg, rate)

    u_fused = jax.lax.all_gather(u, axis_name, axis=0, tiled=True) \
        if axis_name else u
    for e in topo.topo_edges():
        ids = jnp.asarray(topo.payload(e), jnp.int32)
        hopped = wirefmt.relay_hop(
            u_fused[ids], link_bits=edge_bits(e, cfg),
            wire=edge_wire(e, wire), dtype=edge_dtype(e, cfg),
            backend=backend)
        u_fused = u_fused.at[ids].set(hopped)
    return u, rate, u_fused
