"""Finite-capacity link simulation (the wireless substrate, per repro band).

Each edge node j talks to node (J+1) over an error-free link of capacity C_j
(§II, eq. 1: phi_j maps into [1 : 2^C_j]).  On TPU the "link" is ICI; here we
simulate the capacity constraint with a uniform scalar quantizer over the
bottleneck activations (straight-through gradients) and count exact bits.

This module doubles as the beyond-paper ICI-compression knob: quantizing the
latents that cross the 'client' axis boundary reduces collective bytes on a
real pod by 32/link_bits (fp32) or 16/link_bits (bf16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _kref

QUANT_RANGE = _kref.QUANT_RANGE       # single source of truth with kernels


def quantize_st(u, bits: int, *, u_range: float = QUANT_RANGE):
    """Uniform quantizer with straight-through estimator.

    bits >= 32 is treated as 'no quantization' (full-precision link).
    Latents are clipped to [-u_range, u_range] (Gaussian bottlenecks are
    near-standard-normal, so 4 sigma covers them).  The value map is
    kernels/ref.quantize_value — the same math the fused cut-layer kernel
    (kernels/inl_bottleneck.py) bakes in, so the standalone quantizer and
    the megakernel cannot drift apart.
    """
    if bits >= 32:
        return u
    q = _kref.quantize_value(u, bits, u_range=u_range)
    return u + jax.lax.stop_gradient(q - u)


def transmit(key, mu, logvar, *, bits: int, rate_estimator: str = "sample",
             backend: str = "auto", block_t: int = None):
    """Fused node->(J+1) transmission: everything the edge sends, one pass.

    Draws eps, then a single cut-layer kernel launch produces the quantized
    latent u AND the per-row rate term of eq. (6); the backward is the
    paper's eq.-(10) error-vector + rate-gradient split.  mu/logvar:
    (..., d) with all leading axes (J clients, batch, ...) folded into the
    kernel's row grid.  Returns (u, rate)."""
    from repro.core import bottleneck
    return bottleneck.fused_sample_rate(key, mu, logvar, link_bits=bits,
                                        rate_estimator=rate_estimator,
                                        backend=backend, block_t=block_t)


_WIRE_RANGE = 4.0                 # Gaussian bottlenecks: 4 sigma coverage
_WIRE_SCALE = _WIRE_RANGE / 127.0


def _to_int8(u):
    return jnp.clip(jnp.round(u.astype(jnp.float32) / _WIRE_SCALE),
                    -127, 127).astype(jnp.int8)


def _from_int8(q, dtype):
    return (q.astype(jnp.float32) * _WIRE_SCALE).astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def wire_concat(u, gathered_spec=None, client_spec=None):
    """The INL node->(J+1) boundary as a REAL int8 wire.

    u: (J, B, S, d_b) float, sharded over the 'client' mesh axis.  The
    forward quantizes to int8 BEFORE the axis-merging reshape, and
    `gathered_spec` (a PartitionSpec replicating the client axis) pins the
    all-gather to the INT8 tensor — without the constraint GSPMD prefers to
    contract locally and all-reduce bf16 outputs instead, bypassing the wire
    (observed; EXPERIMENTS.md §Perf).  Dequantization is local, after the
    gather.

    The backward is exactly the paper's eq.-(8c) error-vector split: the
    decoder-input cotangent is cut into J chunks and returned to each node
    (straight-through through the quantizer), itself int8-quantized with a
    dynamic scale so the backward link is compressed too (`client_spec`
    pins that scatter to int8 likewise).
    """
    J, B, S, db = u.shape
    if client_spec is not None:
        # pin u to the client layout, quantize LOCALLY, barrier so the
        # downstream replicated constraint cannot propagate back through the
        # elementwise quantize chain (GSPMD otherwise gathers the f32 input
        # and quantizes redundantly — observed), then pin the gather to the
        # INT8 tensor before the axis-merging reshape.
        u = jax.lax.with_sharding_constraint(u, client_spec)
    q = _to_int8(u)
    if gathered_spec is not None:
        q = jax.lax.optimization_barrier(q)
        q = jax.lax.with_sharding_constraint(q, gathered_spec)
    cat = jnp.moveaxis(q, 0, 2).reshape(B, S, J * db)
    return _from_int8(cat, u.dtype)


def _wire_fwd(u, gathered_spec, client_spec):
    J = u.shape[0]
    marker = jnp.zeros((J, 0), u.dtype)       # carries J + dtype, no data
    return wire_concat(u, gathered_spec, client_spec), marker


def _wire_bwd(gathered_spec, client_spec, res, g):
    marker = res
    J, dtype = marker.shape[0], marker.dtype
    B, S, jdb = g.shape
    db = jdb // J
    gmax = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12)
    scale = gmax / 127.0
    g8 = jnp.clip(jnp.round(g.astype(jnp.float32) / scale),
                  -127, 127).astype(jnp.int8)
    du8 = jnp.moveaxis(g8.reshape(B, S, J, db), 2, 0)   # the backward link
    if client_spec is not None:
        du8 = jax.lax.with_sharding_constraint(du8, client_spec)
    du = du8.astype(jnp.float32) * scale
    return (du.astype(dtype),)


wire_concat.defvjp(_wire_fwd, _wire_bwd)


def float_concat(u):
    """Uncompressed boundary (link_bits >= 16): plain eq.-(5) concat."""
    J, B, S, db = u.shape
    return jnp.moveaxis(u, 0, 2).reshape(B, S, J * db)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def packed_wire_concat(u, bits, gathered_spec=None, client_spec=None):
    """The INL node->(J+1) boundary as a SUB-BYTE packed wire.

    The int8 wire (`wire_concat`) bottoms out at 8 bits per value; for
    link_bits < 8 this variant quantizes the latents onto the shared
    `bits`-level grid (kernels/ref.quantize_value semantics) and moves them
    as bit-packed uint32 codeword lanes (kernels/inl_bottleneck.pack_values
    — 32/bits values per lane), dequantizing locally after the gather.  The
    same GSPMD pinning discipline as wire_concat applies: quantize+pack
    locally under `client_spec`, barrier, then constrain the PACKED tensor
    to `gathered_spec` so the collective moves lanes, not floats
    (launch/sharding.wire_specs builds both specs).

    Backward: the eq.-(8c) error-vector split with the chunks quantized at
    the same `bits` on a dynamic per-tensor scale (the packed counterpart
    of the int8 backward link)."""
    from repro.kernels import inl_bottleneck as _bn
    J, B, S, db = u.shape
    if client_spec is not None:
        u = jax.lax.with_sharding_constraint(u, client_spec)
    packed = _bn.pack_values(u, link_bits=bits)          # (J, B, S, W)
    if gathered_spec is not None:
        packed = jax.lax.optimization_barrier(packed)
        packed = jax.lax.with_sharding_constraint(packed, gathered_spec)
    vals = _bn.unpack_dequant(packed, db, link_bits=bits, dtype=u.dtype)
    return jnp.moveaxis(vals, 0, 2).reshape(B, S, J * db)


def _packed_wire_fwd(u, bits, gathered_spec, client_spec):
    J = u.shape[0]
    marker = jnp.zeros((J, 0), u.dtype)       # carries J + dtype, no data
    return packed_wire_concat(u, bits, gathered_spec, client_spec), marker


def _packed_wire_bwd(bits, gathered_spec, client_spec, res, g):
    from repro.core import wirefmt
    marker = res
    J, dtype = marker.shape[0], marker.dtype
    B, S, jdb = g.shape
    db = jdb // J
    gq = wirefmt.dyn_quantize(g.astype(jnp.float32), bits, axis=None)
    du = jnp.moveaxis(gq.reshape(B, S, J, db), 2, 0)    # the backward link
    if client_spec is not None:
        du = jax.lax.with_sharding_constraint(du, client_spec)
    return (du.astype(dtype),)


packed_wire_concat.defvjp(_packed_wire_fwd, _packed_wire_bwd)


def activation_bits(batch: int, width: int, bits: int) -> int:
    """Bits to move `width` activation values per sample across a link."""
    return batch * width * bits


def training_step_bits(batch: int, p_total: int, bits: int) -> int:
    """Paper §III-C: forward activations + backward error vectors = 2 b p s."""
    return 2 * batch * p_total * bits


def inference_step_bits(batch: int, p_total: int, bits: int) -> int:
    """Inference sends the forward activations only."""
    return batch * p_total * bits
