"""Stochastic bottlenecks for in-network learning.

Each edge node j parametrises P_theta_j(u_j | x_j) as a diagonal Gaussian
(regression/continuous latents; the paper's choice via the reparametrization
trick of Kingma & Welling) whose (mu, log sigma^2) come from the node's NN.
The prior Q_psi_j(u_j) is a standard normal by default or a learned diagonal
Gaussian marginal.

The rate term of eq. (6), log(P(u|x)/Q(u)), is provided both as the paper's
per-sample ESTIMATE (evaluated at the sampled u) and as the ANALYTIC KL
between the two Gaussians — the estimator the paper trains with is the
sampled one; both are tested against each other in expectation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers

LOG2PI = float(np.log(2.0 * np.pi))


def head_init(key, d_in: int, d_bottleneck: int, dtype=jnp.float32):
    """Projection from encoder features to (mu, logvar)."""
    ks = jax.random.split(key, 2)
    return {"mu": layers.dense_init(ks[0], d_in, d_bottleneck, bias=True,
                                    dtype=dtype),
            "logvar": layers.dense_init(ks[1], d_in, d_bottleneck, bias=True,
                                        dtype=dtype, scale=1e-2)}


def head_apply(p, h) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mu = layers.dense(p["mu"], h)
    logvar = jnp.clip(layers.dense(p["logvar"], h), -8.0, 8.0)
    return mu, logvar


def sample(key, mu, logvar):
    """Reparametrised draw u = mu + sigma * eps.

    Computed in fp32, returned in mu.dtype — bf16 inputs must not silently
    upcast the latent (the kernels' dtype-preservation contract,
    tests/test_cutlayer_vjp.py)."""
    eps = jax.random.normal(key, mu.shape, jnp.float32)
    u = mu.astype(jnp.float32) \
        + jnp.exp(0.5 * logvar.astype(jnp.float32)) * eps
    return u.astype(mu.dtype)


def fused_sample_rate(key, mu, logvar, *, link_bits: int = 32,
                      rate_estimator: str = "sample", prior: dict = None,
                      backend: str = "auto", block_t: int = None):
    """The cut-layer hot path in ONE fused kernel pass: draws eps and
    returns

        u    = quantize_st(mu + exp(logvar/2) * eps)   (..., d)
        rate = eq.-(6) rate term per row                (...,)  fp32

    with mu/logvar read from HBM once (kernels/inl_bottleneck.py via
    kernels/ops.py dispatch).  The backward pass is the hand-written
    eq.-(10) split, not AD through three unfused ops.  Leading axes —
    including the J client axis — fold into the kernel row grid, so all
    nodes share one launch.

    key=None runs the DETERMINISTIC cut (eps == 0 -> u == quantize(mu)):
    split learning's non-stochastic activation exchange and the inference
    path, still through the same kernel.  Pair it with
    rate_estimator="none" to skip the rate entirely.

    prior — a {"mu", "logvar"} dict of (d,) shared or (J, d) per-node
    learned-Gaussian-prior params — switches the eq.-(6) rate to Q_psi and
    stays on the fused path (the kernel also emits the prior gradients);
    there is no fallback to the unfused 3-pass estimator any more."""
    from repro.kernels import ops
    if key is None:
        eps = jnp.zeros(mu.shape, jnp.float32)
    else:
        eps = jax.random.normal(key, mu.shape, jnp.float32)
    prior = prior or {}
    return ops.cutlayer(mu, logvar, eps, link_bits=link_bits,
                        rate_estimator=rate_estimator,
                        prior_mu=prior.get("mu"),
                        prior_logvar=prior.get("logvar"),
                        backend=backend, block_t=block_t)


def gaussian_logpdf(u, mu, logvar):
    lv = logvar.astype(jnp.float32)
    d = (u - mu).astype(jnp.float32)
    return -0.5 * jnp.sum(lv + LOG2PI + d * d * jnp.exp(-lv), axis=-1)


def prior_init(d_bottleneck: int, learned: bool = False,
               num_nodes: int = None):
    """Learned diagonal-Gaussian prior params; {} = standard normal.

    num_nodes=J stacks one independent prior per node ((J, d) leaves) —
    the shape the fused cut-layer kernel's per-node prior grid expects."""
    if not learned:
        return {}
    shape = (d_bottleneck,) if num_nodes is None \
        else (num_nodes, d_bottleneck)
    return {"mu": jnp.zeros(shape, jnp.float32),
            "logvar": jnp.zeros(shape, jnp.float32)}


def prior_logpdf(prior, u):
    if prior:
        return gaussian_logpdf(u, prior["mu"], prior["logvar"])
    uf = u.astype(jnp.float32)
    return -0.5 * jnp.sum(uf * uf + LOG2PI, axis=-1)


def rate_sampled(u, mu, logvar, prior=None):
    """The paper's per-sample rate term log(P(u|x) / Q(u)), eq. (6)."""
    return gaussian_logpdf(u, mu, logvar) - prior_logpdf(prior or {}, u)


def rate_analytic(mu, logvar, prior=None):
    """KL( N(mu, sigma^2) || prior ) in closed form (variance-reduced)."""
    lv = logvar.astype(jnp.float32)
    muf = mu.astype(jnp.float32)
    if prior:
        plv = prior["logvar"]
        pmu = prior["mu"]
        return 0.5 * jnp.sum(plv - lv + (jnp.exp(lv) + (muf - pmu) ** 2)
                             / jnp.exp(plv) - 1.0, axis=-1)
    return 0.5 * jnp.sum(jnp.exp(lv) + muf * muf - 1.0 - lv, axis=-1)
