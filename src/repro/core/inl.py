"""In-network learning (INL) — the paper's architecture (§III).

J edge nodes encode their local views into stochastic bottleneck latents u_j;
node (J+1) concatenates them (eq. 5) and decodes.  Training optimises eq. (6)
end-to-end: JAX AD through the concatenation reproduces exactly the paper's
error-vector split (eq. 8c / Remark 2) — node j receives only its chunk
delta[j] of the decoder-input cotangent, plus the local gradient of its own
rate term (eq. 10).  tests/test_inl_grads.py verifies the hand-derived split
against AD.

Encoder parameters are STACKED along a leading J axis so the whole system
shards over a 'client' mesh axis (each client's encoder params + data live on
its own devices; only u_j / delta_j cross the boundary — the paper's
bandwidth story).  A heterogeneous (list-of-different-encoders) path is also
provided, since the paper allows per-node architectures to differ.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import (bottleneck, linkfault, linkmodel, losses,
                        paper_model, wirefmt)
from repro.core import topology as topology_lib


class INLParams(NamedTuple):
    encoders: dict          # stacked: leading axis J
    decoder: dict
    priors: dict            # {} when standard-normal


def init(cfg, key):
    """cfg: PaperExperimentConfig.  Returns (INLParams, state).

    cfg.learned_prior=True adds per-node trainable Gaussian-prior params
    ((J, d) mean/logvar, init at the standard normal); the rate term then
    runs the fused kernel's learned-prior path — same one-pass-per-direction
    substrate, no unfused fallback."""
    J = cfg.num_clients
    ks = jax.random.split(key, 3)
    enc_keys = jax.random.split(ks[0], J)
    stacked = jax.vmap(lambda k: paper_model.encoder_init(k, cfg))(enc_keys)
    enc_params, enc_state = stacked
    dec = paper_model.decoder_init(ks[1], cfg)
    priors = bottleneck.prior_init(
        cfg.d_bottleneck, learned=getattr(cfg, "learned_prior", False),
        num_nodes=J)
    return (INLParams(enc_params, dec, priors), {"encoders": enc_state})


def _encode_mu_logvar(params: INLParams, state, views, *, train: bool):
    """All J per-node encoders under one vmap: views (J,B,H,W,C) ->
    ((mu, logvar) (J,B,d), new encoder state).  The single definition the
    stochastic, deterministic and wire-aware paths all share."""
    return jax.vmap(
        lambda p, s, v: paper_model.encoder_apply(p, s, v, train=train)
    )(params.encoders, state["encoders"], views)


def encode_and_rate(params: INLParams, state, views, *, train: bool, rng,
                    link_bits: int = 32, rate_estimator: str = "sample",
                    backend: str = "auto"):
    """The fused edge hot path: views (J,B,H,W,C) ->
    (u (J,B,d), mu, logvar, rate (J,B), new_state).

    After the per-node encoders produce (mu, logvar), ONE cut-layer kernel
    launch (client axis folded into the row grid, kernels/ops.cutlayer)
    yields both the quantized transmission u and the per-sample rate term
    of eq. (6); the backward pass is the paper's eq.-(10) error-vector +
    rate-gradient split.  Learned priors (params.priors non-empty) ride the
    same launch via the kernel's per-node prior grid."""
    (mu, logvar), new_state = _encode_mu_logvar(params, state, views,
                                                train=train)
    u, rate = bottleneck.fused_sample_rate(
        rng, mu, logvar, link_bits=link_bits, rate_estimator=rate_estimator,
        prior=params.priors, backend=backend)
    return u, mu, logvar, rate, {"encoders": new_state}


def encode(params: INLParams, state, views, *, train: bool, rng=None,
           link_bits: int = 32, sample_latent: bool = True,
           backend: str = "auto"):
    """views: (J,B,H,W,C) -> (u (J,B,d), mu, logvar, new_state).

    This is everything that runs AT THE EDGE.  u is what crosses the links
    (quantized to link_bits).  Both paths run the fused cut-layer kernel:
    sampling draws eps, the deterministic path (inference, u = quantize(mu))
    is the kernel's no-noise "none" mode — one measured substrate for every
    scheme."""
    if sample_latent and rng is not None:
        u, mu, logvar, _, new_state = encode_and_rate(
            params, state, views, train=train, rng=rng, link_bits=link_bits,
            backend=backend)
        return u, mu, logvar, new_state
    (mu, logvar), new_state = _encode_mu_logvar(params, state, views,
                                                train=train)
    u_sent, _ = bottleneck.fused_sample_rate(
        None, mu, logvar, link_bits=link_bits, rate_estimator="none",
        backend=backend)
    return u_sent, mu, logvar, {"encoders": new_state}


def decode(params: INLParams, u, *, train: bool, rng=None, u_joint=None):
    """Node (J+1): u (J,B,d) -> (joint_logits, branch_logits (J,B,C)).

    u_joint — the latents as RECEIVED over the wire (wirefmt.cut_and_ship's
    third output; defaults to u).  The fusion decoder reads the received
    buffer, the per-branch heads the same values — with a packed wire both
    are bit-identical to the dense path, but the joint-decoder cotangent
    flows back through the wire's straight-through VJP (where
    "packed_duplex" compresses the backward link too)."""
    if u_joint is None:
        u_joint = u
    J, B, d = u_joint.shape
    u_cat = jnp.moveaxis(u_joint, 0, 1).reshape(B, J * d)  # eq. (5) concat
    joint = paper_model.decoder_apply(params.decoder, u_cat, train=train,
                                      rng=rng)
    branch = paper_model.branch_heads_apply(params.decoder, u)
    return joint, branch


def loss_fn(params: INLParams, state, views, labels, rng, cfg, *,
            train: bool = True, rate_estimator: str = "sample",
            backend: str = "auto", wire: str = "dense", topology=None,
            delivery=None):
    """Full eq.-(6) loss.  Returns (loss, (metrics, new_state)).

    The encode side runs the fused cut-layer megakernel, which also emits
    the per-sample rate — losses.inl_loss consumes it instead of
    recomputing the rate from (u, mu, logvar).

    wire selects the u_j -> node-(J+1) format (core/wirefmt.py): "dense"
    is the pre-existing graph; "packed"/"packed_duplex" route the latents
    through bit-packed codewords (here as an on-device round trip — the
    sharded rounds run the same format over the real 'client' collective).
    cfg.compute_dtype="bf16" applies the mixed-precision policy: params
    and views drop to bf16 INSIDE this function, so gradients and the
    optimizer's master params stay fp32.

    topology — a core/topology.Topology (defaults to cfg.topology, then
    the implicit star): non-star graphs cut each node at its first hop's
    width and route the latents through the edges' re-encoding hops in
    topological order before the eq.-(5) concatenation at the fuse node
    (graph_cut_and_ship); the default star keeps this function's
    pre-topology graph bit for bit.

    Unreliable links (core/linkfault.py): when any edge carries a
    LinkModel, cfg.edge_dropout > 0, or cfg.fusion_deadline_ms is set,
    a deterministic per-(round, edge) delivery mask drops the views whose
    route failed this round and the fusion center fuses what arrived
    (mask + renormalise, `linkfault.partial_fuse`) — eq.-(10) error
    chunks then flow back only over the surviving reverse edges.  Branch
    heads and rate terms stay local and unmasked: a cut-off node keeps
    training its own head.

    delivery — an EXPLICIT (J,) or (J, B) delivery mask that overrides the
    in-graph fault draw entirely: the transport layer
    (repro/transport/NetworkTransport) measures which views actually
    arrived this round — after retries, circuit breakers and chaos — and
    feeds the outcome in as data.  None keeps the legacy in-graph draws
    (or the perfect network) bit for bit."""
    topo_full = topology_lib.resolve(topology, cfg)
    faulty = delivery is None and linkfault.active(topo_full, cfg,
                                                   train=train)
    topo = topology_lib.nontrivial(topology, cfg)
    dt = paper_model.compute_dtype(cfg)
    params_c = paper_model.cast_compute(params, dt)
    views = views.astype(dt)
    r_enc, r_dec = jax.random.split(rng)
    (mu, logvar), new_enc = _encode_mu_logvar(params_c, state, views,
                                              train=train)
    if topo is None:
        u, rate, u_joint = wirefmt.cut_and_ship(
            r_enc, mu, logvar, link_bits=cfg.link_bits,
            rate_estimator=rate_estimator, wire=wire, prior=params_c.priors,
            backend=backend)
    else:
        eps = jax.random.normal(r_enc, mu.shape, jnp.float32)
        u, rate, u_joint = topology_lib.graph_cut_and_ship(
            topo, cfg, mu, logvar, eps, rate_estimator=rate_estimator,
            wire=wire, prior=params_c.priors, backend=backend)
    if delivery is not None:
        u_joint = linkfault.partial_fuse(u_joint, delivery)
    elif faulty:
        mask = linkfault.round_delivery_mask(rng, topo_full, cfg,
                                             labels.shape[0], train=train)
        u_joint = linkfault.partial_fuse(u_joint, mask)
    new_state = {"encoders": new_enc}
    joint, branch = decode(params_c, u, train=train, rng=r_dec,
                           u_joint=u_joint)
    J = u.shape[0]
    loss, metrics = losses.inl_loss(
        joint, list(branch), labels,
        list(mu), list(logvar), list(u),
        s=cfg.s, rate_estimator=rate_estimator, rates=list(rate))
    metrics["accuracy"] = losses.accuracy(joint, labels)
    # §III-C accounting: activations forward + error vectors backward
    # (per-edge payloads summed when a topology re-routes them)
    if topo is None:
        p_total = J * cfg.d_bottleneck
        bits_sent = linkmodel.training_step_bits(labels.shape[0], p_total,
                                                 cfg.link_bits)
    else:
        bits_sent = topology_lib.round_bits(topo, cfg, labels.shape[0])
    metrics["bits_sent"] = jnp.asarray(bits_sent, jnp.float32)
    return loss, (metrics, new_state)


def make_train_step(cfg, optimizer, *, rate_estimator: str = "sample",
                    wire: str = "dense", topology=None,
                    explicit_delivery: bool = False):
    """jit-able train step closed over the experiment config + optimizer.

    explicit_delivery=True returns the TRANSPORT-mode step: it takes a
    trailing (J,) / (J, B) delivery-mask argument (the measured transport
    outcome) instead of drawing faults in-graph."""
    if explicit_delivery:
        @jax.jit
        def step_d(params, state, opt_state, views, labels, rng, delivery):
            (loss, (metrics, new_state)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(
                    params, state, views, labels, rng, cfg, train=True,
                    rate_estimator=rate_estimator, wire=wire,
                    topology=topology, delivery=delivery)
            new_params, new_opt = optimizer.update(grads, opt_state, params)
            return new_params, new_state, new_opt, metrics
        return step_d

    @jax.jit
    def step(params, state, opt_state, views, labels, rng):
        (loss, (metrics, new_state)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, state, views, labels, rng, cfg,
                                   train=True, rate_estimator=rate_estimator,
                                   wire=wire, topology=topology)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_state, new_opt, metrics
    return step


def predict(params: INLParams, state, views, *, cfg=None, topology=None,
            delivery=None, wire: str = "dense"):
    """Inference phase (§III-B): deterministic latents (u = mu), soft output.

    delivery — an optional (J,) or (J, B) boolean delivery mask
    (core/linkfault.py): views whose route dropped or missed the fusion
    deadline are masked out of the concatenation and the survivors
    renormalised (fuse-what-arrived).  None is the perfect network —
    bit-identical to the pre-fault path.

    wire — the per-hop link encoding for graph topologies
    (core/wirefmt.py): "packed" moves each hop's payload as bit-packed
    codeword lanes.  Hop values are already on the edge's quantizer grid,
    so packing is a lossless re-encoding — graph predictions are
    bit-identical across wire formats; only the measured bytes ledger
    moves.  The star path ships unquantized latents (the golden-pinned
    seed convention, see NOTE below) and ignores `wire`.

    A non-star `topology` (needs `cfg` for the edge widths) routes the
    deterministic latents through the same multi-hop re-encoding the
    training graph runs — what the fuse node actually receives.  NOTE the
    deliberate convention split: the star path ships UNQUANTIZED latents
    at inference (the seed convention, pinned by the golden accuracies),
    while the graph path models the real quantized multi-hop delivery —
    so at full-precision links (every hop the identity) chain/tree
    inference is bit-identical to the star, and at narrow links the
    difference IS the deployment effect (a 2-bit uplink visibly costs
    accuracy).  Compare star-vs-graph accuracy curves at link_bits=32, or
    read narrow-width comparisons as including inference-time
    quantization."""
    topo = None if cfg is None else topology_lib.nontrivial(topology, cfg)
    if topo is None:
        u, _, _, _ = encode(params, state, views, train=False,
                            sample_latent=False)
        u_joint = None if delivery is None else linkfault.partial_fuse(
            u, delivery)
        joint, _ = decode(params, u, train=False, u_joint=u_joint)
        return jax.nn.softmax(joint, axis=-1)
    (mu, logvar), _ = _encode_mu_logvar(params, state, views, train=False)
    u, _, u_fused = topology_lib.graph_cut_and_ship(
        topo, cfg, mu, logvar, jnp.zeros(mu.shape, jnp.float32),
        rate_estimator="none", wire=wire)
    if delivery is not None:
        u_fused = linkfault.partial_fuse(u_fused, delivery)
    joint, _ = decode(params, u, train=False, u_joint=u_fused)
    return jax.nn.softmax(joint, axis=-1)


def evaluate(params: INLParams, state, views, labels):
    probs = predict(params, state, views)
    return losses.accuracy(jnp.log(probs + 1e-30), labels)


# ---------------------------------------------------------------------------
# Heterogeneous-encoder variant (paper: NNs "need not be identical")
# ---------------------------------------------------------------------------

def init_heterogeneous(cfgs, key):
    """One (possibly different) PaperExperimentConfig per client; returns
    list-based params usable with loss_fn_heterogeneous."""
    ks = jax.random.split(key, len(cfgs) + 1)
    encs = [paper_model.encoder_init(ks[j], c) for j, c in enumerate(cfgs)]
    dec = paper_model.decoder_init(ks[-1], cfgs[0])
    params = {"encoders": [e[0] for e in encs], "decoder": dec}
    state = {"encoders": [e[1] for e in encs]}
    return params, state


def loss_fn_heterogeneous(params, state, views, labels, rng, cfg, *,
                          train: bool = True, backend: str = "auto"):
    """Per-node encoder architectures may differ, but every node emits the
    same d_bottleneck — so after the (necessarily sequential) encoder
    applies, the cut layer is still ONE fused kernel launch over the
    stacked (J, B, d) latents."""
    mus, lvs, new_states = [], [], []
    for j, (ep, es) in enumerate(zip(params["encoders"], state["encoders"])):
        (mu, lv), ns = paper_model.encoder_apply(ep, es, views[j], train=train)
        mus.append(mu); lvs.append(lv); new_states.append(ns)
    rng, r_cut, r_dec = jax.random.split(rng, 3)
    u, rate = bottleneck.fused_sample_rate(
        r_cut, jnp.stack(mus), jnp.stack(lvs), link_bits=cfg.link_bits,
        rate_estimator="sample", backend=backend)
    fake = INLParams(None, params["decoder"], {})
    joint, branch = decode(fake, u, train=train, rng=r_dec)
    loss, metrics = losses.inl_loss(joint, list(branch), labels, mus, lvs,
                                    list(u), s=cfg.s, rates=list(rate))
    metrics["accuracy"] = losses.accuracy(joint, labels)
    return loss, (metrics, {"encoders": new_states})
