"""In-network learning (INL) — the paper's architecture (§III).

J edge nodes encode their local views into stochastic bottleneck latents u_j;
node (J+1) concatenates them (eq. 5) and decodes.  Training optimises eq. (6)
end-to-end: JAX AD through the concatenation reproduces exactly the paper's
error-vector split (eq. 8c / Remark 2) — node j receives only its chunk
delta[j] of the decoder-input cotangent, plus the local gradient of its own
rate term (eq. 10).  tests/test_inl_grads.py verifies the hand-derived split
against AD.

Encoder parameters are STACKED along a leading J axis so the whole system
shards over a 'client' mesh axis (each client's encoder params + data live on
its own devices; only u_j / delta_j cross the boundary — the paper's
bandwidth story).  A heterogeneous (list-of-different-encoders) path is also
provided, since the paper allows per-node architectures to differ.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bottleneck, linkmodel, losses, paper_model


class INLParams(NamedTuple):
    encoders: dict          # stacked: leading axis J
    decoder: dict
    priors: dict            # {} when standard-normal


def init(cfg, key):
    """cfg: PaperExperimentConfig.  Returns (INLParams, state)."""
    J = cfg.num_clients
    ks = jax.random.split(key, 3)
    enc_keys = jax.random.split(ks[0], J)
    stacked = jax.vmap(lambda k: paper_model.encoder_init(k, cfg))(enc_keys)
    enc_params, enc_state = stacked
    dec = paper_model.decoder_init(ks[1], cfg)
    return (INLParams(enc_params, dec, {}), {"encoders": enc_state})


def encode(params: INLParams, state, views, *, train: bool, rng=None,
           link_bits: int = 32, sample_latent: bool = True):
    """views: (J,B,H,W,C) -> (u (J,B,d), mu, logvar, new_state).

    This is everything that runs AT THE EDGE.  u is what crosses the links
    (quantized to link_bits)."""
    (mu, logvar), new_state = jax.vmap(
        lambda p, s, v: paper_model.encoder_apply(p, s, v, train=train)
    )(params.encoders, state["encoders"], views)
    if sample_latent and rng is not None:
        eps_keys = jax.random.split(rng, mu.shape[0])
        u = jax.vmap(bottleneck.sample)(eps_keys, mu, logvar)
    else:
        u = mu
    u_sent = linkmodel.quantize_st(u, link_bits)
    return u_sent, mu, logvar, {"encoders": new_state}


def decode(params: INLParams, u, *, train: bool, rng=None):
    """Node (J+1): u (J,B,d) -> (joint_logits, branch_logits (J,B,C))."""
    J, B, d = u.shape
    u_cat = jnp.moveaxis(u, 0, 1).reshape(B, J * d)       # eq. (5) concat
    joint = paper_model.decoder_apply(params.decoder, u_cat, train=train,
                                      rng=rng)
    branch = paper_model.branch_heads_apply(params.decoder, u)
    return joint, branch


def loss_fn(params: INLParams, state, views, labels, rng, cfg, *,
            train: bool = True, rate_estimator: str = "sample"):
    """Full eq.-(6) loss.  Returns (loss, (metrics, new_state))."""
    r_enc, r_dec = jax.random.split(rng)
    u, mu, logvar, new_state = encode(params, state, views, train=train,
                                      rng=r_enc, link_bits=cfg.link_bits)
    joint, branch = decode(params, u, train=train, rng=r_dec)
    J = u.shape[0]
    loss, metrics = losses.inl_loss(
        joint, list(branch), labels,
        list(mu), list(logvar), list(u),
        s=cfg.s, rate_estimator=rate_estimator)
    metrics["accuracy"] = losses.accuracy(joint, labels)
    # §III-C accounting: activations forward + error vectors backward
    p_total = J * cfg.d_bottleneck
    metrics["bits_sent"] = jnp.asarray(
        linkmodel.training_step_bits(labels.shape[0], p_total, cfg.link_bits),
        jnp.float32)
    return loss, (metrics, new_state)


def make_train_step(cfg, optimizer, *, rate_estimator: str = "sample"):
    """jit-able train step closed over the experiment config + optimizer."""
    @jax.jit
    def step(params, state, opt_state, views, labels, rng):
        (loss, (metrics, new_state)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, state, views, labels, rng, cfg,
                                   train=True, rate_estimator=rate_estimator)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_state, new_opt, metrics
    return step


def predict(params: INLParams, state, views):
    """Inference phase (§III-B): deterministic latents (u = mu), soft output."""
    u, _, _, _ = encode(params, state, views, train=False,
                        sample_latent=False)
    joint, _ = decode(params, u, train=False)
    return jax.nn.softmax(joint, axis=-1)


def evaluate(params: INLParams, state, views, labels):
    probs = predict(params, state, views)
    return losses.accuracy(jnp.log(probs + 1e-30), labels)


# ---------------------------------------------------------------------------
# Heterogeneous-encoder variant (paper: NNs "need not be identical")
# ---------------------------------------------------------------------------

def init_heterogeneous(cfgs, key):
    """One (possibly different) PaperExperimentConfig per client; returns
    list-based params usable with loss_fn_heterogeneous."""
    ks = jax.random.split(key, len(cfgs) + 1)
    encs = [paper_model.encoder_init(ks[j], c) for j, c in enumerate(cfgs)]
    dec = paper_model.decoder_init(ks[-1], cfgs[0])
    params = {"encoders": [e[0] for e in encs], "decoder": dec}
    state = {"encoders": [e[1] for e in encs]}
    return params, state


def loss_fn_heterogeneous(params, state, views, labels, rng, cfg, *,
                          train: bool = True):
    us, mus, lvs, new_states = [], [], [], []
    for j, (ep, es) in enumerate(zip(params["encoders"], state["encoders"])):
        (mu, lv), ns = paper_model.encoder_apply(ep, es, views[j], train=train)
        rng, sub = jax.random.split(rng)
        u = linkmodel.quantize_st(bottleneck.sample(sub, mu, lv),
                                  cfg.link_bits)
        us.append(u); mus.append(mu); lvs.append(lv); new_states.append(ns)
    u = jnp.stack(us)
    fake = INLParams(None, params["decoder"], {})
    rng, sub = jax.random.split(rng)
    joint, branch = decode(fake, u, train=train, rng=sub)
    loss, metrics = losses.inl_loss(joint, list(branch), labels, mus, lvs, us,
                                    s=cfg.s)
    metrics["accuracy"] = losses.accuracy(joint, labels)
    return loss, (metrics, {"encoders": new_states})
