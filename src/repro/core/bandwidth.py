"""Bandwidth accounting — §III-C / Table I, exactly as published.

    INL:  2 p q s / J        per epoch (activations fwd + errors bwd; each of
                             the J nodes holds q/J points and sends p/J values)
    FL:   2 N J s            per round (full weights down + up, J clients)
    SL:   (2 p q + eta N J) s  per epoch (cut activations for all q points +
                             J sequential weight hand-offs of eta*N params)

Table I constants: VGG16 N=138,344,128; ResNet50 N=25,636,712; J=500;
p=25088; eta=0.11 (VGG16) / 0.88 (ResNet50); s=32 bits.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

GBIT = 1e9

VGG16_PARAMS = 138_344_128
RESNET50_PARAMS = 25_636_712
TABLE1_J = 500
TABLE1_P = 25_088
TABLE1_ETA = {"vgg16": 0.11, "resnet50": 0.88}
TABLE1_BITS = 32


def inl_epoch_bits(p: int, q: int, J: int, s: int = TABLE1_BITS) -> float:
    return 2.0 * p * q * s / J


def fl_round_bits(N: int, J: int, s: int = TABLE1_BITS) -> float:
    return 2.0 * N * J * s


def sl_epoch_bits(p: int, q: int, N: int, J: int, eta: float,
                  s: int = TABLE1_BITS) -> float:
    return (2.0 * p * q + eta * N * J) * s


def table1(q: int, network: str) -> Dict[str, float]:
    """Reproduce one row of Table I (values in Gbits)."""
    N = VGG16_PARAMS if network == "vgg16" else RESNET50_PARAMS
    eta = TABLE1_ETA[network]
    return {
        "federated": fl_round_bits(N, TABLE1_J) / GBIT,
        "split": sl_epoch_bits(TABLE1_P, q, N, TABLE1_J, eta) / GBIT,
        "in_network": inl_epoch_bits(TABLE1_P, q, TABLE1_J) / GBIT,
    }


# Published Table I values (Gbits) for validation in tests/benchmarks.
PAPER_TABLE1 = {
    ("vgg16", 50_000): {"federated": 4427, "split": 324, "in_network": 0.16},
    ("resnet50", 50_000): {"federated": 820, "split": 441, "in_network": 0.16},
    ("vgg16", 500_000): {"federated": 4427, "split": 1046, "in_network": 1.6},
    ("resnet50", 500_000): {"federated": 820, "split": 1164,
                            "in_network": 1.6},
}


@dataclass
class BandwidthMeter:
    """Two ledgers for one run: the ACCOUNTED bits (closed-form §III-C /
    Table-I charges, `add`) and the MEASURED bytes (`add_measured`) — the
    `nbytes` of the buffers the execution layer actually put on the wire
    (core/wirefmt.py derives them from the real wire ops via eval_shape).

    With the packed wire format the two ledgers agree exactly
    (measured_bits == accounted bits); the dense fp32 baseline moves
    32/link_bits more than it accounts — the gap this meter exists to
    expose.  tests/test_scheme_parity.py pins the agreement."""
    total_bits: float = 0.0
    measured_bytes: float = 0.0

    def add(self, bits: float) -> None:
        self.total_bits += float(bits)

    def add_measured(self, nbytes: float) -> None:
        self.measured_bytes += float(nbytes)

    @property
    def gbits(self) -> float:
        return self.total_bits / GBIT

    @property
    def measured_bits(self) -> float:
        return self.measured_bytes * 8.0

    @property
    def measured_gbits(self) -> float:
        return self.measured_bits / GBIT


# the ISSUE/roadmap name for the measured meter
BitMeter = BandwidthMeter
