"""Bandwidth accounting — §III-C / Table I, exactly as published.

    INL:  2 p q s / J        per epoch (activations fwd + errors bwd; each of
                             the J nodes holds q/J points and sends p/J values)
    FL:   2 N J s            per round (full weights down + up, J clients)
    SL:   (2 p q + eta N J) s  per epoch (cut activations for all q points +
                             J sequential weight hand-offs of eta*N params)

Table I constants: VGG16 N=138,344,128; ResNet50 N=25,636,712; J=500;
p=25088; eta=0.11 (VGG16) / 0.88 (ResNet50); s=32 bits.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

GBIT = 1e9

VGG16_PARAMS = 138_344_128
RESNET50_PARAMS = 25_636_712
TABLE1_J = 500
TABLE1_P = 25_088
TABLE1_ETA = {"vgg16": 0.11, "resnet50": 0.88}
TABLE1_BITS = 32


def inl_epoch_bits(p: int, q: int, J: int, s: int = TABLE1_BITS) -> float:
    return 2.0 * p * q * s / J


def fl_round_bits(N: int, J: int, s: int = TABLE1_BITS) -> float:
    return 2.0 * N * J * s


def sl_epoch_bits(p: int, q: int, N: int, J: int, eta: float,
                  s: int = TABLE1_BITS) -> float:
    return (2.0 * p * q + eta * N * J) * s


def table1(q: int, network: str) -> Dict[str, float]:
    """Reproduce one row of Table I (values in Gbits).

    `network` must be a Table-I architecture — an unknown string used to
    fall through to resnet50 silently."""
    if network not in TABLE1_ETA:
        raise ValueError(f"unknown Table-I network {network!r}; "
                         f"known: {sorted(TABLE1_ETA)}")
    N = VGG16_PARAMS if network == "vgg16" else RESNET50_PARAMS
    eta = TABLE1_ETA[network]
    return {
        "federated": fl_round_bits(N, TABLE1_J) / GBIT,
        "split": sl_epoch_bits(TABLE1_P, q, N, TABLE1_J, eta) / GBIT,
        "in_network": inl_epoch_bits(TABLE1_P, q, TABLE1_J) / GBIT,
    }


# Published Table I values (Gbits) for validation in tests/benchmarks.
PAPER_TABLE1 = {
    ("vgg16", 50_000): {"federated": 4427, "split": 324, "in_network": 0.16},
    ("resnet50", 50_000): {"federated": 820, "split": 441, "in_network": 0.16},
    ("vgg16", 500_000): {"federated": 4427, "split": 1046, "in_network": 1.6},
    ("resnet50", 500_000): {"federated": 820, "split": 1164,
                            "in_network": 1.6},
}


@dataclass
class BandwidthMeter:
    """Two ledgers for one run: the ACCOUNTED bits (closed-form §III-C /
    Table-I charges, `add`) and the MEASURED bytes (`add_measured`) — the
    `nbytes` of the buffers the execution layer actually put on the wire
    (core/wirefmt.py derives them from the real wire ops via eval_shape).

    With the packed wire format the two ledgers agree exactly
    (measured_bits == accounted bits); the dense fp32 baseline moves
    32/link_bits more than it accounts — the gap this meter exists to
    expose.  tests/test_scheme_parity.py pins the agreement.

    Both ledgers also decompose PER EDGE of a network topology
    (core/topology.py): `add_edge` charges one named link on both ledgers
    at once, accumulating `edge_bits` / `edge_measured_bytes` alongside the
    totals — for `star(J)` the per-edge charges sum to exactly the Table-I
    totals the scalar `add` path produces.

    Unreliable links (core/linkfault.py) split each ledger further into
    OFFERED vs DELIVERED: `add` / `add_measured` / `add_edge` charge what
    the schedule put on the links (SL's bounded retries re-offer the
    round's exchange per attempt), while `add_delivered` accrues what the
    consumer actually used (the latent chunks that reached the fusion in
    time, the FedAvg uploads that arrived, the SL rounds that ran).  On a
    fault-free run the runner credits delivered == offered, so
    `delivery_ratio` is exactly 1.0 and drops with the network."""
    total_bits: float = 0.0
    measured_bytes: float = 0.0
    edge_bits: Dict[str, float] = field(default_factory=dict)
    edge_measured_bytes: Dict[str, float] = field(default_factory=dict)
    delivered_bits: float = 0.0
    delivered_measured_bytes: float = 0.0
    edge_delivered_bits: Dict[str, float] = field(default_factory=dict)

    def add(self, bits: float) -> None:
        self.total_bits += float(bits)

    def add_measured(self, nbytes: float) -> None:
        self.measured_bytes += float(nbytes)

    def add_edge(self, edge: str, *, bits: float = 0.0,
                 nbytes: float = 0.0) -> None:
        """Charge one topology edge on both ledgers (totals included)."""
        self.edge_bits[edge] = self.edge_bits.get(edge, 0.0) + float(bits)
        self.edge_measured_bytes[edge] = \
            self.edge_measured_bytes.get(edge, 0.0) + float(nbytes)
        self.add(bits)
        self.add_measured(nbytes)

    def add_delivered(self, *, bits: float = 0.0, nbytes: float = 0.0,
                      edge: str = None) -> None:
        """Credit traffic the consumer actually used (<= the offered
        charge of the same transmission; per edge when named)."""
        self.delivered_bits += float(bits)
        self.delivered_measured_bytes += float(nbytes)
        if edge is not None:
            self.edge_delivered_bits[edge] = \
                self.edge_delivered_bits.get(edge, 0.0) + float(bits)

    @property
    def gbits(self) -> float:
        return self.total_bits / GBIT

    @property
    def measured_bits(self) -> float:
        return self.measured_bytes * 8.0

    @property
    def measured_gbits(self) -> float:
        return self.measured_bits / GBIT

    @property
    def delivered_gbits(self) -> float:
        return self.delivered_bits / GBIT

    @property
    def delivery_ratio(self) -> float:
        """Delivered / offered accounted bits; 1.0 on an idle meter (and
        on any fault-free run — the runner credits both ledgers equally)."""
        return (self.delivered_bits / self.total_bits
                if self.total_bits else 1.0)


# the ISSUE/roadmap name for the measured meter
BitMeter = BandwidthMeter
