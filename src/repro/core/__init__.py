# The paper's primary contribution: in-network learning (INL) — distributed
# variational-information-bottleneck inference/training over edge nodes —
# plus its published baselines (federated + split learning) and the
# bandwidth/link substrate they are compared on.  `schemes` is the unified
# Scheme API the three-way comparison runs behind (registry + runner).
from repro.core import (bandwidth, bottleneck, fl, inl, inl_llm,  # noqa
                        linkmodel, losses, paper_model, sl)
from repro.core import schemes  # noqa  (after the modules it wraps)
