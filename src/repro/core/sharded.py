"""Node-parallel scheme execution: shard_map rounds over a (client, data) mesh.

The paper's claim that INL is *naturally distributed* (J nodes compute
features in parallel, a fusion center combines them) becomes an execution
strategy here: each scheme's training round is re-expressed as a
`shard_map` body over `launch/mesh.make_inl_host_mesh` /`make_inl_mesh`
axes —

    'client'  holds the J INL/FL branches (encoder params, branch heads,
              per-node priors, per-client FL replicas are sharded on their
              leading J axis),
    'data'    shards the batch.

Cross-node traffic is exactly the paper's cut-layer exchange: the fused
`kernels/ops.cutlayer` kernel runs per-shard on the local (J/c, B/d, d_b)
latent block, and the ONLY collectives are the fusion-center fan-in
(`all_gather` of u over 'client' — eq. (5)'s concatenation as a wire
transfer), the decoder/aggregation reductions (`psum` over 'client'), and
batch-mean reductions (`pmean` over 'data').

The fan-in's WIRE FORMAT is selectable (`wire=`, core/wirefmt.py): "dense"
all-gathers the quantized latents at their storage dtype (the baseline the
goldens pin); "packed" runs the pack-emitting cut-layer kernel and gathers
`link_bits`-bit codewords in uint32 lanes — 32/link_bits fewer collective
bytes, values and trajectories bit-identical; "packed_duplex" additionally
quantizes the eq.-(10) error chunks on the way back, making measured bytes
equal the paper's symmetric 2 b p s accounting (lossy: each node receives
exactly the q-bit-coded error chunk the modeled link delivers — execution
sums the replicated decoder's partial cotangents with a dense psum_scatter
first and quantizes after, a shard_map artifact the meter does not charge;
see core/wirefmt.py).  FL's weight exchange stays fp32 — quantized
FedAvg is a different algorithm, not a wire format.  `cfg.compute_dtype`
applies the mixed-precision policy inside every round body (params/views
drop to bf16 before local AD; grads, optimizer state and collective
reductions stay fp32).

Single-device semantics are preserved exactly (golden-trajectory parity,
tests/test_sharded_parity.py):

- all randomness (bottleneck eps, decoder dropout masks) is drawn OUTSIDE
  the shard_map body at global batch shape, so shards consume the same
  random stream the single-device run does;
- BatchNorm statistics are made global with pmean (paper_model.bn_apply
  axis_name) in the two-pass form matching jnp.var's numerics;
- the redundantly-replicated fusion term is scaled by 1/n_client before
  local AD: the all_gather transpose (psum_scatter) sums the n_client
  identical joint-CE cotangents, restoring the exact coefficient, while
  replicated decoder grads are psum'ed back up.  Verified against
  single-device AD at 1e-7.

Gradients come OUT of the shard_map body; the (elementwise) optimizer
update runs outside under plain jit so GSPMD keeps m/v in the params'
layout for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import linkfault, linkmodel, losses, paper_model, wirefmt
from repro.core import topology as topology_lib
from repro.core.inl import INLParams
from repro.kernels import ops


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def check_mesh(mesh, num_clients: int):
    """The sharded rounds need ('client', 'data') axes with J divisible by
    the client axis (make_inl_host_mesh guarantees this via its replicated
    fallback)."""
    for ax in ("client", "data"):
        if ax not in mesh.axis_names:
            raise ValueError(f"sharded schemes need a {ax!r} mesh axis; "
                             f"got {mesh.axis_names} (use "
                             f"launch.mesh.make_inl_host_mesh)")
    n_c = axis_size(mesh, "client")
    if num_clients % n_c:
        raise ValueError(f"client axis {n_c} does not divide J="
                         f"{num_clients}; make_inl_host_mesh falls back to "
                         f"a replicated client axis for such J")


def _check_batch(batch: int, n_d: int):
    if batch % n_d:
        raise ValueError(f"batch {batch} not divisible by data axis {n_d}; "
                         f"pick a batch size divisible by the device count")


def _pmean(tree, axis: str):
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis), tree)


def _psum(tree, axis: str):
    return jax.tree.map(lambda x: jax.lax.psum(x, axis), tree)


# ---------------------------------------------------------------------------
# INL: encoders sharded over 'client', batch over 'data', all_gather fan-in
# ---------------------------------------------------------------------------

def make_inl_sharded_round(cfg, mesh, optimizer, *, wire: str = "dense",
                           topology=None):
    """(state, views (1,J,B,H,W,C), labels (1,B), rng) -> (state, metrics),
    numerically matching core/inl.make_train_step on one device.

    A non-star `topology` swaps the fan-in for the graph execution
    (core/topology.graph_cut_and_ship): each node's cut runs per-shard at
    its first-hop width (group masks stay SPMD-uniform via the sharded
    `group_ids` input), the 'client' all_gather remains the one physical
    collective, and the per-edge re-encoding hops run replicated on the
    gathered buffer — values exactly the modeled multi-hop network's, so
    single-device parity holds at the same rtol as the star."""
    check_mesh(mesh, cfg.num_clients)
    wirefmt.resolve_wire(wire, cfg.link_bits)        # fail at build time
    topo = topology_lib.nontrivial(topology, cfg)
    topo_full = topology_lib.resolve(topology, cfg)
    faulty = linkfault.active(topo_full, cfg, train=True)
    J, s = cfg.num_clients, cfg.s
    n_c, n_d = axis_size(mesh, "client"), axis_size(mesh, "data")
    d_ax = "data"
    dt = paper_model.compute_dtype(cfg)
    if topo is None:
        gid_of_view = (0,) * J
    else:
        _, gid_of_view = topology_lib.first_hop_groups(topo, cfg)

    def local_grads(params, enc_state, views, labels, eps, masks, gids,
                    fmask):
        def obj_fn(p):
            p = paper_model.cast_compute(p, dt)
            (mu, logvar), new_st = jax.vmap(
                lambda pp, ss, v: paper_model.encoder_apply(
                    pp, ss, v, train=True, axis_name=d_ax)
            )(p.encoders, enc_state, views.astype(dt))
            # fusion-center fan-in: eq. (5)'s concat as a wire transfer —
            # dense values or packed codewords over the 'client' collective
            if topo is None:
                u, rate, u_all = wirefmt.cut_and_ship(
                    None, mu, logvar, eps=eps, link_bits=cfg.link_bits,
                    rate_estimator="sample", wire=wire, axis_name="client",
                    prior=p.priors or {})
            else:
                u, rate, u_all = topology_lib.graph_cut_and_ship(
                    topo, cfg, mu, logvar, eps, rate_estimator="sample",
                    wire=wire, prior=p.priors or {}, axis_name="client",
                    group_ids=gids)
            b_l = u.shape[1]
            if faulty:
                # fuse-what-arrived: the (J,) mask is replicated (drawn at
                # global scope from the round rng), so every shard fuses
                # the same survivors the single-device round does
                u_all = linkfault.partial_fuse(u_all, fmask)
            u_cat = jnp.moveaxis(u_all, 0, 1).reshape(b_l, J * u.shape[-1])
            joint = paper_model.decoder_apply(p.decoder, u_cat, train=True,
                                              drop_masks=masks)
            branch = paper_model.branch_heads_apply(p.decoder, u)
            ce_joint = losses.xent(joint, labels)
            ce_branch = jnp.stack([losses.xent(bl, labels) for bl in branch])
            rate_m = jnp.mean(rate, axis=1)                  # (J_local,)
            # 1/n_c on the replicated joint term: the all_gather transpose
            # psums the n_c identical cotangents back to full strength
            obj = ce_joint / n_c + s * (jnp.sum(ce_branch) + jnp.sum(rate_m))
            return obj, (ce_joint, jnp.sum(ce_branch), jnp.sum(rate_m),
                         joint, new_st)
        grads, aux = jax.grad(obj_fn, has_aux=True)(params)
        ce_joint, ce_b_sum, rate_sum, joint, new_st = aux
        # decoder dense grads carried 1/n_c each: restore via psum('client')
        grads = INLParams(
            grads.encoders,
            {"dense": _psum(grads.decoder["dense"], "client"),
             "branch_heads": grads.decoder["branch_heads"]},
            grads.priors)
        grads = _pmean(grads, d_ax)                # global batch mean
        ce_joint_g = jax.lax.pmean(ce_joint, d_ax)
        ce_b_g = jax.lax.pmean(jax.lax.psum(ce_b_sum, "client"), d_ax)
        rate_g = jax.lax.pmean(jax.lax.psum(rate_sum, "client"), d_ax)
        metrics = {
            "loss": ce_joint_g + s * (ce_b_g + rate_g),
            "ce_joint": ce_joint_g,
            "ce_branch_mean": ce_b_g / J,
            "rate_mean": rate_g / J,
            "rate_total": rate_g,
            "accuracy": jax.lax.pmean(losses.accuracy(joint, labels), d_ax),
        }
        return grads, metrics, new_st

    def round_fn(state, views, labels, rng):
        params, mstate, opt_state = (state["params"], state["state"],
                                     state["opt"])
        views, labels = views[0], labels[0]
        B = labels.shape[0]
        _check_batch(B, n_d)
        # same split chain as core/inl.loss_fn: eps + dropout at global shape
        r_enc, r_dec = jax.random.split(rng)
        eps = jax.random.normal(r_enc, (J, B, cfg.d_bottleneck), jnp.float32)
        masks = paper_model.decoder_dropout_masks(r_dec, cfg.dense_units, B)
        # delivery mask from the round rng's FOLDED fault stream — the same
        # draw core/inl.loss_fn and the host-side meter replay
        fmask = (linkfault.round_delivery_mask(rng, topo_full, cfg, B,
                                               train=True)
                 if faulty else jnp.ones((J,), bool))

        c = P("client")
        p_specs = INLParams(c, {"dense": P(), "branch_heads": c}, c)
        grads, metrics, new_enc_st = shard_map(
            local_grads, mesh=mesh,
            in_specs=(p_specs, c, P("client", "data"), P("data"),
                      P("client", "data"), P("data"), c, P()),
            out_specs=(p_specs, P(), c),
            check_rep=False,
        )(params, mstate["encoders"], views, labels, eps, masks,
          jnp.asarray(gid_of_view, jnp.int32), fmask)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        if topo is None:
            p_total = J * cfg.d_bottleneck
            bits_sent = linkmodel.training_step_bits(B, p_total,
                                                     cfg.link_bits)
        else:
            bits_sent = topology_lib.round_bits(topo, cfg, B)
        metrics["bits_sent"] = jnp.asarray(bits_sent, jnp.float32)
        return ({"params": new_params, "state": {"encoders": new_enc_st},
                 "opt": new_opt}, metrics)
    return jax.jit(round_fn)


# ---------------------------------------------------------------------------
# FL: the J client replicas (params, opt state, local steps) over 'client'
# ---------------------------------------------------------------------------

def make_fl_sharded_round(cfg, mesh, optimizer, local_steps: int, *,
                          topology=None):
    """FedAvg round with the per-client local-step scans running in parallel
    across the 'client' axis; server aggregation is one psum.  The weight
    exchange stays fp32 whatever the wire format (quantizing FedAvg updates
    changes the algorithm); cfg.compute_dtype still applies inside each
    client's local steps.

    When the (star) topology carries LinkModels or cfg.edge_dropout > 0,
    each round draws the same (J,) client delivery mask the single-device
    round does (core/linkfault.client_delivery_mask on the round rng) and
    the psum average runs over the uploads that arrived — all lost keeps
    the previous global model.  An all-ones mask divides by exactly J, so
    a modelled-perfect network stays bitwise on the legacy trajectory."""
    from repro.core import fl
    check_mesh(mesh, cfg.num_clients)
    J = cfg.num_clients
    topo_full = topology_lib.resolve(topology, cfg)
    faulty = linkfault.active(topo_full, cfg, train=True)
    one_client = fl.make_one_client(
        optimizer, compute_dtype=getattr(cfg, "compute_dtype", "fp32"))

    def local_round(params, mstate, opt_state, views, labels, rngs, mask):
        p, st, opt, m = jax.vmap(one_client)(params, mstate, opt_state,
                                             views, labels, rngs)
        j_l = labels.shape[0]
        if not faulty:
            # server aggregation: mean over ALL J clients = psum of local sums
            avg = jax.tree.map(
                lambda x: jax.lax.psum(jnp.sum(x, axis=0), "client") / J, p)
        else:
            w = mask.astype(jnp.float32)
            n = jax.lax.psum(jnp.sum(w), "client")

            def masked_avg(x, old):
                wx = w.reshape((j_l,) + (1,) * (x.ndim - 1))
                s = jax.lax.psum(jnp.sum(x * wx, axis=0), "client")
                return jnp.where(n > 0, s / jnp.maximum(n, 1.0),
                                 old[0].astype(x.dtype))

            avg = jax.tree.map(masked_avg, p, params)
        p_new = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (j_l,) + x.shape), avg)
        metrics = jax.tree.map(
            lambda x: jax.lax.psum(jnp.sum(x, axis=0), "client") / J, m)
        return p_new, st, opt, metrics

    sharded = shard_map(
        local_round, mesh=mesh,
        in_specs=(P("client"), P("client"), P("client"), P("client"),
                  P("client"), P("client"), P("client")),
        out_specs=(P("client"), P("client"), P("client"), P()),
        check_rep=False)

    def round_fn(state, views, labels, rng):
        # identical packing to FLScheme.make_round's single-device path
        ls = local_steps
        R, Jv, B = views.shape[:3]
        v5 = views.reshape((J, ls) + views.shape[1:])
        own = v5[jnp.arange(J)[:, None], jnp.arange(ls)[None, :],
                 jnp.arange(J)[:, None]]
        packed = jnp.broadcast_to(own[:, :, None],
                                  (J, ls, J) + own.shape[2:])
        lab = labels.reshape(J, ls, B)
        rngs = jax.random.split(rng, J)
        mask = (linkfault.client_delivery_mask(rng, topo_full, cfg,
                                               train=True)
                if faulty else jnp.ones((J,), bool))
        p, st, opt, metrics = sharded(state["params"], state["state"],
                                      state["opt"], packed, lab, rngs, mask)
        return ({"params": p, "state": st, "opt": opt}, metrics)
    return jax.jit(round_fn)


# ---------------------------------------------------------------------------
# SL: client/server split is sequential by construction; the batch shards
# ---------------------------------------------------------------------------

def make_sl_sharded_round(cfg, mesh, opt_client, opt_server, *,
                          wire: str = "dense"):
    """One SL client->server->client exchange with the minibatch sharded
    over 'data' (the J conv branches all live client-side, so 'client' only
    replicates); grads are pmean'ed back to the exact global-batch values.
    The cut crossing honours `wire` (packed codewords are a per-row
    re-encoding, so any batch sharding sees identical values)."""
    check_mesh(mesh, cfg.num_clients)
    wirefmt.resolve_wire(wire, cfg.link_bits)
    n_d = axis_size(mesh, "data")
    d_ax = "data"
    dt = paper_model.compute_dtype(cfg)

    def local_grads(client, server, mstate, views, labels, masks):
        def obj_fn(cs):
            cl, srv = cs
            cl = paper_model.cast_compute(cl, dt)
            srv = paper_model.cast_compute(srv, dt)
            mus, lvs, new_states = [], [], []
            for j, (ep, es) in enumerate(zip(cl["encoders"],
                                             mstate["encoders"])):
                (mu, lv), ns = paper_model.encoder_apply(
                    ep, es, views[j].astype(dt), train=True, axis_name=d_ax)
                mus.append(mu)
                lvs.append(lv)
                new_states.append(ns)
            u, _ = ops.cutlayer(jnp.stack(mus), jnp.stack(lvs),
                                jnp.zeros((len(mus),) + mus[0].shape,
                                          jnp.float32),
                                link_bits=cfg.link_bits,
                                rate_estimator="none")
            u_w = wirefmt.ship(u, link_bits=cfg.link_bits, wire=wire)
            j, b_l, d = u_w.shape
            u_cat = jnp.moveaxis(u_w, 0, 1).reshape(b_l, j * d)
            logits = paper_model.decoder_apply(srv["decoder"], u_cat,
                                               train=True, drop_masks=masks)
            loss = losses.xent(logits, labels)
            return loss, (logits, {"encoders": new_states})
        (loss, (logits, new_state)), grads = jax.value_and_grad(
            obj_fn, has_aux=True)((client, server))
        g_client, g_server = _pmean(grads, d_ax)
        metrics = {"loss": jax.lax.pmean(loss, d_ax),
                   "accuracy": jax.lax.pmean(
                       losses.accuracy(logits, labels), d_ax)}
        return g_client, g_server, metrics, new_state

    def round_fn(state, views, labels, rng):
        views, labels = views[0], labels[0]
        B = labels.shape[0]
        _check_batch(B, n_d)
        masks = paper_model.decoder_dropout_masks(rng, cfg.dense_units, B)
        g_c, g_s, metrics, new_state = shard_map(
            local_grads, mesh=mesh,
            in_specs=(P(), P(), P(), P(None, "data"), P("data"), P("data")),
            out_specs=(P(), P(), P(), P()),
            check_rep=False,
        )(state["client"], state["server"], state["state"], views, labels,
          masks)
        new_client, new_opt_c = opt_client.update(g_c, state["opt_c"],
                                                  state["client"])
        new_server, new_opt_s = opt_server.update(g_s, state["opt_s"],
                                                  state["server"])
        return ({"client": new_client, "server": new_server,
                 "state": new_state, "opt_c": new_opt_c,
                 "opt_s": new_opt_s}, metrics)
    return jax.jit(round_fn)
