"""Split learning (Gupta & Raskar 2018) — the paper's second baseline.

Per §IV-A: each client holds ALL J conv branches (the full Fig.-4 network
minus node (J+1)'s dense part); the server holds the dense part.  Training is
SEQUENTIAL round-robin: client j runs epochs on its local shard, exchanging
cut-layer activations/errors with the server; then passes its (client-side)
weights to client j+1.

Bandwidth per epoch (§III-C): (2 p q + eta N J) s bits — activations/errors
for every data point plus one client->client weight transfer per epoch
(eta = client-side fraction of the N parameters).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bottleneck, losses, paper_model, wirefmt


def init(cfg, key):
    """Returns ((client_params, server_params), state).  The client side =
    all J conv branches + bottleneck heads; server side = dense decoder."""
    params, state = paper_model.fl_model_init(key, cfg)
    client = {"encoders": params["encoders"]}
    server = {"decoder": params["decoder"]}
    return (client, server), state


def forward_client(client, state, views, *, train: bool,
                   link_bits: int = 32, backend: str = "auto",
                   compute_dtype: str = "fp32"):
    """Client-side cut-layer activations: concat of all J branch latents.

    SL sends DETERMINISTIC activations (no stochastic bottleneck), but the
    exchange itself runs the same fused cut-layer kernel as INL in its
    no-noise mode (eps == 0, rate == 0): one launch over the stacked
    (J, B, d) latents yields u = quantize(mu), and the backward pass
    returns the server's error vector through the straight-through
    quantizer — the two schemes now share one measured substrate.

    compute_dtype="bf16" runs the conv trunks in half precision (the
    mixed-precision policy; grads/master params stay fp32 at the caller)."""
    dt = paper_model.COMPUTE_DTYPES[compute_dtype]
    client = paper_model.cast_compute(client, dt)
    views = views.astype(dt)
    mus, lvs, new_states = [], [], []
    for j, (ep, es) in enumerate(zip(client["encoders"], state["encoders"])):
        (mu, lv), ns = paper_model.encoder_apply(ep, es, views[j],
                                                 train=train)
        mus.append(mu)
        lvs.append(lv)
        new_states.append(ns)
    u, _ = bottleneck.fused_sample_rate(
        None, jnp.stack(mus), jnp.stack(lvs), link_bits=link_bits,
        rate_estimator="none", backend=backend)            # (J,B,d_b)
    return u, {"encoders": new_states}


def loss_fn(client, server, state, views, labels, rng, *, train=True,
            link_bits: int = 32, backend: str = "auto", wire: str = "dense",
            compute_dtype: str = "fp32"):
    u, new_state = forward_client(client, state, views, train=train,
                                  link_bits=link_bits, backend=backend,
                                  compute_dtype=compute_dtype)
    # the client->server link: dense values or bit-packed codewords
    # (wirefmt; dense is the identity, so the baseline graph is untouched)
    u_w = wirefmt.ship(u, link_bits=link_bits, wire=wire, backend=backend)
    J, B, d = u_w.shape
    u_cat = jnp.moveaxis(u_w, 0, 1).reshape(B, J * d)
    server = paper_model.cast_compute(
        server, paper_model.COMPUTE_DTYPES[compute_dtype])
    logits = paper_model.decoder_apply(server["decoder"], u_cat, train=train,
                                       rng=rng)
    loss = losses.xent(logits, labels)
    return loss, ({"loss": loss,
                   "accuracy": losses.accuracy(logits, labels)}, new_state)


def make_train_step(optimizer_client, optimizer_server, *,
                    link_bits: int = 32, backend: str = "auto",
                    wire: str = "dense", compute_dtype: str = "fp32"):
    """One SL step: server computes loss, backprops the cut-layer error to
    the active client (the fused kernel's custom VJP produces exactly that
    error vector, straight-through through the link quantizer)."""
    @jax.jit
    def step(client, server, state, opt_c, opt_s, views, labels, rng):
        (loss, (metrics, new_state)), grads = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(
            client, server, state, views, labels, rng,
            link_bits=link_bits, backend=backend, wire=wire,
            compute_dtype=compute_dtype)
        g_client, g_server = grads
        new_client, new_opt_c = optimizer_client.update(g_client, opt_c, client)
        new_server, new_opt_s = optimizer_server.update(g_server, opt_s, server)
        return new_client, new_server, new_state, new_opt_c, new_opt_s, metrics
    return step


def epoch_bits(cfg, dataset_size: int, client_params: int,
               bits: int = 32) -> int:
    """(2 p q + eta N J) s for one full epoch over q points: cut activations
    forward + errors backward for every point, plus J client->client weight
    hand-offs.  Here eta*N == client_params (the client-side count)."""
    p_total = cfg.num_clients * cfg.d_bottleneck
    return (2 * p_total * dataset_size
            + client_params * cfg.num_clients) * bits


def predict(client, server, state, views):
    u, _ = forward_client(client, state, views, train=False)
    J, B, d = u.shape
    u_cat = jnp.moveaxis(u, 0, 1).reshape(B, J * d)
    logits = paper_model.decoder_apply(server["decoder"], u_cat, train=False)
    return jax.nn.softmax(logits, axis=-1)
