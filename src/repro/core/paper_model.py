"""The paper's §IV network (Fig. 4): per-client VGG-style conv encoders over
32x32x3 noisy views, and two dense layers at node (J+1).

Pure JAX: conv via lax.conv_general_dilated, BatchNorm with running stats
(threaded as `state`), Dropout, max-pool.  Apply signature:

    encoder_apply(params, state, x, *, train, rng) -> (features, new_state)

The same conv trunk is reused to build the FL model (all J branches + head on
one client, Fig. 4/6) and the SL client net (all conv branches client-side).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bottleneck
from repro.models import layers

BN_MOMENTUM = 0.9

COMPUTE_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16}


def compute_dtype(cfg):
    """The hot-path matmul/conv dtype from cfg.compute_dtype ("fp32" default,
    "bf16" for the mixed-precision policy).  Master params, optimizer state,
    BatchNorm statistics and the kernels' rate/KL accumulation ALWAYS stay
    fp32 — only the activations/weights entering convs and denses drop."""
    name = getattr(cfg, "compute_dtype", "fp32") or "fp32"
    try:
        return COMPUTE_DTYPES[name]
    except KeyError:
        raise ValueError(f"unknown compute_dtype {name!r}; "
                         f"known: {sorted(COMPUTE_DTYPES)}") from None


def cast_compute(tree, dtype):
    """Cast the fp32 float leaves of a param tree to the compute dtype.

    Applied INSIDE the loss function, so AD's transpose casts the gradients
    back to fp32 and the optimizer keeps full-precision master params (the
    classic mixed-precision split).  Identity for fp32 — the default policy
    adds nothing to the graph and the golden trajectories are untouched."""
    if dtype == jnp.float32:
        return tree
    return jax.tree.map(
        lambda x: x.astype(dtype) if x.dtype == jnp.float32 else x, tree)


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def conv_init(key, c_in: int, c_out: int, ksize: int = 3):
    fan_in = c_in * ksize * ksize
    w = jax.random.normal(key, (ksize, ksize, c_in, c_out), jnp.float32) \
        * np.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((c_out,), jnp.float32)}


def conv(p, x, stride: int = 1):
    out = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + p["b"]


def bn_init(c: int):
    return ({"scale": jnp.ones((c,), jnp.float32),
             "bias": jnp.zeros((c,), jnp.float32)},
            {"mean": jnp.zeros((c,), jnp.float32),
             "var": jnp.ones((c,), jnp.float32)})


def bn_apply(p, st, x, *, train: bool, axis_name=None):
    """axis_name — a mesh axis the batch dim is sharded over (shard_map
    bodies): batch statistics become GLOBAL via pmean, so data-parallel
    training normalises exactly like the single-device run.  The variance
    uses the two-pass form around the global mean (matching jnp.var's
    numerics) rather than E[x^2]-m^2, which would lose ~3 digits to
    cancellation and drift the golden trajectories.

    Statistics always accumulate in fp32 (`xf`), whatever the compute dtype
    — with the bf16 policy the conv activations come in half precision, but
    the running mean/var state and the normalisation arithmetic stay full
    precision; only the output drops back to x.dtype.  For fp32 inputs every
    cast is the identity, so the default policy's numerics are unchanged."""
    xf = x.astype(jnp.float32)
    if train:
        mean = xf.mean(axis=(0, 1, 2))
        if axis_name is not None:
            mean = jax.lax.pmean(mean, axis_name)
            var = jax.lax.pmean(
                jnp.square(xf - mean).mean(axis=(0, 1, 2)), axis_name)
        else:
            var = xf.var(axis=(0, 1, 2))
        new_st = {"mean": BN_MOMENTUM * st["mean"] + (1 - BN_MOMENTUM) * mean,
                  "var": BN_MOMENTUM * st["var"] + (1 - BN_MOMENTUM) * var}
    else:
        mean, var = st["mean"], st["var"]
        new_st = st
    y = (xf - mean) * jax.lax.rsqrt(var + 1e-5) \
        * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype), new_st


def maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def dropout(key, x, rate: float, *, train: bool):
    if not train or rate == 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


# ---------------------------------------------------------------------------
# Conv encoder trunk (one client branch)
# ---------------------------------------------------------------------------

def encoder_init(key, cfg):
    """cfg: PaperExperimentConfig.  Returns (params, state)."""
    chans = (cfg.image_shape[-1],) + tuple(cfg.conv_channels)
    params, state = {"convs": [], "bns": []}, {"bns": []}
    ks = jax.random.split(key, 2 * len(cfg.conv_channels) + 2)
    for i in range(len(cfg.conv_channels)):
        params["convs"].append(conv_init(ks[2 * i], chans[i], chans[i + 1]))
        bp, bs = bn_init(chans[i + 1])
        params["bns"].append(bp)
        state["bns"].append(bs)
    h = cfg.image_shape[0] // (2 ** len(cfg.conv_channels))
    feat_dim = h * h * cfg.conv_channels[-1]
    params["head"] = bottleneck.head_init(ks[-1], feat_dim, cfg.d_bottleneck)
    return params, state


def encoder_feat_dim(cfg) -> int:
    h = cfg.image_shape[0] // (2 ** len(cfg.conv_channels))
    return h * h * cfg.conv_channels[-1]


def encoder_apply(params, state, x, *, train: bool, axis_name=None):
    """x: (B,H,W,C) -> ((mu, logvar), new_state).  axis_name: mesh axis the
    batch is sharded over (collective BatchNorm stats, see bn_apply)."""
    new_bns = []
    h = x
    for cp, bp, bs in zip(params["convs"], params["bns"], state["bns"]):
        h = conv(cp, h)
        h, nbs = bn_apply(bp, bs, h, train=train, axis_name=axis_name)
        h = jax.nn.relu(h)
        h = maxpool2(h)
        new_bns.append(nbs)
    h = h.reshape(h.shape[0], -1)
    mu, logvar = bottleneck.head_apply(params["head"], h)
    return (mu, logvar), {"bns": new_bns}


def encoder_param_count(cfg) -> int:
    chans = (cfg.image_shape[-1],) + tuple(cfg.conv_channels)
    n = 0
    for i in range(len(cfg.conv_channels)):
        n += 9 * chans[i] * chans[i + 1] + chans[i + 1]   # conv w+b
        n += 2 * chans[i + 1]                              # bn scale+bias
    n += 2 * (encoder_feat_dim(cfg) * cfg.d_bottleneck + cfg.d_bottleneck)
    return n


# ---------------------------------------------------------------------------
# Central node (J+1): fusion decoder + per-branch decoders (Remark 1)
# ---------------------------------------------------------------------------

def decoder_init(key, cfg):
    J = cfg.num_clients
    dims = (J * cfg.d_bottleneck,) + tuple(cfg.dense_units) \
        + (cfg.num_classes,)
    ks = jax.random.split(key, len(dims) + 1)
    bh = jax.vmap(lambda k: layers.dense_init(
        k, cfg.d_bottleneck, cfg.num_classes, bias=True, dtype=jnp.float32))(
        jax.random.split(ks[-1], J))
    p = {"dense": [layers.dense_init(ks[i], dims[i], dims[i + 1], bias=True,
                                     dtype=jnp.float32)
                   for i in range(len(dims) - 1)],
         "branch_heads": bh}               # stacked (J, d_b, C) / (J, C)
    return p


def decoder_apply(p, u_cat, *, train: bool, rng=None, drop: float = 0.3,
                  drop_masks=None):
    """u_cat: (B, J*d_bottleneck) -> logits (B, classes).

    drop_masks — pre-drawn keep masks, one (B, units) bool array per hidden
    layer (see decoder_dropout_masks).  Sharded execution pre-draws them at
    GLOBAL batch shape outside the shard_map body so every shard applies the
    same slice the single-device run would — drawing per-shard would change
    the random stream and break golden-trajectory parity."""
    h = u_cat
    for i, dp in enumerate(p["dense"][:-1]):
        h = jax.nn.relu(layers.dense(dp, h))
        if train and drop_masks is not None:
            h = jnp.where(drop_masks[i], h / (1.0 - drop), 0.0)
        elif train and rng is not None:
            rng, sub = jax.random.split(rng)
            h = dropout(sub, h, drop, train=train)
    return layers.dense(p["dense"][-1], h)


def decoder_dropout_masks(rng, dense_units, batch: int, drop: float = 0.3):
    """The exact keep masks decoder_apply(rng=...) would draw, pre-computed.

    Replays decoder_apply's split chain (one split per hidden layer, in
    order) so `decoder_apply(..., drop_masks=masks)` is bitwise identical to
    `decoder_apply(..., rng=rng)` for the same key."""
    masks = []
    for units in dense_units:
        rng, sub = jax.random.split(rng)
        masks.append(jax.random.bernoulli(sub, 1.0 - drop, (batch, units)))
    return masks


def branch_heads_apply(p, us):
    """us: (J, B, d_b) -> per-branch logits (J, B, classes)."""
    return jax.vmap(layers.dense)(p["branch_heads"], us)


def decoder_param_count(cfg) -> int:
    J = cfg.num_clients
    dims = (J * cfg.d_bottleneck,) + tuple(cfg.dense_units) \
        + (cfg.num_classes,)
    n = sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
    n += J * (cfg.d_bottleneck * cfg.num_classes + cfg.num_classes)
    return n


# ---------------------------------------------------------------------------
# FL full model (Fig. 4 entire network on each client) and SL split
# ---------------------------------------------------------------------------

def fl_model_init(key, cfg):
    """The whole Fig.-4 network: J conv branches + fusion head, one copy."""
    ks = jax.random.split(key, cfg.num_clients + 1)
    encs = [encoder_init(ks[j], cfg) for j in range(cfg.num_clients)]
    params = {"encoders": [e[0] for e in encs],
              "decoder": decoder_init(ks[-1], cfg)}
    state = {"encoders": [e[1] for e in encs]}
    return params, state


def fl_model_apply(params, state, views, *, train: bool, rng=None,
                   deterministic_latent: bool = True, backend: str = "auto"):
    """views: (J,B,H,W,C) — all J views of the same images (FL/SL training),
    or a broadcast single image for FL Exp-2 inference.

    The branch latents cross the (here: in-model) cut through the SAME
    fused cut-layer kernel the other schemes use — deterministic no-noise
    mode (u == mu at full-precision link) or a reparametrised draw — so
    the three-way comparison shares one measured substrate."""
    mus, lvs, new_states = [], [], []
    for j, (ep, es) in enumerate(zip(params["encoders"], state["encoders"])):
        (mu, logvar), ns = encoder_apply(ep, es, views[j], train=train)
        mus.append(mu)
        lvs.append(logvar)
        new_states.append(ns)
    if deterministic_latent:
        sub = None
    else:
        rng, sub = jax.random.split(rng)
    u, _ = bottleneck.fused_sample_rate(
        sub, jnp.stack(mus), jnp.stack(lvs), link_bits=32,
        rate_estimator="none", backend=backend)
    J, B = u.shape[0], u.shape[1]
    u_cat = jnp.moveaxis(u, 0, 1).reshape(B, -1)          # == concat over J
    logits = decoder_apply(params["decoder"], u_cat, train=train, rng=rng)
    return logits, {"encoders": new_states}


def fl_param_count(cfg) -> int:
    return cfg.num_clients * encoder_param_count(cfg) + decoder_param_count(cfg)
