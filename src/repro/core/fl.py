"""Federated learning (FedAvg, McMahan et al. 2017) — the paper's baseline.

Every client holds a copy of the ENTIRE Fig.-4 network and trains on its
local shard (Exp-1: disjoint images, all J views of an image at one client;
Exp-2: all images, client-specific noise).  After `local_steps` minibatch
updates the server averages the weights and re-broadcasts.

Clients run in parallel via vmap over a stacked (J, ...) param tree — on a
mesh this vmap axis is sharded over 'client'.  Bandwidth per round:
2 * N * J * s bits (weights down + weights up, §III-C Table I).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import losses, paper_model


def init(cfg, key):
    """Stacked client copies of the full model (identical init = broadcast)."""
    params, state = paper_model.fl_model_init(key, cfg)
    J = cfg.num_clients
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x, (J,) + x.shape).copy(), t)
    return stack(params), stack(state)


def client_loss(params, state, views, labels, rng, *, train=True,
                compute_dtype: str = "fp32"):
    """views: (J,B,H,W,C) — all J views of this client's images.

    compute_dtype="bf16" drops params/views to half precision INSIDE the
    loss (mixed-precision policy): grads and the FedAvg weight exchange —
    which stays fp32 on the wire by design — keep full precision."""
    dt = paper_model.COMPUTE_DTYPES[compute_dtype]
    logits, new_state = paper_model.fl_model_apply(
        paper_model.cast_compute(params, dt), state, views.astype(dt),
        train=train, rng=rng)
    loss = losses.xent(logits, labels)
    acc = losses.accuracy(logits, labels)
    return loss, ({"loss": loss, "accuracy": acc}, new_state)


def make_local_step(optimizer, *, compute_dtype: str = "fp32"):
    def local_step(params, state, opt_state, views, labels, rng):
        (loss, (metrics, new_state)), grads = jax.value_and_grad(
            client_loss, has_aux=True)(params, state, views, labels, rng,
                                       compute_dtype=compute_dtype)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_state, new_opt, metrics
    return local_step


def make_one_client(optimizer, *, compute_dtype: str = "fp32"):
    """One client's FedAvg contribution: a lax.scan of local_steps minibatch
    updates, returning (params, state, opt_state, step-mean metrics).  Shared
    by the vmapped single-device round and the shard_map client-parallel
    round (core/sharded.py), so both paths train the same client program."""
    local_step = make_local_step(optimizer, compute_dtype=compute_dtype)

    def one_client(params, state, opt_state, views_seq, labels_seq, rng):
        def body(carry, inp):
            p, s, o, r = carry
            v, l = inp
            r, sub = jax.random.split(r)
            p, s, o, m = local_step(p, s, o, v, l, sub)
            return (p, s, o, r), m
        (p, s, o, _), ms = jax.lax.scan(
            body, (params, state, opt_state, rng), (views_seq, labels_seq))
        return p, s, o, jax.tree.map(jnp.mean, ms)
    return one_client


def make_round(cfg, optimizer, local_steps: int, *, faulty: bool = False):
    """One FedAvg round, jitted: local_steps on all J clients in parallel,
    then weight averaging.  client_data: (J, local_steps, B, J, H*W*C-shaped
    views...) — see examples/compare_schemes.py for the packing helper.

    faulty=True returns a round_fn taking an extra (J,) boolean `mask`
    (core/linkfault.client_delivery_mask): clients whose uplink dropped
    are masked out of the average (the server averages the weights that
    ARRIVED and re-broadcasts); when every upload is lost the round keeps
    the previous global model.  With an all-ones mask the masked average
    is sum(x)/J — bitwise the unfaulted jnp.mean."""
    one_client = make_one_client(
        optimizer, compute_dtype=getattr(cfg, "compute_dtype", "fp32"))

    if not faulty:
        @jax.jit
        def round_fn(stacked_params, stacked_state, stacked_opt, views,
                     labels, rngs):
            """views: (J, local_steps, J, B, H, W, C); labels: (J, local_steps, B)."""
            p, s, o, m = jax.vmap(one_client)(stacked_params, stacked_state,
                                              stacked_opt, views, labels,
                                              rngs)
            # ---- server aggregation: plain parameter average, re-broadcast
            avg = jax.tree.map(lambda x: jnp.mean(x, axis=0), p)
            J = labels.shape[0]
            p_new = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (J,) + x.shape).copy(), avg)
            return p_new, s, o, jax.tree.map(jnp.mean, m)
        return round_fn

    @jax.jit
    def round_fn(stacked_params, stacked_state, stacked_opt, views, labels,
                 rngs, mask):
        p, s, o, m = jax.vmap(one_client)(stacked_params, stacked_state,
                                          stacked_opt, views, labels, rngs)
        J = labels.shape[0]
        w = mask.astype(jnp.float32)
        n = jnp.sum(w)

        def masked_avg(x, old):
            wx = w.reshape((J,) + (1,) * (x.ndim - 1))
            avg = jnp.sum(x * wx, axis=0) / jnp.maximum(n, 1.0)
            # all uploads lost: the server re-broadcasts the previous
            # global model (every incoming replica holds it identically)
            return jnp.where(n > 0, avg, old[0].astype(avg.dtype))

        avg = jax.tree.map(masked_avg, p, stacked_params)
        p_new = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (J,) + x.shape).copy(), avg)
        return p_new, s, o, jax.tree.map(jnp.mean, m)
    return round_fn


def round_bits(cfg, num_params: int, bits: int = 32) -> int:
    """Table I: 2 N J s bits per round (download + upload of all weights)."""
    return 2 * num_params * cfg.num_clients * bits


def predict(stacked_params, stacked_state, images, *, exp2_average=False):
    """FL inference is CENTRAL: one aggregated model on one input image.
    For Exp-2 the paper feeds the average-quality image; views are broadcast
    to all J branch inputs of the Fig.-4 network."""
    params = jax.tree.map(lambda x: x[0], stacked_params)
    state = jax.tree.map(lambda x: x[0], stacked_state)
    J = len(params["encoders"])
    views = jnp.broadcast_to(images, (J,) + images.shape)
    logits, _ = paper_model.fl_model_apply(params, state, views, train=False)
    return jax.nn.softmax(logits, axis=-1)
