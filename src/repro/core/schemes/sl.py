"""Split learning behind the unified Scheme API (wraps core/sl.py).

One round == one client->server->client exchange on a minibatch: the
client-side conv branches emit deterministic cut-layer activations through
the fused kernel's no-noise mode, the server decoder computes the loss, and
the custom VJP returns the cut-layer error vector.  Per §III-C the epoch
cost is (2 p q + eta N J) s — the activation/error traffic accrues per
round, the J sequential client->client weight hand-offs once per epoch.
"""
from __future__ import annotations

import jax

from repro import optim
from repro.core import bandwidth, linkfault, paper_model, sl, wirefmt
from repro.core import schemes as _schemes
from repro.core import topology as topology_lib
from repro.core.schemes import base


@_schemes.register
class SLScheme(base.Scheme):
    name = "sl"
    # bounded retry on the single client->server uplink: a round runs iff
    # one of (1 + max_link_retries) attempts survives the link's erasure
    # draw; otherwise the round is SKIPPED (state carried unchanged) — SL
    # has no partial-fusion reading.  Every attempt is charged as offered
    # bandwidth (linkfault.round_fault_charges).
    max_link_retries = 2

    def init(self, cfg, key, *, lr: float = 2e-3):
        (client, server), state = sl.init(cfg, key)
        oc, osrv = optim.adam(lr), optim.adam(lr)
        return {"client": client, "server": server, "state": state,
                "opt_c": oc.init(client), "opt_s": osrv.init(server)}

    def _skip_failed_round(self, cfg, topology, round_fn):
        """Wrap a round: when the (star) topology models unreliable links,
        draw the bounded-retry survival from the round rng and carry the
        state through UNCHANGED on total failure.  A perfect link draws
        success with certainty, so jnp.where(True, new, old) keeps the
        legacy trajectory bitwise."""
        import jax.numpy as jnp
        topo_full = topology_lib.resolve(topology, cfg)
        if not linkfault.active(topo_full, cfg, train=True):
            return round_fn
        attempts = self.max_link_retries + 1

        def faulty_round(state, views, labels, rng):
            new_state, metrics = round_fn(state, views, labels, rng)
            ok = linkfault.round_success(rng, topo_full, cfg, attempts)
            new_state = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                     new_state, state)
            return new_state, metrics
        return faulty_round

    def _make_raw_round(self, cfg, *, lr: float, wire: str):
        """The fault-free round body (no link-survival wrapper)."""
        oc, osrv = optim.adam(lr), optim.adam(lr)
        step = sl.make_train_step(
            oc, osrv, link_bits=cfg.link_bits, wire=wire,
            compute_dtype=getattr(cfg, "compute_dtype", "fp32"))

        def round_fn(state, views, labels, rng):
            client, server, st, opt_c, opt_s, metrics = step(
                state["client"], state["server"], state["state"],
                state["opt_c"], state["opt_s"], views[0], labels[0], rng)
            return ({"client": client, "server": server, "state": st,
                     "opt_c": opt_c, "opt_s": opt_s}, metrics)
        return round_fn

    def make_round(self, cfg, *, lr: float = 2e-3, wire: str = "dense",
                   topology=None):
        # SL's cut is ONE client->server boundary (all conv branches live on
        # the active client), so only the star topology has a reading here
        topology_lib.require_star(topology, cfg, scheme=self.name)
        return self._skip_failed_round(
            cfg, topology, self._make_raw_round(cfg, lr=lr, wire=wire))

    def make_transport_round(self, cfg, *, lr: float = 2e-3,
                             wire: str = "dense", topology=None):
        # SL under a transport: the round's exchange rides the single
        # client->server boundary, so it has no partial reading — the round
        # RUNS iff every link delivered (the transport already spent the
        # retry budget), else the state carries through unchanged and the
        # whole round is lost.  The SL half of the one-vote-vs-whole-round
        # comparison.
        import jax.numpy as jnp
        topology_lib.require_star(topology, cfg, scheme=self.name)
        inner = self._make_raw_round(cfg, lr=lr, wire=wire)

        def round_fn(state, views, labels, rng, delivery):
            new_state, metrics = inner(state, views, labels, rng)
            ok = jnp.all(delivery)
            new_state = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                     new_state, state)
            return new_state, metrics
        return round_fn

    def make_sharded_round(self, cfg, mesh, *, lr: float = 2e-3,
                           wire: str = "dense", topology=None):
        # SL is sequential client/server by construction; the batch shards
        # over 'data' (params replicated — the base state_shardings default)
        from repro.core import sharded
        topology_lib.require_star(topology, cfg, scheme=self.name)
        inner = sharded.make_sl_sharded_round(cfg, mesh, optim.adam(lr),
                                              optim.adam(lr), wire=wire)
        return self._skip_failed_round(cfg, topology, inner)

    def predict(self, state, views, topology=None, cfg=None):
        return sl.predict(state["client"], state["server"], state["state"],
                          views)

    def bits_per_round(self, cfg, state, batch_size: int, *,
                       topology=None) -> float:
        topology_lib.require_star(topology, cfg, scheme=self.name)
        # activation/error traffic only (eta = 0 cancels the hand-off term)
        p = cfg.num_clients * cfg.d_bottleneck
        N = paper_model.fl_param_count(cfg)
        return bandwidth.sl_epoch_bits(p, batch_size, N, cfg.num_clients,
                                       0.0, cfg.link_bits)

    def epoch_overhead_bits(self, cfg, state) -> float:
        # q = 0 isolates the eta*N*J hand-off term; eta*N == client params
        p = cfg.num_clients * cfg.d_bottleneck
        N = paper_model.fl_param_count(cfg)
        eta = self.param_count(state["client"]) / N
        return bandwidth.sl_epoch_bits(p, 0, N, cfg.num_clients, eta,
                                       cfg.link_bits)

    def wire_bytes_per_round(self, cfg, state, batch_size: int, *,
                             wire: str = "dense", topology=None) -> float:
        # J*B deterministic cut d_b-vectors to the server, error vectors
        # back — same per-vector wire encoding as INL's exchange
        return wirefmt.round_wire_bytes(
            cfg.num_clients * batch_size, cfg.d_bottleneck,
            link_bits=cfg.link_bits, wire=wire,
            dtype=paper_model.compute_dtype(cfg))["total"]

    def epoch_overhead_wire_bytes(self, cfg, state) -> float:
        # the J sequential client->client hand-offs each move the actual
        # client-side param buffers (fp32 master weights — the wire format
        # does not quantize weight transfers)
        import jax
        client_nbytes = sum(x.size * x.dtype.itemsize
                            for x in jax.tree.leaves(state["client"]))
        return float(client_nbytes * cfg.num_clients)
