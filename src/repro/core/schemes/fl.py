"""Federated learning (FedAvg) behind the unified Scheme API (wraps
core/fl.py).

One round == one FedAvg round: each of the J clients takes `local_steps`
optimizer steps on its own minibatches, then the server averages weights
and re-broadcasts — so one round consumes J * local_steps minibatches and
moves 2 N J s bits (full weights down + up, Table I).  Per the paper's
Exp-2 setting, client j only observes its own noise level: its view of the
batch images is broadcast to all J branch inputs of the full Fig.-4 model.
Inference is central: the aggregated model on the average-quality view.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import bandwidth, fl, linkfault, paper_model
from repro.core import schemes as _schemes
from repro.core import topology as topology_lib
from repro.core.schemes import base
from repro.data import multiview


def _pack_exp2_views(views, labels, J: int, ls: int):
    """(R, J, B, ...) round views -> FedAvg packing: client j takes
    minibatches [j*ls, (j+1)*ls) and sees only ITS view of them, broadcast
    to the model's J branch inputs (paper Exp-2).  Returns
    ((J, ls, J, B, ...) views, (J, ls, B) labels)."""
    B = views.shape[2]
    v5 = views.reshape((J, ls) + views.shape[1:])
    own = v5[jnp.arange(J)[:, None], jnp.arange(ls)[None, :],
             jnp.arange(J)[:, None]]               # (J, ls, B, ...)
    packed = jnp.broadcast_to(own[:, :, None], (J, ls, J) + own.shape[2:])
    return packed, labels.reshape(J, ls, B)


@_schemes.register
class FLScheme(base.Scheme):
    name = "fl"
    local_steps = 2

    def batches_per_round(self, cfg) -> int:
        return cfg.num_clients * self.local_steps

    def init(self, cfg, key, *, lr: float = 2e-3):
        params, state = fl.init(cfg, key)
        opt = optim.adam(lr)
        return {"params": params, "state": state,
                "opt": jax.vmap(opt.init)(params)}

    def make_round(self, cfg, *, lr: float = 2e-3, wire: str = "dense",
                   topology=None):
        # FL has no cut-layer exchange: the wire carries full fp32 weights
        # (quantized FedAvg would be a different algorithm), so `wire` is
        # accepted for interface parity and ignored; the weight exchange is
        # a client<->server star by definition, so non-star topologies are
        # rejected up front.  A star whose edges carry LinkModels (or
        # cfg.edge_dropout > 0) IS accepted: dropped uplinks mask their
        # client's weights out of the FedAvg average
        # (core/linkfault.client_delivery_mask; all lost keeps the
        # previous global model).
        topology_lib.require_star(topology, cfg, scheme=self.name)
        topo_full = topology_lib.resolve(topology, cfg)
        faulty = linkfault.active(topo_full, cfg, train=True)
        opt = optim.adam(lr)
        round_impl = fl.make_round(cfg, opt, self.local_steps, faulty=faulty)
        J, ls = cfg.num_clients, self.local_steps

        @jax.jit
        def round_fn(state, views, labels, rng):
            packed, lab = _pack_exp2_views(views, labels, J, ls)
            rngs = jax.random.split(rng, J)
            args = (state["params"], state["state"], state["opt"],
                    packed, lab, rngs)
            if faulty:
                mask = linkfault.client_delivery_mask(rng, topo_full, cfg,
                                                      train=True)
                params, st, opt_state, metrics = round_impl(*args, mask)
            else:
                params, st, opt_state, metrics = round_impl(*args)
            return ({"params": params, "state": st, "opt": opt_state},
                    metrics)
        return round_fn

    def make_transport_round(self, cfg, *, lr: float = 2e-3,
                             wire: str = "dense", topology=None):
        # FL under a transport: the (J,) delivery verdict is the set of
        # client uploads that ARRIVED — missing clients are dropped from
        # the FedAvg average and their whole round of local work is lost
        # (all-lost keeps the previous global model).  The whole-round
        # granularity is the FL half of the one-vote-vs-whole-round
        # comparison the chaos bench quantifies.
        topology_lib.require_star(topology, cfg, scheme=self.name)
        opt = optim.adam(lr)
        round_impl = fl.make_round(cfg, opt, self.local_steps, faulty=True)
        J, ls = cfg.num_clients, self.local_steps

        @jax.jit
        def round_fn(state, views, labels, rng, delivery):
            packed, lab = _pack_exp2_views(views, labels, J, ls)
            rngs = jax.random.split(rng, J)
            params, st, opt_state, metrics = round_impl(
                state["params"], state["state"], state["opt"],
                packed, lab, rngs, delivery)
            return ({"params": params, "state": st, "opt": opt_state},
                    metrics)
        return round_fn

    def make_sharded_round(self, cfg, mesh, *, lr: float = 2e-3,
                           wire: str = "dense", topology=None):
        from repro.core import sharded
        topology_lib.require_star(topology, cfg, scheme=self.name)
        return sharded.make_fl_sharded_round(cfg, mesh, optim.adam(lr),
                                             self.local_steps,
                                             topology=topology)

    def state_shardings(self, cfg, state, mesh):
        # every FL state leaf is a stacked per-client replica (leading J):
        # params, model state, and the vmapped optimizer state all shard
        # over 'client'
        from jax.sharding import NamedSharding, PartitionSpec as P
        cl = NamedSharding(mesh, P("client"))
        return jax.tree.map(lambda _: cl, state)

    def predict(self, state, views, topology=None, cfg=None):
        # FL inference is central: aggregated model, average-quality view
        return fl.predict(state["params"], state["state"],
                          multiview.average_view(views))

    def bits_per_round(self, cfg, state, batch_size: int, *,
                       topology=None) -> float:
        topology_lib.require_star(topology, cfg, scheme=self.name)
        N = paper_model.fl_param_count(cfg)
        return bandwidth.fl_round_bits(N, cfg.num_clients, cfg.link_bits)

    def wire_bytes_per_round(self, cfg, state, batch_size: int, *,
                             wire: str = "dense", topology=None) -> float:
        # weights down + weights up for every client, at the buffers'
        # actual (fp32 master) sizes — FL keeps a full-precision exchange
        # regardless of the wire format
        stacked_nbytes = sum(x.size * x.dtype.itemsize
                             for x in jax.tree.leaves(state["params"]))
        return float(2 * stacked_nbytes)      # leading J axis = per client
