"""The `Scheme` interface all registered training schemes implement.

A scheme's `state` is an opaque pytree (dict) bundling its parameters,
model state (e.g. BatchNorm running stats) and optimizer state(s); only the
scheme itself looks inside.  The runner interacts purely through the
interface, so schemes with wildly different structure (INL's stacked
encoders, FL's per-client model copies, SL's client/server split) drive the
same benchmark loop.

Rounds vs batches: a "round" is the scheme's natural training transaction —
one optimizer step for INL/SL, one full FedAvg round (local steps on every
client + server aggregation) for FL.  `batches_per_round` tells the runner
how many (views, labels) minibatches to stack into one round call; the
round receives them as (R, J, B, ...) / (R, B) arrays.

Bandwidth: `bits_per_round` must route through the closed-form §III-C /
Table-I accounting in `core/bandwidth.py` (tests/test_scheme_parity.py
asserts exact agreement), so the measured curves and the published formulas
cannot drift apart.
"""
from __future__ import annotations

from typing import Any, Tuple


class Scheme:
    """Base class: override the five methods; keep `state` a pure pytree."""

    name: str = ""

    def batches_per_round(self, cfg) -> int:
        """Minibatches one round consumes (the runner stacks this many)."""
        return 1

    def init(self, cfg, key, *, lr: float = 2e-3) -> Any:
        """Build params + optimizer state for `cfg` (PaperExperimentConfig).

        Must be deterministic in `key`; `lr` must match `make_round`'s."""
        raise NotImplementedError

    def make_round(self, cfg, *, lr: float = 2e-3, wire: str = "dense"):
        """Return a jitted round_fn(state, views, labels, rng) ->
        (new_state, metrics) with views (R, J, B, H, W, C), labels (R, B),
        R == batches_per_round(cfg).  metrics must include "loss".

        wire — the cut-layer link format (core/wirefmt.py): "dense" moves
        quantized values at their storage dtype (the golden baseline),
        "packed" moves bit-packed codewords (trajectory bit-identical),
        "packed_duplex" packs the backward error vectors too.  Schemes
        without a cut-layer exchange (FL's weight transfer) ignore it."""
        raise NotImplementedError

    def make_sharded_round(self, cfg, mesh, *, lr: float = 2e-3,
                           wire: str = "dense"):
        """Round with the same signature/semantics as make_round's, executed
        across a ('client', 'data') mesh via shard_map (core/sharded.py):
        the J client branches on 'client', the batch on 'data'.  Must match
        the single-device round's trajectory at rtol 1e-4 (bit-exact for
        wire="packed" vs "dense" — packing is a re-encoding)."""
        raise NotImplementedError(f"scheme {self.name!r} has no sharded "
                                  "round")

    def make_epoch(self, cfg, *, lr: float = 2e-3, mesh=None, donate=None,
                   wire: str = "dense"):
        """K rounds in ONE jitted lax.scan — the whole-epoch dispatch unit.

        Returns epoch_fn(state, views, labels, rngs) -> (state, metrics)
        with views (K, R, J, B, ...), labels (K, R, B), rngs (K,) PRNG keys
        (one per round, the same chain the per-round path splits), and
        metrics stacked (K,) leaves.  mesh switches the body to the
        shard_map round; wire selects the cut-layer link format for every
        round in the scan.  donate=None donates (params/opt buffers reused
        in-place) on accelerators only — CPU XLA cannot alias and would
        warn."""
        import jax
        round_fn = (self.make_sharded_round(cfg, mesh, lr=lr, wire=wire)
                    if mesh is not None
                    else self.make_round(cfg, lr=lr, wire=wire))

        def epoch_fn(state, views, labels, rngs):
            def body(st, xs):
                v, lab, r = xs
                st, metrics = round_fn(st, v, lab, r)
                return st, metrics
            return jax.lax.scan(body, state, (views, labels, rngs))

        if donate is None:
            donate = jax.default_backend() != "cpu"
        return jax.jit(epoch_fn, donate_argnums=(0,) if donate else ())

    def state_shardings(self, cfg, state, mesh):
        """NamedSharding layout for this scheme's state on `mesh` (leading-J
        leaves on 'client' where the sharded round expects them).  Default:
        fully replicated."""
        from jax.sharding import NamedSharding, PartitionSpec
        import jax
        rep = NamedSharding(mesh, PartitionSpec())
        return jax.tree.map(lambda _: rep, state)

    def predict(self, state, views) -> Any:
        """views (J, B, ...) -> class probabilities (B, C); rows sum to 1.

        Each scheme applies its own inference convention (INL: deterministic
        latents; FL: central model on the average-quality view; SL: client
        forward + server decoder)."""
        raise NotImplementedError

    def bits_per_round(self, cfg, state, batch_size: int) -> float:
        """Bits moved by ONE round, via core/bandwidth.py closed forms."""
        raise NotImplementedError

    def epoch_overhead_bits(self, cfg, state) -> float:
        """Bits charged once per epoch on top of the per-round cost
        (split learning's sequential weight hand-offs).  Default 0."""
        return 0.0

    def wire_bytes_per_round(self, cfg, state, batch_size: int, *,
                             wire: str = "dense") -> float:
        """MEASURED bytes one round actually puts on the wire under `wire`
        — the nbytes of the transmitted buffers (core/wirefmt.py derives
        them from the real wire ops), not the closed-form accounting.
        tests/test_scheme_parity.py asserts the two ledgers agree whenever
        the wire carries what the formulas charge (packed links, fp32
        weight exchanges)."""
        raise NotImplementedError

    def epoch_overhead_wire_bytes(self, cfg, state) -> float:
        """Measured bytes of the once-per-epoch transfers (SL's weight
        hand-offs: the actual nbytes of the client param buffers).
        Default 0."""
        return 0.0

    # -- conveniences shared by implementations ---------------------------

    @staticmethod
    def param_count(tree) -> int:
        import jax
        return sum(int(x.size) for x in jax.tree.leaves(tree))

    def __repr__(self):
        return f"<Scheme {self.name!r}>"


def evaluate_accuracy(scheme: Scheme, state, views, labels) -> float:
    """Shared top-1 accuracy via the scheme's own predict convention.

    The predict forward is jitted once per scheme (cached on the registry
    singleton) — the per-epoch eval in the runner would otherwise run the
    whole encoder/decoder stack op-by-op."""
    import jax
    import jax.numpy as jnp
    jitted = scheme.__dict__.get("_predict_jit")
    if jitted is None:
        jitted = scheme._predict_jit = jax.jit(scheme.predict)
    probs = jitted(state, views)
    return float((jnp.argmax(probs, axis=-1) == labels).mean())
