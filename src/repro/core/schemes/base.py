"""The `Scheme` interface all registered training schemes implement.

A scheme's `state` is an opaque pytree (dict) bundling its parameters,
model state (e.g. BatchNorm running stats) and optimizer state(s); only the
scheme itself looks inside.  The runner interacts purely through the
interface, so schemes with wildly different structure (INL's stacked
encoders, FL's per-client model copies, SL's client/server split) drive the
same benchmark loop.

Rounds vs batches: a "round" is the scheme's natural training transaction —
one optimizer step for INL/SL, one full FedAvg round (local steps on every
client + server aggregation) for FL.  `batches_per_round` tells the runner
how many (views, labels) minibatches to stack into one round call; the
round receives them as (R, J, B, ...) / (R, B) arrays.

Bandwidth: `bits_per_round` must route through the closed-form §III-C /
Table-I accounting in `core/bandwidth.py` (tests/test_scheme_parity.py
asserts exact agreement), so the measured curves and the published formulas
cannot drift apart.
"""
from __future__ import annotations

from typing import Any, Tuple


class Scheme:
    """Base class: override the five methods; keep `state` a pure pytree."""

    name: str = ""

    def batches_per_round(self, cfg) -> int:
        """Minibatches one round consumes (the runner stacks this many)."""
        return 1

    def init(self, cfg, key, *, lr: float = 2e-3) -> Any:
        """Build params + optimizer state for `cfg` (PaperExperimentConfig).

        Must be deterministic in `key`; `lr` must match `make_round`'s."""
        raise NotImplementedError

    def make_round(self, cfg, *, lr: float = 2e-3):
        """Return a jitted round_fn(state, views, labels, rng) ->
        (new_state, metrics) with views (R, J, B, H, W, C), labels (R, B),
        R == batches_per_round(cfg).  metrics must include "loss"."""
        raise NotImplementedError

    def predict(self, state, views) -> Any:
        """views (J, B, ...) -> class probabilities (B, C); rows sum to 1.

        Each scheme applies its own inference convention (INL: deterministic
        latents; FL: central model on the average-quality view; SL: client
        forward + server decoder)."""
        raise NotImplementedError

    def bits_per_round(self, cfg, state, batch_size: int) -> float:
        """Bits moved by ONE round, via core/bandwidth.py closed forms."""
        raise NotImplementedError

    def epoch_overhead_bits(self, cfg, state) -> float:
        """Bits charged once per epoch on top of the per-round cost
        (split learning's sequential weight hand-offs).  Default 0."""
        return 0.0

    # -- conveniences shared by implementations ---------------------------

    @staticmethod
    def param_count(tree) -> int:
        import jax
        return sum(int(x.size) for x in jax.tree.leaves(tree))

    def __repr__(self):
        return f"<Scheme {self.name!r}>"


def evaluate_accuracy(scheme: Scheme, state, views, labels) -> float:
    """Shared top-1 accuracy via the scheme's own predict convention."""
    import jax.numpy as jnp
    probs = scheme.predict(state, views)
    return float((jnp.argmax(probs, axis=-1) == labels).mean())
