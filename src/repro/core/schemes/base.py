"""The `Scheme` interface all registered training schemes implement.

A scheme's `state` is an opaque pytree (dict) bundling its parameters,
model state (e.g. BatchNorm running stats) and optimizer state(s); only the
scheme itself looks inside.  The runner interacts purely through the
interface, so schemes with wildly different structure (INL's stacked
encoders, FL's per-client model copies, SL's client/server split) drive the
same benchmark loop.

Rounds vs batches: a "round" is the scheme's natural training transaction —
one optimizer step for INL/SL, one full FedAvg round (local steps on every
client + server aggregation) for FL.  `batches_per_round` tells the runner
how many (views, labels) minibatches to stack into one round call; the
round receives them as (R, J, B, ...) / (R, B) arrays.

Bandwidth: `bits_per_round` must route through the closed-form §III-C /
Table-I accounting in `core/bandwidth.py` (tests/test_scheme_parity.py
asserts exact agreement), so the measured curves and the published formulas
cannot drift apart.

Topology: every entry point accepts `topology=` (a core/topology.Topology;
None resolves to cfg.topology, then the implicit `star(cfg.num_clients)`).
The default star dispatches to the pre-topology code paths bit for bit;
INL compiles non-star graphs to multi-hop execution and per-edge
accounting (`edge_ledger`), while schemes whose exchange has no multi-hop
reading validate the topology is a star and raise otherwise.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax


class Scheme:
    """Base class: override the five methods; keep `state` a pure pytree."""

    name: str = ""

    def batches_per_round(self, cfg) -> int:
        """Minibatches one round consumes (the runner stacks this many)."""
        return 1

    def init(self, cfg, key, *, lr: float = 2e-3) -> Any:
        """Build params + optimizer state for `cfg` (PaperExperimentConfig).

        Must be deterministic in `key`; `lr` must match `make_round`'s."""
        raise NotImplementedError

    def make_round(self, cfg, *, lr: float = 2e-3, wire: str = "dense",
                   topology=None):
        """Return a jitted round_fn(state, views, labels, rng) ->
        (new_state, metrics) with views (R, J, B, H, W, C), labels (R, B),
        R == batches_per_round(cfg).  metrics must include "loss".

        wire — the cut-layer link format (core/wirefmt.py): "dense" moves
        quantized values at their storage dtype (the golden baseline),
        "packed" moves bit-packed codewords (trajectory bit-identical),
        "packed_duplex" packs the backward error vectors too.  Schemes
        without a cut-layer exchange (FL's weight transfer) ignore it.

        topology — the inference graph (core/topology.py); the default
        star keeps the pre-topology round bit for bit."""
        raise NotImplementedError

    def make_transport_round(self, cfg, *, lr: float = 2e-3,
                             wire: str = "dense", topology=None):
        """Return round_fn(state, views, labels, rng, delivery) ->
        (new_state, metrics): `make_round` with the fault outcome as an
        EXPLICIT (J,) boolean argument instead of an in-graph draw.

        `delivery` is the transport layer's measured verdict for this
        round (repro/transport.NetworkTransport.round_outcome — retries,
        circuit breakers and chaos already applied).  Each scheme applies
        its own degradation semantics to the same mask: INL partial-fuses
        the surviving views (one vote lost per failed route), FL drops the
        missing clients from the FedAvg average (their whole round of
        local work lost), SL carries the state through unchanged unless
        every link delivered (the whole round lost) — the comparison the
        chaos bench quantifies."""
        raise NotImplementedError(f"scheme {self.name!r} has no "
                                  "transport round")

    def make_sharded_round(self, cfg, mesh, *, lr: float = 2e-3,
                           wire: str = "dense", topology=None):
        """Round with the same signature/semantics as make_round's, executed
        across a ('client', 'data') mesh via shard_map (core/sharded.py):
        the J client branches on 'client', the batch on 'data'.  Must match
        the single-device round's trajectory at rtol 1e-4 (bit-exact for
        wire="packed" vs "dense" — packing is a re-encoding)."""
        raise NotImplementedError(f"scheme {self.name!r} has no sharded "
                                  "round")

    def make_epoch(self, cfg, *, lr: float = 2e-3, mesh=None, donate=None,
                   wire: str = "dense", topology=None):
        """K rounds in ONE jitted lax.scan — the whole-epoch dispatch unit.

        Returns epoch_fn(state, views, labels, rngs) -> (state, metrics)
        with views (K, R, J, B, ...), labels (K, R, B), rngs (K,) PRNG keys
        (one per round, the same chain the per-round path splits), and
        metrics stacked (K,) leaves.  mesh switches the body to the
        shard_map round; wire selects the cut-layer link format and
        topology the inference graph for every round in the scan.
        donate=None donates (params/opt buffers reused in-place) on
        accelerators only — CPU XLA cannot alias and would warn."""
        round_fn = (self.make_sharded_round(cfg, mesh, lr=lr, wire=wire,
                                            topology=topology)
                    if mesh is not None
                    else self.make_round(cfg, lr=lr, wire=wire,
                                         topology=topology))

        def epoch_fn(state, views, labels, rngs):
            def body(st, xs):
                v, lab, r = xs
                st, metrics = round_fn(st, v, lab, r)
                return st, metrics
            return jax.lax.scan(body, state, (views, labels, rngs))

        if donate is None:
            donate = jax.default_backend() != "cpu"
        return jax.jit(epoch_fn, donate_argnums=(0,) if donate else ())

    def state_shardings(self, cfg, state, mesh):
        """NamedSharding layout for this scheme's state on `mesh` (leading-J
        leaves on 'client' where the sharded round expects them).  Default:
        fully replicated."""
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        return jax.tree.map(lambda _: rep, state)

    def predict(self, state, views, topology=None, cfg=None) -> Any:
        """views (J, B, ...) -> class probabilities (B, C); rows sum to 1.

        Each scheme applies its own inference convention (INL: deterministic
        latents, routed through the topology's hops when one is given — that
        path needs `cfg` for the edge-width defaults; FL: central model on
        the average-quality view; SL: client forward + server decoder)."""
        raise NotImplementedError

    # serving bucket sizes (repro/serving): in-flight requests are padded
    # to the smallest bucket, so the engine jits at most ONE predict per
    # bucket size — no retracing under request churn
    serve_buckets: Tuple[int, ...] = (1, 4, 16, 64)

    def predict_batched(self, state, views, *, delivery=None, topology=None,
                        cfg=None, wire: str = "dense") -> Any:
        """The serving plane's batched inference entry (repro/serving):
        `predict` plus an optional (J,) or (J, B) per-request delivery mask
        and the serving wire format.

        delivery=None is the clean network and MUST equal `predict` bit for
        bit — the engine's bucket-padding parity test pins it.  Default
        masked semantics (single-uplink schemes: FL's central model, SL's
        one boundary): a request answers only if its whole uplink payload
        arrived — any dropped view degrades it to the uniform distribution.
        INL overrides with per-request partial fusion (a lost view costs
        one vote, not the request) and threads `wire` through its graph
        hops."""
        import jax.numpy as jnp
        from repro.core import linkfault
        probs = self.predict(state, views, topology=topology, cfg=cfg)
        if delivery is None:
            return probs
        ok = jnp.all(delivery, axis=0)
        return linkfault.degrade_probs(probs, ok)

    def predict_under_faults(self, state, views, key, topology=None,
                             cfg=None) -> Any:
        """`predict` when the topology's links are unreliable
        (core/linkfault.py): per-request fault draws from `key` decide what
        the decoding side actually receives.

        Default (FL's central model, SL's client->server boundary): the
        answer rides ONE uplink — requests whose erasure/deadline draw
        fails get the uninformative uniform distribution (the server
        answers, but not from this request's data).  INL overrides with
        per-sample partial fusion: only the views that failed are masked,
        the survivors still vote — the graceful-degradation gap the
        links benchmark measures.  A topology with no LinkModels (and no
        deadline) reduces to plain `predict` for every scheme."""
        from repro.core import linkfault
        from repro.core import topology as topology_lib
        probs = self.predict(state, views, topology=topology, cfg=cfg)
        topo = topology_lib.resolve(topology, cfg)
        ok = linkfault.request_survival(key, topo, cfg, views.shape[1])
        return linkfault.degrade_probs(probs, ok)

    def bits_per_round(self, cfg, state, batch_size: int, *,
                       topology=None) -> float:
        """Bits moved by ONE round, via core/bandwidth.py closed forms (a
        non-star topology sums its per-edge charges — identical for the
        star)."""
        raise NotImplementedError

    def epoch_overhead_bits(self, cfg, state) -> float:
        """Bits charged once per epoch on top of the per-round cost
        (split learning's sequential weight hand-offs).  Default 0."""
        return 0.0

    def wire_bytes_per_round(self, cfg, state, batch_size: int, *,
                             wire: str = "dense", topology=None) -> float:
        """MEASURED bytes one round actually puts on the wire under `wire`
        — the nbytes of the transmitted buffers (core/wirefmt.py derives
        them from the real wire ops), not the closed-form accounting.
        tests/test_scheme_parity.py asserts the two ledgers agree whenever
        the wire carries what the formulas charge (packed links, fp32
        weight exchanges)."""
        raise NotImplementedError

    def epoch_overhead_wire_bytes(self, cfg, state) -> float:
        """Measured bytes of the once-per-epoch transfers (SL's weight
        hand-offs: the actual nbytes of the client param buffers).
        Default 0."""
        return 0.0

    def edge_ledger(self, cfg, state, batch_size: int, *,
                    wire: str = "dense",
                    topology=None) -> Optional[Dict[str, Tuple[float,
                                                               float]]]:
        """Per-edge bandwidth of one round: {edge_key: (closed-form bits,
        measured wire bytes)}, summing to bits_per_round /
        wire_bytes_per_round exactly.  None (the default) for schemes whose
        exchange has no per-edge decomposition — the runner then meters
        totals only."""
        return None

    # -- conveniences shared by implementations ---------------------------

    @staticmethod
    def param_count(tree) -> int:
        return sum(int(x.size) for x in jax.tree.leaves(tree))

    def __repr__(self):
        return f"<Scheme {self.name!r}>"


# One jitted predict per (scheme, topology, cfg): topology and cfg are
# closed over as statics (they change the traced graph), while state/views
# changes hit jax.jit's OWN cache — a new treedef or shape retraces, so
# switching cfgs mid-process can never reuse a stale closure (the former
# cache pinned the first-ever jitted predict on the registry singleton
# forever).  LRU-bounded so a config sweep (placement search over
# (topology, width) settings) cannot grow it monotonically.
_PREDICT_JIT: dict = {}
_PREDICT_JIT_CAP = 32


def evaluate_accuracy(scheme: Scheme, state, views, labels,
                      topology=None, cfg=None) -> float:
    """Shared top-1 accuracy via the scheme's own predict convention.

    The predict forward is jitted once per (scheme, topology, cfg) — the
    per-epoch eval in the runner would otherwise run the whole
    encoder/decoder stack op-by-op."""
    import jax.numpy as jnp
    key = (scheme.name, topology, cfg)
    jitted = _PREDICT_JIT.pop(key, None)
    if jitted is None:
        def _predict(st, v):
            return scheme.predict(st, v, topology=topology, cfg=cfg)
        jitted = jax.jit(_predict)
    _PREDICT_JIT[key] = jitted                   # most-recently-used last
    while len(_PREDICT_JIT) > _PREDICT_JIT_CAP:
        _PREDICT_JIT.pop(next(iter(_PREDICT_JIT)))
    probs = jitted(state, views)
    return float((jnp.argmax(probs, axis=-1) == labels).mean())


def evaluate_accuracy_under_faults(scheme: Scheme, state, views, labels,
                                   key, topology=None, cfg=None) -> float:
    """Top-1 accuracy through `predict_under_faults`: the per-request fault
    draws come from `key` (a PRNG key — vary it to average over network
    realisations).  Jitted per (scheme, topology, cfg) like
    evaluate_accuracy, with the key a traced argument."""
    import jax.numpy as jnp
    cache_key = ("faults", scheme.name, topology, cfg)
    jitted = _PREDICT_JIT.pop(cache_key, None)
    if jitted is None:
        def _predict(st, v, k):
            return scheme.predict_under_faults(st, v, k, topology=topology,
                                               cfg=cfg)
        jitted = jax.jit(_predict)
    _PREDICT_JIT[cache_key] = jitted
    while len(_PREDICT_JIT) > _PREDICT_JIT_CAP:
        _PREDICT_JIT.pop(next(iter(_PREDICT_JIT)))
    probs = jitted(state, views, key)
    return float((jnp.argmax(probs, axis=-1) == labels).mean())
