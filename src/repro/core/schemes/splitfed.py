"""SplitFed behind the unified Scheme API (Thapa et al.'s SplitFedV1
synchronisation, recast on the paper's multi-view setting).

One round == one parallel SL-style step against a shared server stub PLUS
one FedAvg of the client-side weights: every client encoder ships its
DETERMINISTIC cut-layer activations (the fused kernel's no-noise mode —
`wirefmt.cut_and_ship(key=None, ...)`, the same substrate SL's boundary
uses) to the server decoder, the eq.-(10) error chunks flow back per
client, each client applies its optimizer step, and the freshly-updated
client encoders are averaged and re-broadcast.  Bandwidth per round is
therefore the INL-style cut exchange (per-edge, wire-encoded) PLUS an
FL-style fp32 weight exchange of the (small) client-side network — both
decomposed per edge in `edge_ledger`, closed == measured by construction.

`cfg.cut_depth` picks how many conv blocks stay client-side (`client_cfg`
truncates the trunk); None keeps the full trunk — the classic boundary
right before the bottleneck head.

Under faults a dead route costs BOTH exchanges: the client's activations
drop out of the fusion (partial_fuse renormalises over survivors) and its
weights drop out of the round's average (masked FedAvg; the stranded
client keeps its local update and rejoins when the route heals).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import bottleneck, linkfault, losses, paper_model, wirefmt
from repro.core import schemes as _schemes
from repro.core import topology as topology_lib
from repro.core.schemes import base


def client_cfg(cfg):
    """The config the CLIENT-side network is built from: conv trunk
    truncated to the first `cfg.cut_depth` blocks (None = full trunk)."""
    k = getattr(cfg, "cut_depth", None)
    if k is None:
        return cfg
    k = int(k)
    if not 1 <= k <= len(cfg.conv_channels):
        raise ValueError(
            f"cut_depth must be in [1, {len(cfg.conv_channels)}] (the conv "
            f"trunk has {len(cfg.conv_channels)} blocks), got {k}")
    return dataclasses.replace(cfg, cut_depth=None,
                               conv_channels=cfg.conv_channels[:k])


def tree_nbytes(tree) -> float:
    return float(sum(x.size * jnp.dtype(x.dtype).itemsize
                     for x in jax.tree.leaves(tree)))


def fedavg(new, old, mask):
    """Masked FedAvg over the stacked leading-J axis: surviving clients
    (mask) receive the average of the survivors' updates, dead routes keep
    their LOCAL update (they neither uploaded nor heard the broadcast).
    With an all-ones mask every client gets sum/J — bitwise the unfaulted
    plain average, so perfect links cannot move a trajectory."""
    J = mask.shape[0]
    w = mask.astype(jnp.float32)
    n = jnp.sum(w)

    def avg(x, o):
        wx = w.reshape((J,) + (1,) * (x.ndim - 1))
        a = jnp.sum(x.astype(jnp.float32) * wx, axis=0) / jnp.maximum(n, 1.0)
        a = jnp.where(n > 0, a, o[0].astype(jnp.float32))
        bcast = jnp.broadcast_to(a, x.shape).astype(x.dtype)
        return jnp.where(wx > 0, bcast, x)

    return jax.tree.map(avg, new, old)


def _encode(params, state, views, *, train):
    return jax.vmap(
        lambda p, s, v: paper_model.encoder_apply(p, s, v, train=train)
    )(params, state, views)


def _fuse_cat(u_joint):
    J, B, d = u_joint.shape
    return jnp.moveaxis(u_joint, 0, 1).reshape(B, J * d)


@_schemes.register
class SplitFedScheme(base.Scheme):
    name = "splitfed"

    def init(self, cfg, key, *, lr: float = 2e-3):
        ccfg = client_cfg(cfg)
        k_enc, k_dec = jax.random.split(key)
        enc_p, enc_s = jax.vmap(
            lambda k: paper_model.encoder_init(k, ccfg)
        )(jax.random.split(k_enc, cfg.num_clients))
        params = {"encoders": enc_p, "decoder": paper_model.decoder_init(
            k_dec, cfg)}
        opt = optim.adam(lr)
        return {"params": params, "state": {"encoders": enc_s},
                "opt": opt.init(params)}

    # ------------------------------------------------------------------
    # training
    # ------------------------------------------------------------------

    def _loss(self, params, enc_state, views, labels, rng, cfg, *, wire,
              topo, delivery):
        dt = paper_model.compute_dtype(cfg)
        params_c = paper_model.cast_compute(params, dt)
        (mu, logvar), new_enc = _encode(params_c["encoders"],
                                        enc_state["encoders"],
                                        views.astype(dt), train=True)
        if topo is None:
            _, _, u_joint = wirefmt.cut_and_ship(
                None, mu, logvar, link_bits=cfg.link_bits,
                rate_estimator="none", wire=wire)
        else:
            _, _, u_joint = topology_lib.graph_cut_and_ship(
                topo, cfg, mu, logvar, jnp.zeros(mu.shape, jnp.float32),
                rate_estimator="none", wire=wire)
        if delivery is not None:
            u_joint = linkfault.partial_fuse(u_joint, delivery)
        logits = paper_model.decoder_apply(params_c["decoder"],
                                           _fuse_cat(u_joint), train=True,
                                           rng=rng)
        loss = losses.xent(logits, labels)
        metrics = {"loss": loss, "accuracy": losses.accuracy(logits, labels)}
        return loss, (metrics, {"encoders": new_enc})

    def _make_step(self, cfg, *, lr, wire, topology, explicit_delivery):
        opt = optim.adam(lr)
        topo_full = topology_lib.resolve(topology, cfg)
        topo = topology_lib.nontrivial(topology, cfg)
        faulty = linkfault.active(topo_full, cfg, train=True)

        @jax.jit
        def step(state, views, labels, rng, delivery):
            _, r_dec = jax.random.split(rng)
            grad_fn = jax.value_and_grad(self._loss, has_aux=True)
            (_, (metrics, new_enc)), grads = grad_fn(
                state["params"], state["state"], views, labels, r_dec, cfg,
                wire=wire, topo=topo, delivery=delivery)
            params, opt_state = opt.update(grads, state["opt"],
                                           state["params"])
            mask = jnp.ones((cfg.num_clients,), bool) if delivery is None \
                else delivery
            params = dict(params, encoders=fedavg(
                params["encoders"], state["params"]["encoders"], mask))
            return ({"params": params, "state": new_enc, "opt": opt_state},
                    metrics)

        if explicit_delivery:
            return step

        def round_fn(state, views, labels, rng):
            # the fault stream folds off rng (linkfault.fault_key) without
            # disturbing the round's own key consumption.  The no-fault
            # path ships an all-ones mask as a RUNTIME argument rather
            # than a trace-time None: a constant mask lets XLA fold the
            # masked FedAvg into a different (reciprocal-multiply)
            # division than the traced graph uses, so the two spellings
            # would differ in the last ulp — one traced graph keeps
            # perfect links bitwise identical to the fault-free run
            delivery = linkfault.round_delivery_mask(
                rng, topo_full, cfg, labels.shape[-1], train=True) \
                if faulty else jnp.ones((cfg.num_clients,), bool)
            return step(state, views, labels, rng, delivery)
        return round_fn

    def make_round(self, cfg, *, lr: float = 2e-3, wire: str = "dense",
                   topology=None):
        step = self._make_step(cfg, lr=lr, wire=wire, topology=topology,
                               explicit_delivery=False)

        def round_fn(state, views, labels, rng):
            return step(state, views[0], labels[0], rng)
        return round_fn

    def make_transport_round(self, cfg, *, lr: float = 2e-3,
                             wire: str = "dense", topology=None):
        # the transport's measured (J,) outcome masks BOTH of the round's
        # exchanges: a dead route's activations leave the fusion AND its
        # weights leave the average — one fault, two degradations
        step = self._make_step(cfg, lr=lr, wire=wire, topology=topology,
                               explicit_delivery=True)

        def round_fn(state, views, labels, rng, delivery):
            return step(state, views[0], labels[0], rng, delivery)
        return round_fn

    # ------------------------------------------------------------------
    # inference
    # ------------------------------------------------------------------

    def _predict(self, state, views, topology, cfg, delivery=None,
                 wire: str = "dense"):
        topo = None if cfg is None else topology_lib.nontrivial(topology,
                                                                cfg)
        (mu, logvar), _ = _encode(state["params"]["encoders"],
                                  state["state"]["encoders"], views,
                                  train=False)
        if topo is None:
            # the star ships unquantized at inference (INL's convention:
            # bottleneck.fused_sample_rate at the default 32-bit grid)
            u, _ = bottleneck.fused_sample_rate(None, mu, logvar,
                                                rate_estimator="none")
        else:
            _, _, u = topology_lib.graph_cut_and_ship(
                topo, cfg, mu, logvar, jnp.zeros(mu.shape, jnp.float32),
                rate_estimator="none", wire=wire)
        if delivery is not None:
            u = linkfault.partial_fuse(u, delivery)
        logits = paper_model.decoder_apply(state["params"]["decoder"],
                                           _fuse_cat(u), train=False)
        return jax.nn.softmax(logits, axis=-1)

    def predict(self, state, views, topology=None, cfg=None):
        return self._predict(state, views, topology, cfg)

    def predict_batched(self, state, views, *, delivery=None, topology=None,
                        cfg=None, wire: str = "dense"):
        return self._predict(state, views, topology, cfg, delivery=delivery,
                             wire=wire)

    def predict_under_faults(self, state, views, key, topology=None,
                             cfg=None):
        # like INL: each sample draws a (J,) route-survival mask and the
        # server fuses (renormalised) whatever arrived — one lost vote,
        # not a lost prediction
        topo_full = topology_lib.resolve(topology, cfg)
        delivery = linkfault.sample_delivery_mask(key, topo_full, cfg,
                                                  views.shape[1])
        return self._predict(state, views, topology, cfg, delivery=delivery)

    # ------------------------------------------------------------------
    # bandwidth
    # ------------------------------------------------------------------

    def _weight_charges(self, cfg, state):
        """(closed bits, measured bytes) ONE client's weight exchange costs
        per direction: the client-side encoder at fp32."""
        n_enc = paper_model.encoder_param_count(client_cfg(cfg))
        enc_nbytes = tree_nbytes(state["params"]["encoders"]) \
            / cfg.num_clients
        return 32.0 * n_enc, enc_nbytes

    def edge_ledger(self, cfg, state, batch_size: int, *,
                    wire: str = "dense", topology=None):
        # per edge: the cut exchange the edge's payload occupies (closed /
        # wire-measured, exactly INL's charge) + the FedAvg exchange of the
        # payload clients' encoders, fp32 both directions (up to the
        # server-side aggregator, averaged copy back down the same route)
        topo = topology_lib.resolve(topology, cfg)
        w_bits, w_nbytes = self._weight_charges(cfg, state)
        bits = topology_lib.round_edge_bits(topo, cfg, batch_size)
        nbytes = topology_lib.round_edge_wire_bytes(topo, cfg, batch_size,
                                                    wire=wire)
        out = {}
        for e in topo.topo_edges():
            k = len(topo.payload(e))
            out[e.key] = (bits[e.key] + 2.0 * k * w_bits,
                          nbytes[e.key] + 2.0 * k * w_nbytes)
        return out

    def bits_per_round(self, cfg, state, batch_size: int, *,
                       topology=None) -> float:
        return float(sum(b for b, _ in self.edge_ledger(
            cfg, state, batch_size, topology=topology).values()))

    def wire_bytes_per_round(self, cfg, state, batch_size: int, *,
                             wire: str = "dense", topology=None) -> float:
        return float(sum(n for _, n in self.edge_ledger(
            cfg, state, batch_size, wire=wire, topology=topology).values()))
