"""Registry-driven training/benchmark runner — ONE loop for every scheme.

The scheme supplies init / round / predict / bandwidth through the Scheme
interface; this module supplies the epoch pipeline, minibatch grouping, the
BandwidthMeter, and the accuracy-vs-epoch / accuracy-vs-Gbit curve — so a
newly registered scheme benchmarks itself with zero extra glue.

Dispatch strategies (the perf ladder tests/benchmarks compare):

    "per_round"  the seed-style loop: one host->device transfer + one jitted
                 dispatch per round (kept as the benchmark baseline);
    "scan"       the default: the whole epoch's rounds are stacked host-side
                 into ONE (K, R, ...) superbatch, moved through the
                 double-buffered prefetcher (data/prefetch.py), and executed
                 as ONE jitted lax.scan (Scheme.make_epoch) — K rounds per
                 dispatch instead of K dispatches.

`mesh` (a ('client', 'data') mesh from launch.mesh.make_inl_host_mesh /
make_inl_mesh) switches the scan body to the scheme's shard_map round
(core/sharded.py): J node branches in parallel over 'client', batch over
'data', state placed once via Scheme.state_shardings and batches device_put
pre-sharded by the prefetcher.  Trajectories match the single-device run at
rtol 1e-4 (tests/test_sharded_parity.py).
"""
from __future__ import annotations

from functools import partial
from typing import List, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as checkpoint_lib
from repro.core import bandwidth, linkfault
from repro.core import topology as topology_lib
from repro.core.schemes import base
from repro.data import multiview, prefetch


class CurvePoint(NamedTuple):
    epoch: int
    accuracy: float
    gbits: float                 # cumulative ACCOUNTED bits (§III-C), Gbit
    measured_gbits: float = 0.0  # cumulative MEASURED wire-buffer bits, Gbit
    delivered_gbits: float = 0.0  # what actually reached its consumer, Gbit


@partial(jax.jit, static_argnums=1)
def _split_chain(key, n: int):
    """n sequential (key, sub) splits in one dispatch — the exact chain the
    per-round loop produces with repeated jax.random.split(rng)."""
    def body(k, _):
        k, sub = jax.random.split(k)
        return k, sub
    return jax.lax.scan(body, key, None, length=n)


def _round_charges(scheme, cfg, state, batch_size, *, wire, topology):
    """ONE round's bandwidth charges, computed once per run (they depend
    only on static shapes, and the measured side runs 2 eval_shape traces
    per edge — per-round recomputation would tax the per_round dispatch
    baseline): the per-edge ledger where the scheme decomposes its
    exchange over the topology's links (INL; per-edge charges sum to the
    totals exactly), else the scalar totals."""
    ledger = scheme.edge_ledger(cfg, state, batch_size, wire=wire,
                                topology=topology)
    if ledger is not None:
        return ledger
    return {None: (scheme.bits_per_round(cfg, state, batch_size,
                                         topology=topology),
                   scheme.wire_bytes_per_round(cfg, state, batch_size,
                                               wire=wire,
                                               topology=topology))}


def _meter_rounds(meter, charges, rounds=1, delivered=None):
    """Charge `rounds` rounds of `charges` as offered traffic, and
    `delivered` (defaults to the same charges — the fault-free case where
    everything offered arrives) on the delivered ledger."""
    for edge, (bits, nbytes) in charges.items():
        if edge is None:
            meter.add(rounds * bits)
            meter.add_measured(rounds * nbytes)
        else:
            meter.add_edge(edge, bits=rounds * bits, nbytes=rounds * nbytes)
    for edge, (bits, nbytes) in (charges if delivered is None
                                 else delivered).items():
        meter.add_delivered(bits=rounds * bits, nbytes=rounds * nbytes,
                            edge=edge)


def _meter_fault_rounds(meter, scheme, topo_full, cfg, batch_size, charges,
                        round_keys):
    """Per-round fault metering: replay each round key's fault draws
    (linkfault.round_fault_charges folds the SAME keys the in-graph masks
    consume) and split the round between the offered and delivered
    ledgers."""
    for sub in round_keys:
        off, dlv = linkfault.round_fault_charges(
            jnp.asarray(sub), scheme.name, topo_full, cfg, batch_size,
            charges)
        _meter_rounds(meter, off, delivered=dlv)


def _meter_dump(meter) -> dict:
    """The meter's full ledger state, JSON-serialisable (resume context)."""
    return {"total_bits": meter.total_bits,
            "measured_bytes": meter.measured_bytes,
            "delivered_bits": meter.delivered_bits,
            "delivered_measured_bytes": meter.delivered_measured_bytes,
            "edge_bits": dict(meter.edge_bits),
            "edge_measured_bytes": dict(meter.edge_measured_bytes),
            "edge_delivered_bits": dict(meter.edge_delivered_bits)}


def _meter_load(meter, d: dict) -> None:
    meter.total_bits = float(d["total_bits"])
    meter.measured_bytes = float(d["measured_bytes"])
    meter.delivered_bits = float(d["delivered_bits"])
    meter.delivered_measured_bytes = float(d["delivered_measured_bytes"])
    meter.edge_bits = {k: float(v) for k, v in d["edge_bits"].items()}
    meter.edge_measured_bytes = {k: float(v) for k, v
                                 in d["edge_measured_bytes"].items()}
    meter.edge_delivered_bits = {k: float(v) for k, v
                                 in d["edge_delivered_bits"].items()}


def _save_epoch(ckpt_dir, name, ep, state, curve, meter,
                transport=None) -> None:
    """One epoch-granular checkpoint: the FULL training state (params,
    model state, optimizer) plus the curve and both meter ledgers in the
    sidecar — everything a bit-identical resume needs (fp32/int leaves are
    npz-lossless; bf16 stores as fp32 and round-trips bitwise).  A
    transport run also records `transport.snapshot()` — breaker counters
    for the record, adaptive-policy state for restore — so resumed runs
    replay the same retry/threshold knob trajectory."""
    extra = {"scheme": name, "epoch": ep,
             "curve": [list(map(float, p)) for p in curve],
             "meter": _meter_dump(meter)}
    if transport is not None:
        extra["transport"] = transport.snapshot()
    checkpoint_lib.save(ckpt_dir, ep, jax.device_get(state), extra=extra)


def _try_resume(ckpt_dir, state, meter):
    """Restore the latest epoch checkpoint when one exists: returns
    (state, curve-so-far, epochs-already-done, transport-snapshot-or-None).
    A fresh directory resumes from nothing — epoch 0 with the given init
    state."""
    step = checkpoint_lib.latest_step(ckpt_dir) if ckpt_dir else None
    if step is None:
        return state, [], 0, None
    restored, _ = checkpoint_lib.restore(ckpt_dir, jax.device_get(state),
                                         step=step)
    meta = checkpoint_lib.load_meta(ckpt_dir, step)
    curve = [CurvePoint(int(p[0]), *map(float, p[1:]))
             for p in meta["curve"]]
    _meter_load(meter, meta["meter"])
    return restored, curve, int(meta["epoch"]), meta.get("transport")


def _meter_overheads(meter, scheme, cfg, state):
    """Once-per-epoch charges (SL's weight hand-offs ride a reliable
    control channel here — charged and delivered in full)."""
    bits = scheme.epoch_overhead_bits(cfg, state)
    nbytes = scheme.epoch_overhead_wire_bytes(cfg, state)
    meter.add(bits)
    meter.add_measured(nbytes)
    meter.add_delivered(bits=bits, nbytes=nbytes)


def rounds_per_epoch(scheme, cfg, n: int, batch_size: int) -> int:
    """Rounds one epoch of an n-sample set runs: full minibatches grouped
    by the scheme's batches_per_round.  Public because the search
    subsystem's closed-form pricing (repro/search/pricing.py) must charge
    EXACTLY the rounds the runner will execute — one rule, two callers."""
    return (n // batch_size) // scheme.batches_per_round(cfg)


def run_scheme(name: str, views, labels, cfg, *, epochs: int,
               batch_size: int = 64, lr: float = 2e-3, seed: int = 0,
               eval_n: int = 512, dispatch: str = "scan", mesh=None,
               prefetch_size: int = 2, wire: str = "dense",
               topology=None, meter=None, transport=None,
               ckpt_dir=None, ckpt_every: int = 1,
               resume: bool = False) -> List[CurvePoint]:
    """Train scheme `name` for `epochs` over the (J, n, ...) multi-view set
    and return its accuracy/bandwidth curve (paper Figs. 5/7 rows).

    Minibatches are grouped `batches_per_round(cfg)` at a time into round
    calls; a trailing partial group is dropped (same rounding the paper's
    per-epoch accounting uses).  Bandwidth accrues on TWO ledgers: the
    §III-C closed forms (`gbits`, as published) and the MEASURED nbytes of
    the buffers the chosen wire format actually transmits per round
    (`measured_gbits`; Scheme.wire_bytes_per_round via core/wirefmt.py) —
    per EDGE where the scheme decomposes its exchange over the topology's
    links (pass `meter=` a BandwidthMeter to read the per-edge ledgers
    afterwards).

    dispatch="scan" (default) runs each epoch as one jitted lax.scan fed by
    the device prefetcher; dispatch="per_round" keeps the seed-style loop
    (one dispatch per round).  `mesh` enables shard_map execution (scan
    dispatch only).  wire="packed" moves the cut-layer collectives as
    bit-packed codewords (trajectories identical to dense);
    "packed_duplex" packs the backward error vectors too.  topology — a
    core/topology.Topology routing the INL exchange over a multi-hop graph
    (the default star reproduces the pre-topology behaviour bit for bit;
    FL/SL validate and reject non-star graphs).

    Elastic recovery: `ckpt_dir` saves an epoch-granular checkpoint every
    `ckpt_every` epochs (full state + curve + meter ledgers);
    `resume=True` restores the latest one and fast-forwards the data/rng
    streams, so the resumed trajectory is BIT-IDENTICAL to the
    uninterrupted run (tests/test_recovery.py pins it).

    transport — a repro/transport.NetworkTransport over the resolved
    topology: fault outcomes then come from the transport's retrying
    channels / breakers / chaos schedule per round instead of in-graph
    draws (Scheme.make_transport_round), metered on the transport's
    offered/delivered ledgers.  Transport execution is per-round
    (host-side masks), so it excludes mesh/scan dispatch.
    """
    from repro.core import schemes
    scheme = schemes.get(name)
    if transport is not None:
        if mesh is not None:
            raise ValueError("transport execution is per-round; no mesh")
        if meter is not None and meter is not transport.meter:
            raise ValueError("pass either meter= or transport= (the "
                             "transport owns the run's meter)")
        return _run_transport(scheme, views, labels, cfg, epochs=epochs,
                              batch_size=batch_size, lr=lr, seed=seed,
                              eval_n=eval_n, wire=wire, topology=topology,
                              transport=transport, ckpt_dir=ckpt_dir,
                              ckpt_every=ckpt_every, resume=resume)
    if dispatch == "per_round":
        if mesh is not None:
            raise ValueError("mesh execution needs dispatch='scan'")
        return _run_per_round(scheme, views, labels, cfg, epochs=epochs,
                              batch_size=batch_size, lr=lr, seed=seed,
                              eval_n=eval_n, wire=wire, topology=topology,
                              meter=meter, ckpt_dir=ckpt_dir,
                              ckpt_every=ckpt_every, resume=resume)
    if dispatch != "scan":
        raise ValueError(f"unknown dispatch {dispatch!r}")

    state = scheme.init(cfg, jax.random.PRNGKey(seed), lr=lr)
    epoch_fn = scheme.make_epoch(cfg, lr=lr, mesh=mesh, wire=wire,
                                 topology=topology)
    bpr = scheme.batches_per_round(cfg)
    views_np, labels_np = np.asarray(views), np.asarray(labels)
    n = labels_np.shape[0]
    rounds = rounds_per_epoch(scheme, cfg, n, batch_size)

    xs_shardings = None
    if mesh is not None:
        from repro.launch import sharding as sharding_lib
        state = jax.device_put(state,
                               scheme.state_shardings(cfg, state, mesh))
        xs_shardings = sharding_lib.scheme_batch_shardings(
            mesh, cfg.num_clients, batch_size)

    meter = bandwidth.BandwidthMeter() if meter is None else meter
    start_ep = 0
    if resume and ckpt_dir:
        state, curve0, start_ep, _ = _try_resume(ckpt_dir, state, meter)
        if mesh is not None and start_ep:
            state = jax.device_put(state,
                                   scheme.state_shardings(cfg, state, mesh))
    else:
        curve0 = []

    def epoch_items():
        """(views (K,R,J,b,...), labels (K,R,b), rngs (K,2)) per epoch —
        the whole-epoch scan xs, assembled host-side (ONE gather over the
        epoch's index matrix, not per-batch stacking) so the prefetcher can
        overlap assembly + transfer with the previous epoch's compute.
        A resumed run fast-forwards the rng chain through the completed
        epochs WITHOUT assembling their batches — the downstream subkeys
        (and so the trajectory) are exactly the uninterrupted run's."""
        rng = jax.random.PRNGKey(seed + 1)
        for ep in range(epochs):
            rng, subs = _split_chain(rng, rounds)
            if ep < start_ep:
                continue
            idx = np.stack(list(multiview.batch_indices(
                n, batch_size, seed=ep)))
            idx = idx[:rounds * bpr].reshape(rounds, bpr, batch_size)
            yield (np.moveaxis(views_np[:, idx], 0, 2), labels_np[idx],
                   subs)

    charges = _round_charges(scheme, cfg, state, batch_size, wire=wire,
                             topology=topology)
    topo_full = topology_lib.resolve(topology, cfg)
    faulty = linkfault.active(topo_full, cfg, train=True)
    n_eval = min(eval_n, n)
    ev = jnp.asarray(views_np[:, :n_eval])
    el = jnp.asarray(labels_np[:n_eval])

    curve: List[CurvePoint] = list(curve0)
    items = prefetch.prefetch_to_device(
        epoch_items() if rounds else iter(()), size=prefetch_size,
        shardings=xs_shardings)
    for ep in range(start_ep, epochs):
        if rounds:
            ep_views, ep_labels, ep_rngs = next(items)
            state, _ = epoch_fn(state, ep_views, ep_labels, ep_rngs)
            if faulty:
                # the scan's per-round subkeys ARE the round rngs — replay
                # their folded fault draws host-side for the two ledgers
                _meter_fault_rounds(meter, scheme, topo_full, cfg,
                                    batch_size, charges,
                                    jax.device_get(ep_rngs))
            else:
                _meter_rounds(meter, charges, rounds)
        _meter_overheads(meter, scheme, cfg, state)
        eval_state = jax.device_get(state) if mesh is not None else state
        acc = base.evaluate_accuracy(scheme, eval_state, ev, el,
                                     topology=topology, cfg=cfg)
        curve.append(CurvePoint(ep + 1, acc, meter.gbits,
                                meter.measured_gbits, meter.delivered_gbits))
        if ckpt_dir and ((ep + 1) % max(ckpt_every, 1) == 0
                         or ep + 1 == epochs):
            _save_epoch(ckpt_dir, scheme.name, ep + 1, state, curve, meter)
    return curve


def _run_per_round(scheme, views, labels, cfg, *, epochs, batch_size, lr,
                   seed, eval_n, wire="dense", topology=None, meter=None,
                   ckpt_dir=None, ckpt_every: int = 1, resume: bool = False):
    """The seed-style path: one transfer + one jitted dispatch per round.
    Kept verbatim as the throughput baseline (benchmarks/throughput_bench)
    and the semantics reference the scan path is tested against."""
    state = scheme.init(cfg, jax.random.PRNGKey(seed), lr=lr)
    round_fn = scheme.make_round(cfg, lr=lr, wire=wire, topology=topology)
    bpr = scheme.batches_per_round(cfg)

    meter = bandwidth.BandwidthMeter() if meter is None else meter
    start_ep = 0
    if resume and ckpt_dir:
        state, curve0, start_ep, _ = _try_resume(ckpt_dir, state, meter)
    else:
        curve0 = []
    charges = _round_charges(scheme, cfg, state, batch_size, wire=wire,
                             topology=topology)
    topo_full = topology_lib.resolve(topology, cfg)
    faulty = linkfault.active(topo_full, cfg, train=True)
    rounds = rounds_per_epoch(scheme, cfg, labels.shape[0], batch_size)
    rng = jax.random.PRNGKey(seed + 1)
    if start_ep and rounds:
        # replay the completed epochs' split chain so the next subkey (and
        # the trajectory downstream of it) matches the uninterrupted run
        rng, _ = _split_chain(rng, start_ep * rounds)
    n_eval = min(eval_n, labels.shape[0])
    ev = jnp.asarray(views[:, :n_eval])
    el = jnp.asarray(labels[:n_eval])

    curve: List[CurvePoint] = list(curve0)
    for ep in range(start_ep, epochs):
        group_v, group_l = [], []
        for v, l in multiview.multiview_batches(views, labels, batch_size,
                                                seed=ep):
            group_v.append(v)
            group_l.append(l)
            if len(group_v) < bpr:
                continue
            rng, sub = jax.random.split(rng)
            state, metrics = round_fn(
                state, jnp.asarray(np.stack(group_v)),
                jnp.asarray(np.stack(group_l)), sub)
            if faulty:
                _meter_fault_rounds(meter, scheme, topo_full, cfg,
                                    batch_size, charges, [sub])
            else:
                _meter_rounds(meter, charges)
            group_v, group_l = [], []
        _meter_overheads(meter, scheme, cfg, state)
        acc = base.evaluate_accuracy(scheme, state, ev, el,
                                     topology=topology, cfg=cfg)
        curve.append(CurvePoint(ep + 1, acc, meter.gbits,
                                meter.measured_gbits, meter.delivered_gbits))
        if ckpt_dir and ((ep + 1) % max(ckpt_every, 1) == 0
                         or ep + 1 == epochs):
            _save_epoch(ckpt_dir, scheme.name, ep + 1, state, curve, meter)
    return curve


def _run_transport(scheme, views, labels, cfg, *, epochs, batch_size, lr,
                   seed, eval_n, wire="dense", topology=None, transport=None,
                   ckpt_dir=None, ckpt_every: int = 1, resume: bool = False):
    """Per-round execution where fault outcomes come from the TRANSPORT:
    each round calls `transport.round_outcome(tick, ...)` — the retrying
    channels, circuit breakers, and chaos schedule decide the (J,) delivery
    mask — and hands the host-side verdict to the scheme's
    `make_transport_round` round (explicit delivery, no in-graph draws).
    The transport owns the run's meter: offered accrues per attempt,
    delivered per surviving payload fraction.

    Degradation semantics (the chaos bench's comparison): INL partial-fuses
    the surviving views (one vote lost per failed route), FL drops missing
    clients from the FedAvg average (their whole round of local work lost),
    SL skips the whole round unless every link delivered.

    A resume replays the completed ticks with ``charge=False`` — the breaker
    trajectories are reproduced without re-charging the restored ledgers —
    so the resumed run is bit-identical to the uninterrupted one."""
    state = scheme.init(cfg, jax.random.PRNGKey(seed), lr=lr)
    round_fn = scheme.make_transport_round(cfg, lr=lr, wire=wire,
                                           topology=topology)
    bpr = scheme.batches_per_round(cfg)
    meter = transport.meter
    charges = _round_charges(scheme, cfg, state, batch_size, wire=wire,
                             topology=topology)
    edges = transport.topo.edges
    if set(charges) == {None}:
        # scalar totals (FL/SL): split the round's charge equally across
        # the (star) edges so per-edge attempts re-offer their own share
        b, nb = charges[None]
        charges = {e.key: (b / len(edges), nb / len(edges)) for e in edges}
    rounds = rounds_per_epoch(scheme, cfg, labels.shape[0], batch_size)

    start_ep = 0
    tsnap = None
    if resume and ckpt_dir:
        state, curve0, start_ep, tsnap = _try_resume(ckpt_dir, state, meter)
    else:
        curve0 = []
    rng = jax.random.PRNGKey(seed + 1)
    tick = start_ep * rounds
    if tick:
        rng, _ = _split_chain(rng, tick)
        for t in range(tick):                 # breaker replay, ledger-free
            transport.round_outcome(t, batch_size, charges=charges,
                                    charge=False)
    if tsnap is not None:
        # the replay above already reproduced the adaptive knob trajectory
        # (observe runs on uncharged rounds too); loading the sidecar's
        # copy on top makes the checkpoint authoritative over the replay
        transport.load_snapshot(tsnap)

    n_eval = min(eval_n, labels.shape[0])
    ev = jnp.asarray(views[:, :n_eval])
    el = jnp.asarray(labels[:n_eval])

    curve: List[CurvePoint] = list(curve0)
    for ep in range(start_ep, epochs):
        group_v, group_l = [], []
        for v, l in multiview.multiview_batches(views, labels, batch_size,
                                                seed=ep):
            group_v.append(v)
            group_l.append(l)
            if len(group_v) < bpr:
                continue
            rng, sub = jax.random.split(rng)
            rep = transport.round_outcome(tick, batch_size, charges=charges)
            tick += 1
            state, metrics = round_fn(
                state, jnp.asarray(np.stack(group_v)),
                jnp.asarray(np.stack(group_l)), sub, jnp.asarray(rep.mask))
            group_v, group_l = [], []
        _meter_overheads(meter, scheme, cfg, state)
        acc = base.evaluate_accuracy(scheme, state, ev, el,
                                     topology=topology, cfg=cfg)
        curve.append(CurvePoint(ep + 1, acc, meter.gbits,
                                meter.measured_gbits, meter.delivered_gbits))
        if ckpt_dir and ((ep + 1) % max(ckpt_every, 1) == 0
                         or ep + 1 == epochs):
            _save_epoch(ckpt_dir, scheme.name, ep + 1, state, curve, meter,
                        transport=transport)
    return curve


def run_all(names: Sequence[str], views, labels, cfg, *, epochs: int,
            **kw) -> dict:
    """Curves for several registered schemes on the same data.

    A caller-supplied `meter=` is per RUN: sharing one across schemes
    would accumulate every earlier scheme's traffic into the later curves'
    gbits, so it is only accepted for a single-scheme list."""
    if kw.get("meter") is not None and len(names) > 1:
        raise ValueError("meter= accumulates across runs; pass it to "
                         "run_scheme per scheme (or run one scheme)")
    return {n: run_scheme(n, views, labels, cfg, epochs=epochs, **kw)
            for n in names}


def efficiency(curve: Sequence[CurvePoint]) -> float:
    """Final accuracy per Gbit exchanged (the paper's headline metric).

    An empty curve (epochs=0, or a rounds == 0 run that never evaluated)
    has no final point — 0.0, not an IndexError."""
    if not curve:
        return 0.0
    last = curve[-1]
    return last.accuracy / max(last.gbits, 1e-9)
