"""Registry-driven training/benchmark runner — ONE loop for every scheme.

Replaces the three ad-hoc per-scheme runners the benchmarks used to carry:
the scheme supplies init / round / predict / bandwidth through the Scheme
interface, this module supplies the epoch loop, minibatch grouping, the
BandwidthMeter, and the accuracy-vs-epoch / accuracy-vs-Gbit curve — so a
newly registered scheme benchmarks itself with zero extra glue.
"""
from __future__ import annotations

from typing import List, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bandwidth
from repro.core.schemes import base
from repro.data import multiview


class CurvePoint(NamedTuple):
    epoch: int
    accuracy: float
    gbits: float                 # cumulative bits exchanged, in Gbit


def run_scheme(name: str, views, labels, cfg, *, epochs: int,
               batch_size: int = 64, lr: float = 2e-3, seed: int = 0,
               eval_n: int = 512) -> List[CurvePoint]:
    """Train scheme `name` for `epochs` over the (J, n, ...) multi-view set
    and return its accuracy/bandwidth curve (paper Figs. 5/7 rows).

    Minibatches are grouped `batches_per_round(cfg)` at a time into round
    calls; a trailing partial group is dropped (same rounding the paper's
    per-epoch accounting uses).  Bandwidth accrues per round plus the
    scheme's once-per-epoch overhead, all through the §III-C closed forms.
    """
    from repro.core import schemes
    scheme = schemes.get(name)
    state = scheme.init(cfg, jax.random.PRNGKey(seed), lr=lr)
    round_fn = scheme.make_round(cfg, lr=lr)
    bpr = scheme.batches_per_round(cfg)

    meter = bandwidth.BandwidthMeter()
    rng = jax.random.PRNGKey(seed + 1)
    n_eval = min(eval_n, labels.shape[0])
    ev = jnp.asarray(views[:, :n_eval])
    el = jnp.asarray(labels[:n_eval])

    curve: List[CurvePoint] = []
    for ep in range(epochs):
        group_v, group_l = [], []
        for v, l in multiview.multiview_batches(views, labels, batch_size,
                                                seed=ep):
            group_v.append(v)
            group_l.append(l)
            if len(group_v) < bpr:
                continue
            rng, sub = jax.random.split(rng)
            state, metrics = round_fn(
                state, jnp.asarray(np.stack(group_v)),
                jnp.asarray(np.stack(group_l)), sub)
            meter.add(scheme.bits_per_round(cfg, state, batch_size))
            group_v, group_l = [], []
        meter.add(scheme.epoch_overhead_bits(cfg, state))
        acc = base.evaluate_accuracy(scheme, state, ev, el)
        curve.append(CurvePoint(ep + 1, acc, meter.gbits))
    return curve


def run_all(names: Sequence[str], views, labels, cfg, *, epochs: int,
            **kw) -> dict:
    """Curves for several registered schemes on the same data."""
    return {n: run_scheme(n, views, labels, cfg, epochs=epochs, **kw)
            for n in names}


def efficiency(curve: Sequence[CurvePoint]) -> float:
    """Final accuracy per Gbit exchanged (the paper's headline metric)."""
    last = curve[-1]
    return last.accuracy / max(last.gbits, 1e-9)
