"""Hybrid FL/SL participation behind the unified Scheme API.

Each client picks HOW it participates (cfg.hybrid_fl_clients): CUT-mode
clients run the SL-style boundary — deterministic cut-layer activations to
the fusion center, eq.-(10) error chunks back — while WEIGHT-mode clients
train their full local model (client-side encoder + own branch head) and
sync fp32 weights with the server each round, FL-style.  The Guo-et-al.
hybrid trade: a weight-mode client's per-round cost is independent of the
batch, a cut-mode client's is independent of the model — the crossover is
what `repro/search` maps.

Training: every view is encoded, the CUT latents that arrived are
partial-fused into the eq.-(5) joint decoder, and all J branch heads train
on their local latent — a weight-mode client's whole gradient flows
through its branch head (its latent never ships), which is exactly its
local FL objective.  Inference ensembles the joint decoder (one vote per
fused cut latent) with the weight-mode clients' local branch predictions
(one vote each) in probability space.

Faults: a dead route drops a cut client's latent from the fusion
(renormalised partial fusion) and costs a weight client its whole round —
the server keeps the stale model copy (per-client revert), the classic
FL skip.  Bandwidth decomposes per edge: the cut payload's activation
exchange (closed == wirefmt-measured) plus 2 x 32 x N_client-side for
every weight-mode client the edge serves.

The graph simulation computes all J latents for vmap convenience; the
MODEL says weight-mode clients never transmit activations — they are
masked from every fusion and never charged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import bottleneck, linkfault, losses, paper_model, wirefmt
from repro.core import schemes as _schemes
from repro.core import topology as topology_lib
from repro.core.schemes import base, splitfed


def fl_clients(cfg):
    """Validated, sorted weight-mode client indices from
    cfg.hybrid_fl_clients.  At least one client must stay cut-mode (the
    fusion center needs something to fuse)."""
    J = cfg.num_clients
    idx = tuple(sorted({int(j) for j in
                        (getattr(cfg, "hybrid_fl_clients", ()) or ())}))
    bad = [j for j in idx if not 0 <= j < J]
    if bad:
        raise ValueError(f"hybrid_fl_clients {bad} out of range for "
                         f"num_clients={J}")
    if len(idx) >= J:
        raise ValueError(
            f"hybrid needs at least one cut-mode client: hybrid_fl_clients="
            f"{idx} claims all {J} clients for weight-mode participation")
    return idx


def cut_mask(cfg) -> np.ndarray:
    """(J,) bool, True where the client ships cut-layer activations."""
    w = set(fl_clients(cfg))
    return np.array([j not in w for j in range(cfg.num_clients)], bool)


def _and_mask(static, delivery):
    """static (J,) & delivery (J,) or (J, B), broadcasting the static
    mode mask over the sample axis when needed."""
    if delivery is None:
        return static
    s = static if delivery.ndim == 1 else static[:, None]
    return jnp.logical_and(s, delivery)


@_schemes.register
class HybridScheme(base.Scheme):
    name = "hybrid"

    def init(self, cfg, key, *, lr: float = 2e-3):
        state = splitfed.SplitFedScheme().init(cfg, key, lr=lr)
        # the mode split rides in the state so inference (which may not
        # see cfg — the parity fixtures call bare predict) always fuses
        # exactly the latents training fused
        state["modes"] = jnp.asarray(cut_mask(cfg))
        return state

    # ------------------------------------------------------------------
    # training
    # ------------------------------------------------------------------

    def _loss(self, params, enc_state, modes, views, labels, rng, cfg, *,
              wire, topo, delivery):
        dt = paper_model.compute_dtype(cfg)
        params_c = paper_model.cast_compute(params, dt)
        (mu, logvar), new_enc = splitfed._encode(
            params_c["encoders"], enc_state["encoders"], views.astype(dt),
            train=True)
        if topo is None:
            u, _, u_joint = wirefmt.cut_and_ship(
                None, mu, logvar, link_bits=cfg.link_bits,
                rate_estimator="none", wire=wire)
        else:
            u, _, u_joint = topology_lib.graph_cut_and_ship(
                topo, cfg, mu, logvar, jnp.zeros(mu.shape, jnp.float32),
                rate_estimator="none", wire=wire)
        u_joint = linkfault.partial_fuse(u_joint, _and_mask(modes, delivery))
        logits = paper_model.decoder_apply(params_c["decoder"],
                                           splitfed._fuse_cat(u_joint),
                                           train=True, rng=rng)
        joint_loss = losses.xent(logits, labels)
        branch = paper_model.branch_heads_apply(params_c["decoder"], u)
        branch_loss = jnp.mean(jax.vmap(losses.xent, in_axes=(0, None))(
            branch, labels))
        loss = joint_loss + branch_loss
        metrics = {"loss": loss, "accuracy": losses.accuracy(logits, labels),
                   "branch_loss": branch_loss}
        return loss, (metrics, {"encoders": new_enc})

    def _make_step(self, cfg, *, lr, wire, topology, explicit_delivery):
        fl_clients(cfg)                      # validate the mode split early
        opt = optim.adam(lr)
        topo_full = topology_lib.resolve(topology, cfg)
        topo = topology_lib.nontrivial(topology, cfg)
        faulty = linkfault.active(topo_full, cfg, train=True)

        @jax.jit
        def step(state, views, labels, rng, delivery):
            _, r_dec = jax.random.split(rng)
            modes = state["modes"]
            grad_fn = jax.value_and_grad(self._loss, has_aux=True)
            (_, (metrics, new_enc)), grads = grad_fn(
                state["params"], state["state"], modes, views, labels,
                r_dec, cfg, wire=wire, topo=topo, delivery=delivery)
            params, opt_state = opt.update(grads, state["opt"],
                                           state["params"])
            if delivery is not None:
                # FL skip semantics: a weight-mode client whose route died
                # never reached the server — revert its per-client rows
                # (encoder + branch head) to the stale server copy.  Cut
                # clients keep local updates (their branch stays on-node).
                revert = jnp.logical_and(~modes, ~delivery)

                def keep(new, old):
                    m = revert.reshape((revert.shape[0],)
                                       + (1,) * (new.ndim - 1))
                    return jnp.where(m, old, new)

                old = state["params"]
                params = dict(params, encoders=jax.tree.map(
                    keep, params["encoders"], old["encoders"]))
                params["decoder"] = dict(
                    params["decoder"], branch_heads=jax.tree.map(
                        keep, params["decoder"]["branch_heads"],
                        old["decoder"]["branch_heads"]))
            return ({"params": params, "state": new_enc, "opt": opt_state,
                     "modes": modes}, metrics)

        if explicit_delivery:
            return step

        def round_fn(state, views, labels, rng):
            # all-ones as a RUNTIME argument, not a trace-time None, so
            # the no-fault and perfect-link cases share one jitted graph
            # (see splitfed.py: a constant mask constant-folds into
            # different last-ulp arithmetic)
            delivery = linkfault.round_delivery_mask(
                rng, topo_full, cfg, labels.shape[-1], train=True) \
                if faulty else jnp.ones((cfg.num_clients,), bool)
            return step(state, views, labels, rng, delivery)
        return round_fn

    def make_round(self, cfg, *, lr: float = 2e-3, wire: str = "dense",
                   topology=None):
        step = self._make_step(cfg, lr=lr, wire=wire, topology=topology,
                               explicit_delivery=False)

        def round_fn(state, views, labels, rng):
            return step(state, views[0], labels[0], rng)
        return round_fn

    def make_transport_round(self, cfg, *, lr: float = 2e-3,
                             wire: str = "dense", topology=None):
        step = self._make_step(cfg, lr=lr, wire=wire, topology=topology,
                               explicit_delivery=True)

        def round_fn(state, views, labels, rng, delivery):
            return step(state, views[0], labels[0], rng, delivery)
        return round_fn

    # ------------------------------------------------------------------
    # inference: joint decoder over fused cut latents, ensembled with the
    # weight-mode clients' local branch predictions
    # ------------------------------------------------------------------

    def _predict(self, state, views, topology, cfg, delivery=None,
                 wire: str = "dense"):
        modes = state["modes"]
        topo = None if cfg is None else topology_lib.nontrivial(topology,
                                                                cfg)
        (mu, logvar), _ = splitfed._encode(
            state["params"]["encoders"], state["state"]["encoders"], views,
            train=False)
        if topo is None:
            u, _ = bottleneck.fused_sample_rate(None, mu, logvar,
                                                rate_estimator="none")
            u_joint = u
        else:
            u, _, u_joint = topology_lib.graph_cut_and_ship(
                topo, cfg, mu, logvar, jnp.zeros(mu.shape, jnp.float32),
                rate_estimator="none", wire=wire)
        cut_m = _and_mask(modes, delivery)
        w_m = _and_mask(~modes, delivery)
        dec = state["params"]["decoder"]
        u_f = linkfault.partial_fuse(u_joint, cut_m)
        p_dec = jax.nn.softmax(paper_model.decoder_apply(
            dec, splitfed._fuse_cat(u_f), train=False), axis=-1)
        p_branch = jax.nn.softmax(paper_model.branch_heads_apply(dec, u),
                                  axis=-1)                      # (J, B, C)
        B = views.shape[1]
        cut2 = jnp.broadcast_to(
            (cut_m if cut_m.ndim == 2 else cut_m[:, None]).astype(
                jnp.float32), (modes.shape[0], B))
        w2 = jnp.broadcast_to(
            (w_m if w_m.ndim == 2 else w_m[:, None]).astype(jnp.float32),
            (modes.shape[0], B))
        cut_votes = jnp.sum(cut2, axis=0)                       # (B,)
        w_votes = jnp.sum(w2, axis=0)
        numer = p_dec * cut_votes[:, None] \
            + jnp.sum(p_branch * w2[:, :, None], axis=0)
        total = cut_votes + w_votes
        probs = numer / jnp.maximum(total, 1.0)[:, None]
        uniform = jnp.full_like(probs, 1.0 / probs.shape[-1])
        return jnp.where(total[:, None] > 0, probs, uniform)

    def predict(self, state, views, topology=None, cfg=None):
        return self._predict(state, views, topology, cfg)

    def predict_batched(self, state, views, *, delivery=None, topology=None,
                        cfg=None, wire: str = "dense"):
        return self._predict(state, views, topology, cfg, delivery=delivery,
                             wire=wire)

    def predict_under_faults(self, state, views, key, topology=None,
                             cfg=None):
        # per-sample route survival: a dead cut route loses one fusion
        # vote, a dead weight route loses that client's ensemble vote
        topo_full = topology_lib.resolve(topology, cfg)
        delivery = linkfault.sample_delivery_mask(key, topo_full, cfg,
                                                  views.shape[1])
        return self._predict(state, views, topology, cfg, delivery=delivery)

    # ------------------------------------------------------------------
    # bandwidth
    # ------------------------------------------------------------------

    def _weight_charges(self, cfg, state):
        """(closed bits, measured bytes) per weight-mode client and
        direction: client-side encoder + its branch head, fp32."""
        J = cfg.num_clients
        n_cs = paper_model.encoder_param_count(splitfed.client_cfg(cfg)) \
            + cfg.d_bottleneck * cfg.num_classes + cfg.num_classes
        nbytes = (splitfed.tree_nbytes(state["params"]["encoders"])
                  + splitfed.tree_nbytes(
                      state["params"]["decoder"]["branch_heads"])) / J
        return 32.0 * n_cs, nbytes

    def edge_ledger(self, cfg, state, batch_size: int, *,
                    wire: str = "dense", topology=None):
        topo = topology_lib.resolve(topology, cfg)
        wset = set(fl_clients(cfg))
        w_bits, w_nbytes = self._weight_charges(cfg, state)
        dt = paper_model.compute_dtype(cfg)
        out = {}
        for e in topo.topo_edges():
            pay = topo.payload(e)
            n_cut = sum(1 for j in pay if j not in wset)
            n_w = len(pay) - n_cut
            q = topology_lib.edge_bits(e, cfg)
            bits = 2.0 * batch_size * n_cut * cfg.d_bottleneck * q
            nbytes = 0.0 if n_cut == 0 else float(wirefmt.round_wire_bytes(
                batch_size * n_cut, cfg.d_bottleneck, link_bits=q,
                wire=topology_lib.edge_wire(e, wire),
                dtype=topology_lib.edge_dtype(e, cfg))["total"])
            out[e.key] = (bits + 2.0 * n_w * w_bits,
                          nbytes + 2.0 * n_w * w_nbytes)
        return out

    def bits_per_round(self, cfg, state, batch_size: int, *,
                       topology=None) -> float:
        return float(sum(b for b, _ in self.edge_ledger(
            cfg, state, batch_size, topology=topology).values()))

    def wire_bytes_per_round(self, cfg, state, batch_size: int, *,
                             wire: str = "dense", topology=None) -> float:
        return float(sum(n for _, n in self.edge_ledger(
            cfg, state, batch_size, wire=wire, topology=topology).values()))
