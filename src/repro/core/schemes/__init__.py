"""Unified Scheme API — the paper's three-way comparison as a subsystem.

The paper's headline claim (Figs. 5/7, Table I) is a COMPARISON: in-network
learning beats federated and split learning on accuracy per epoch AND per
bit exchanged.  That comparison is only meaningful when all three schemes
run on the same measured substrate, so this package makes the harness
first-class: every scheme sits behind one `Scheme` interface

    init(cfg, key, *, lr)        -> opaque state pytree (params + opt state)
    make_round(cfg, *, lr)       -> jitted round_fn(state, views, labels,
                                    rng) -> (state, metrics)
    predict(state, views)        -> class probabilities (B, C), rows sum to 1
    bits_per_round(cfg, state, batch_size)
                                 -> bits moved by ONE round, via the
                                    closed-form §III-C / Table-I accounting
                                    in core/bandwidth.py
    epoch_overhead_bits(cfg, state)
                                 -> bits charged once per epoch (split
                                    learning's client->client weight
                                    hand-offs; 0 for the others)

and every cut-layer exchange — INL's stochastic bottleneck, SL's
deterministic activations, FL's in-model branch latents — runs through the
SAME fused kernel (`kernels/ops.cutlayer`).  Every entry point also takes
`topology=` (core/topology.py): the network graph the exchange routes
over — star by default (bit-identical to the pre-topology paths), chains/
trees/arbitrary single-sink DAGs for INL, with per-edge link widths, wire
formats and a per-edge bandwidth ledger.  See the "Topologies" section of
core/schemes/README.md.

Registering a new scheme
------------------------
Subclass `base.Scheme`, implement the five methods above, and register an
instance:

    from repro.core import schemes
    from repro.core.schemes import base

    @schemes.register
    class MyScheme(base.Scheme):
        name = "my-scheme"
        ...                         # the five methods; optionally override
                                    # batches_per_round(cfg) (default 1)

`schemes.get("my-scheme")` then returns it, and the registry-driven runner
(`schemes.runner.run_scheme`), `benchmarks/accuracy_curves.py`, and
`examples/compare_schemes.py` pick it up with zero further glue — a new
scheme variant is a ~100-line plugin, not a fork of the benchmark loop.
See core/schemes/README.md for a walk-through.
"""
from __future__ import annotations

from repro.core.schemes.base import Scheme  # noqa: F401  (public API)

_REGISTRY: dict = {}


def register(cls):
    """Class decorator: instantiate and register a Scheme under cls.name."""
    inst = cls()
    if not inst.name:
        raise ValueError(f"{cls.__name__} must set a non-empty .name")
    _REGISTRY[inst.name] = inst
    return cls


def get(name: str) -> Scheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scheme {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def available():
    """Registered scheme names, INL first (the paper's ordering)."""
    order = {"inl": 0, "sl": 1, "fl": 2}
    return tuple(sorted(_REGISTRY, key=lambda n: (order.get(n, 99), n)))


# importing the built-in schemes self-registers them
from repro.core.schemes import fl, hybrid, inl, runner, sl, \
    splitfed  # noqa: E402,F401
