"""In-network learning behind the unified Scheme API (wraps core/inl.py).

One round == one eq.-(6) optimizer step on a (J, B) multi-view batch; the
cut layer (sample + link quantizer + rate, learned priors included) is the
fused kernel.  Bandwidth per round is the paper's 2 b p s — activations
forward, eq.-(10) error vectors backward — expressed through the Table-I
closed form so measured and published accounting share one source.

INL is the scheme the network GRAPH belongs to: `topology=` compiles
non-star graphs (chains, trees, heterogeneous per-edge widths) to
multi-hop execution (core/topology.graph_cut_and_ship) and decomposes both
bandwidth ledgers per edge (`edge_ledger`), each edge charged for the
payload it carries.  The default star keeps every path bit-identical to
the pre-topology code.
"""
from __future__ import annotations

from repro import optim
from repro.core import bandwidth, inl, linkfault, paper_model, wirefmt
from repro.core import schemes as _schemes
from repro.core import topology as topology_lib
from repro.core.schemes import base


@_schemes.register
class INLScheme(base.Scheme):
    name = "inl"

    def init(self, cfg, key, *, lr: float = 2e-3):
        params, state = inl.init(cfg, key)
        opt = optim.adam(lr)
        return {"params": params, "state": state, "opt": opt.init(params)}

    def make_round(self, cfg, *, lr: float = 2e-3, wire: str = "dense",
                   topology=None):
        opt = optim.adam(lr)
        step = inl.make_train_step(cfg, opt, wire=wire, topology=topology)

        def round_fn(state, views, labels, rng):
            params, st, opt_state, metrics = step(
                state["params"], state["state"], state["opt"],
                views[0], labels[0], rng)
            return ({"params": params, "state": st, "opt": opt_state},
                    metrics)
        return round_fn

    def make_transport_round(self, cfg, *, lr: float = 2e-3,
                             wire: str = "dense", topology=None):
        # the transport's measured (J,) outcome IS the round's delivery
        # mask: surviving views partial-fuse (renormalised by J/n), lost
        # ones cost exactly their own vote — rate terms and branch heads
        # stay local, so a cut-off node keeps training its encoder
        opt = optim.adam(lr)
        step = inl.make_train_step(cfg, opt, wire=wire, topology=topology,
                                   explicit_delivery=True)

        def round_fn(state, views, labels, rng, delivery):
            params, st, opt_state, metrics = step(
                state["params"], state["state"], state["opt"],
                views[0], labels[0], rng, delivery)
            return ({"params": params, "state": st, "opt": opt_state},
                    metrics)
        return round_fn

    def make_sharded_round(self, cfg, mesh, *, lr: float = 2e-3,
                           wire: str = "dense", topology=None):
        from repro.core import sharded
        return sharded.make_inl_sharded_round(cfg, mesh, optim.adam(lr),
                                              wire=wire, topology=topology)

    def state_shardings(self, cfg, state, mesh):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        cl = NamedSharding(mesh, P("client"))
        rep = NamedSharding(mesh, P())

        def param_sh(params):
            return inl.INLParams(
                jax.tree.map(lambda _: cl, params.encoders),
                {"dense": jax.tree.map(lambda _: rep,
                                       params.decoder["dense"]),
                 "branch_heads": jax.tree.map(
                     lambda _: cl, params.decoder["branch_heads"])},
                jax.tree.map(lambda _: cl, params.priors))

        p_sh = param_sh(state["params"])
        return {"params": p_sh,
                "state": jax.tree.map(lambda _: cl, state["state"]),
                "opt": {k: (rep if k == "step" else p_sh)
                        for k in state["opt"]}}

    def predict(self, state, views, topology=None, cfg=None):
        return inl.predict(state["params"], state["state"], views,
                           cfg=cfg, topology=topology)

    def predict_batched(self, state, views, *, delivery=None, topology=None,
                        cfg=None, wire: str = "dense"):
        # the serving-plane entry: per-request partial fusion (delivery is
        # the (J, B) fuse-what-arrived mask) with the engine's wire format
        # threaded through the graph hops.  delivery=None reproduces
        # `predict` bit for bit — the bucket-padding parity contract.
        return inl.predict(state["params"], state["state"], views, cfg=cfg,
                           topology=topology, delivery=delivery, wire=wire)

    def predict_under_faults(self, state, views, key, topology=None,
                             cfg=None):
        # INL degrades per VIEW, not per request: each sample draws its own
        # (J,) route-survival mask and the fusion center renormalises over
        # the latents that arrived (linkfault.partial_fuse) — a lost link
        # costs one vote, not the prediction
        topo_full = topology_lib.resolve(topology, cfg)
        delivery = linkfault.sample_delivery_mask(key, topo_full, cfg,
                                                  views.shape[1])
        return inl.predict(state["params"], state["state"], views,
                           cfg=cfg, topology=topology, delivery=delivery)

    def bits_per_round(self, cfg, state, batch_size: int, *,
                       topology=None) -> float:
        topo = topology_lib.nontrivial(topology, cfg)
        if topo is not None:
            return topology_lib.round_bits(topo, cfg, batch_size)
        # §III-C: each of the J nodes holds q/J of the round's q = b*J
        # node-points and sends p/J = d_bottleneck values per point, both
        # directions -> 2 b p s with p = J * d_bottleneck.
        p = cfg.num_clients * cfg.d_bottleneck
        return bandwidth.inl_epoch_bits(p, batch_size * cfg.num_clients,
                                        cfg.num_clients, cfg.link_bits)

    def wire_bytes_per_round(self, cfg, state, batch_size: int, *,
                             wire: str = "dense", topology=None) -> float:
        topo = topology_lib.nontrivial(topology, cfg)
        if topo is not None:
            return topology_lib.round_wire_bytes(topo, cfg, batch_size,
                                                 wire=wire)
        # the round's exchange is J*B latent d_b-vectors forward and their
        # eq.-(10) error chunks back, at the sizes wirefmt actually ships
        return wirefmt.round_wire_bytes(
            cfg.num_clients * batch_size, cfg.d_bottleneck,
            link_bits=cfg.link_bits, wire=wire,
            dtype=paper_model.compute_dtype(cfg))["total"]

    def edge_ledger(self, cfg, state, batch_size: int, *,
                    wire: str = "dense", topology=None):
        # always decomposable for INL — the star is J single-latent edges
        # whose charges sum to the Table-I totals exactly
        topo = topology_lib.resolve(topology, cfg)
        bits = topology_lib.round_edge_bits(topo, cfg, batch_size)
        nbytes = topology_lib.round_edge_wire_bytes(topo, cfg, batch_size,
                                                    wire=wire)
        return {k: (bits[k], nbytes[k]) for k in bits}
