"""Fault-tolerant edge transport over a topology: retries, breakers, chaos.

`core/linkfault.py` models unreliable links as INLINE MASKS — pure draws
consumed inside the jitted round/predict graphs.  This module moves the
same `LinkModel` outcomes down to an actual TRANSPORT: every topology edge
gets a `Channel` (loopback or a real socket), a `RetryPolicy` (bounded
attempts, exponential backoff with seeded jitter, per-attempt timeout) and
a `CircuitBreaker` (open after K consecutive failures, half-open probe,
close on success).  A payload now either ARRIVES — possibly after retries
that cost offered bandwidth and latency — or is LOST because its link
erased every attempt, its route's breaker short-circuited, or a chaos
schedule killed the sending node.

Determinism: every fault draw is a pure function of
(seed, domain, tick, edge index, attempt) through a counter-seeded
`np.random.default_rng`, where tick = the training round index or the
serving request id.  Replaying the same schedule replays the same
outcomes, breaker transitions included — the property the deterministic
chaos harness (repro/chaos.py, benchmarks/chaos_bench.py) is built on.
These draws are the transport's OWN stream: they model the same LinkModel
parameters as linkfault's jax draws but are not bit-coupled to them (the
inline-mask paths and their golden trajectories are untouched).

Ledger convention (BandwidthMeter): every attempt that actually rides a
link offers its full payload charge (retries RE-OFFER — that is their
cost); short-circuited attempts offer NOTHING (that is the breaker's
saving).  Delivered credit accrues when the consumer uses the payload:
rounds credit inside `round_outcome`, the serving engine credits per
completed fusion via `credit_delivered` (so speculative patching can
credit a straggler that was eventually fused).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import bandwidth
from repro.core import topology as topology_lib
from repro.transport import channel as channel_lib
from repro.transport.policy import (CircuitBreaker, NoBreaker, RetryPolicy,
                                    DEFAULT_RETRY, NO_RETRY)

# draw domains: disjoint streams for training rounds vs serving requests
DOMAIN_ROUND = 0
DOMAIN_REQUEST = 1

_PROBE = b"\x00INLPROBE"          # tiny frame for payload-less transmissions


def _edge_tx_ms(link, payload_bits: float) -> float:
    if link is None or link.bandwidth_bps is None:
        return 0.0
    return 1e3 * payload_bits / link.bandwidth_bps


@dataclass
class EdgeResult:
    """One payload's fate on one edge."""
    ok: bool                      # delivered within the attempt budget
    latency_ms: float             # cumulative: failed attempts + backoff +
                                  # the delivering attempt's latency
    attempts: int = 0             # attempts that actually rode the link
    short_circuited: bool = False  # breaker refused every attempt


class EdgeTransport:
    """One edge's channel + policy + breaker + fault model."""

    def __init__(self, edge, index: int, *, seed: int, policy: RetryPolicy,
                 breaker, chan: channel_lib.Channel, chaos=None):
        self.edge = edge
        self.index = index
        self.seed = seed
        self.policy = policy
        self.breaker = breaker if breaker is not None else NoBreaker()
        self.channel = chan
        self.chaos = chaos

    def _draws(self, domain: int, tick: int, attempt: int):
        rng = np.random.default_rng(
            (self.seed, domain, tick, self.index, attempt))
        return rng.random(), rng.exponential(), rng.random()

    def transmit(self, domain: int, tick: int, payload_bits: float,
                 frame: Optional[bytes] = None) -> EdgeResult:
        """Try to move one payload over this edge at `tick`.

        Walks the retry budget: each attempt consults the breaker (an OPEN
        breaker short-circuits the attempt — nothing offered), then draws
        erasure/latency from the edge's LinkModel under the chaos
        schedule's overrides (a down edge fails deterministically; a slow
        window multiplies latency).  The delivering attempt sends `frame`
        (or a probe) through the channel and pulls it across, so bytes
        genuinely traverse the transport.  Returns the EdgeResult; the
        caller owns ledger charges (it knows the bits basis)."""
        link = self.edge.link
        chaos = self.chaos
        t_ms = 0.0
        attempts_used = 0
        refused = 0
        for attempt in range(self.policy.max_attempts):
            u_erase, exp_lat, u_jit = self._draws(domain, tick, attempt)
            t_ms += self.policy.backoff_ms(attempt, u_jit)
            if not self.breaker.allow(tick):
                refused += 1
                continue
            attempts_used += 1
            down = chaos is not None and chaos.edge_down(self.edge.key, tick)
            slow = chaos.slow_factor(self.edge.key, tick) if chaos is not None \
                else 1.0
            erased = down
            lat = 0.0
            if link is not None:
                erased = erased or (link.erasure > 0
                                    and u_erase < link.erasure)
                lat = link.latency_ms + link.jitter_ms * exp_lat
            lat = lat * slow + _edge_tx_ms(link, payload_bits)
            if erased:
                # loss is detected after the timeout (or one latency's
                # worth of silence when no timeout is configured)
                t_ms += self.policy.timeout_ms if self.policy.timeout_ms \
                    is not None else max(lat, 1.0)
                self.breaker.record_failure(tick)
                continue
            if self.policy.attempt_failed(lat):
                t_ms += self.policy.timeout_ms
                self.breaker.record_failure(tick)
                continue
            # delivered: the frame rides the channel end to end.  A channel
            # that fails underneath us (torn frame, dead worker process)
            # is just another failed attempt — typed, not fatal.
            try:
                self.channel.send(frame if frame is not None else _PROBE)
            except channel_lib.ChannelError:
                t_ms += self.policy.timeout_ms if self.policy.timeout_ms \
                    is not None else max(lat, 1.0)
                self.breaker.record_failure(tick)
                continue
            self.breaker.record_success()
            return EdgeResult(ok=True, latency_ms=t_ms + lat,
                              attempts=attempts_used)
        return EdgeResult(ok=False, latency_ms=t_ms, attempts=attempts_used,
                          short_circuited=refused == self.policy.max_attempts)

    def receive(self, timeout: float = 5.0) -> Optional[bytes]:
        try:
            return self.channel.recv(timeout)
        except channel_lib.ChannelError:
            return None                          # abrupt close == lost payload


@dataclass
class RequestReport:
    """One request's transport outcome: which views made the fusion
    deadline (`on_time`), which would still arrive late (`eventual` minus
    `on_time` — the stragglers speculative fusion patches in), and which
    are gone (erased every attempt / short-circuited / dead node)."""
    rid: int
    on_time: np.ndarray           # (J,) bool
    eventual: np.ndarray          # (J,) bool, superset of on_time
    latency_ms: np.ndarray        # (J,) float; inf when lost
    received: Optional[List[Optional[np.ndarray]]] = None
    attempts: Dict[str, int] = field(default_factory=dict)

    @property
    def stragglers(self) -> np.ndarray:
        return self.eventual & ~self.on_time


@dataclass
class RoundReport:
    """One training round's transport outcome."""
    tick: int
    mask: np.ndarray              # (J,) bool: views fused this round
    latency_ms: np.ndarray        # (J,) float
    attempts: Dict[str, int] = field(default_factory=dict)


class NetworkTransport:
    """The per-topology transport: one `EdgeTransport` per edge.

    topo/cfg        a RESOLVED core/topology.Topology and the experiment
                    config (payload widths, deadline default).
    seed            the fault-draw stream (disjoint per domain/tick/edge).
    policy          RetryPolicy for every edge, or {edge_key: policy}.
    breaker         None (no breaking), "default" (CircuitBreaker() per
                    edge), or a factory ``lambda: CircuitBreaker(...)``.
    chaos           a repro/chaos.ChaosSchedule (or None).
    channels        "loopback" | "socket" — the byte transport per edge —
                    or a mapping {edge_key: Channel} supplying ready-made
                    channels (how `repro/cluster` routes edges whose source
                    is a supervised worker PROCESS through its TCP
                    connection; unmapped edges fall back to loopback).
    meter           BandwidthMeter accruing offered/delivered; owns one
                    when not given.
    adaptive        an AdaptivePolicy retuning per-edge retry budgets and
                    breaker thresholds each window from delivered/offered
                    (None keeps the fixed constants).
    on_tick         callable(tick) invoked at the top of every
                    round_outcome/send_request BEFORE any fault draw — the
                    cluster supervisor's hook to realise scheduled
                    kills/freezes and heartbeat at deterministic points.
    node_down       callable(name, tick) -> bool consulted alongside the
                    chaos schedule — the membership view's hook, so an
                    unscheduled worker death masks exactly the votes that
                    worker owned.

    Thread-safe: the serving engine submits from arbitrary threads; breaker
    state and ledger charges are serialised under one lock.
    """

    def __init__(self, topo, cfg, *, seed: int = 0,
                 policy: RetryPolicy = DEFAULT_RETRY, breaker="default",
                 chaos=None, channels="loopback", meter=None,
                 adaptive=None, on_tick=None, node_down=None):
        self.topo = topology_lib.resolve(topo, cfg)
        self.cfg = cfg
        self.seed = seed
        self.chaos = chaos
        self.meter = bandwidth.BandwidthMeter() if meter is None else meter
        self.adaptive = adaptive
        self.on_tick = on_tick
        self.node_down = node_down
        self._lock = threading.Lock()
        if breaker == "default":
            breaker = CircuitBreaker
        self.edges: Dict[str, EdgeTransport] = {}
        for i, e in enumerate(self.topo.edges):
            pol = policy.get(e.key, NO_RETRY) if isinstance(policy, dict) \
                else policy
            if isinstance(channels, str):
                chan = channel_lib.make_channel(channels)
            else:
                chan = channels.get(e.key) or channel_lib.LoopbackChannel()
            self.edges[e.key] = EdgeTransport(
                e, i, seed=seed, policy=pol,
                breaker=breaker() if callable(breaker) else None,
                chan=chan, chaos=chaos)
        # static per-(view, edge) unit charges for serving requests
        self._unit_bits = {e.key: float(cfg.d_bottleneck
                                        * topology_lib.edge_bits(e, cfg))
                           for e in self.topo.edges}
        self._routes = {name: self._route(name)
                        for name in self.topo.view_nodes()}

    # -- helpers -----------------------------------------------------------

    def _route(self, name: str):
        out, cur = [], name
        while cur != self.topo.fuse_node:
            e = self.topo.out_edge(cur)
            out.append(e)
            cur = e.dst
        return out

    def _node_dead(self, name: str, tick: int) -> bool:
        if self.chaos is not None and self.chaos.node_dead(name, tick):
            return True
        return self.node_down is not None and self.node_down(name, tick)

    def _apply_adaptive(self) -> None:
        """Install the controller's current knobs on every edge (called at
        the top of each tick, before any transmit — so a retune triggered
        by tick t's observations first applies at tick t+1)."""
        for et in self.edges.values():
            et.policy = self.adaptive.policy_for(et.edge.key)
            if hasattr(et.breaker, "failure_threshold"):
                et.breaker.failure_threshold = \
                    self.adaptive.threshold_for(et.edge.key)

    def breaker_states(self) -> Dict[str, str]:
        return {k: et.breaker.state for k, et in self.edges.items()}

    def snapshot(self) -> Dict[str, object]:
        """Ledger + breaker counters (the chaos bench's record)."""
        return {
            "offered_bits": self.meter.total_bits,
            "delivered_bits": self.meter.delivered_bits,
            "delivery_ratio": self.meter.delivery_ratio,
            "breaker": {k: {"state": et.breaker.state,
                            "opens": et.breaker.opens,
                            "short_circuits": et.breaker.short_circuits}
                        for k, et in self.edges.items()},
            **({"adaptive": self.adaptive.state_dict()}
               if self.adaptive is not None else {}),
        }

    def load_snapshot(self, snap: Dict[str, object]) -> None:
        """Restore the REPLAYABLE half of a `snapshot()` — the adaptive
        controller's window accumulators and retuned knobs.  Breaker/ledger
        counters are NOT loaded here: resume rebuilds breakers by replaying
        completed ticks with ``charge=False`` and restores ledgers from the
        checkpoint sidecar's meter dump, so loading them twice would
        double-count.  Loading adaptive state after that replay is
        idempotent (the replay reproduces the same trajectory) but makes
        the sidecar authoritative."""
        state = snap.get("adaptive") if isinstance(snap, dict) else None
        if self.adaptive is None or state is None:
            return
        with self._lock:
            self.adaptive.load_state_dict(state)
            self._apply_adaptive()

    def close(self) -> None:
        for et in self.edges.values():
            et.channel.close()

    # -- serving: one request ---------------------------------------------

    def send_request(self, rid: int, views=None,
                     deadline_ms: Optional[float] = None) -> RequestReport:
        """Route one request's J view fragments to the fusion center.

        Each view's fragment traverses its route's channels hop by hop
        (store-and-forward); every hop runs the edge's retry/breaker
        machinery against its LinkModel + chaos window at tick=rid.  A view
        is `on_time` when every hop delivered and the cumulative simulated
        latency met the deadline (engine deadline, else
        cfg.fusion_deadline_ms, else no deadline); delivered-but-late views
        are the stragglers speculative fusion patches into the next bucket.
        Offered bits are charged per attempt; delivered credit is the
        ENGINE's call (`credit_delivered`) once a fusion consumed the
        views."""
        if self.on_tick is not None:
            self.on_tick(rid)
        if deadline_ms is None:
            deadline_ms = getattr(self.cfg, "fusion_deadline_ms", None)
        names = self.topo.view_nodes()
        J = len(names)
        on_time = np.zeros(J, bool)
        eventual = np.zeros(J, bool)
        lat = np.full(J, np.inf, np.float64)
        received: List[Optional[np.ndarray]] = [None] * J
        attempts: Dict[str, int] = {}
        with self._lock:
            if self.adaptive is not None:
                self._apply_adaptive()
            for j, name in enumerate(names):
                if self._node_dead(name, rid):
                    continue                      # a dead node sends nothing
                frame = None
                if views is not None:
                    frame = channel_lib.encode_fragment(
                        rid, j, np.asarray(views[j]))
                t = 0.0
                delivered = True
                for e in self._routes[name]:
                    et = self.edges[e.key]
                    if self._node_dead(e.src, rid):
                        delivered = False
                        break
                    res = et.transmit(DOMAIN_REQUEST, rid,
                                      self._unit_bits[e.key], frame)
                    attempts[e.key] = attempts.get(e.key, 0) + res.attempts
                    self.meter.add_edge(
                        e.key, bits=res.attempts * self._unit_bits[e.key])
                    t += res.latency_ms
                    got = et.receive() if res.ok else None
                    hop_ok = res.ok and got is not None
                    if self.adaptive is not None:
                        self.adaptive.observe(e.key, offered=res.attempts,
                                              delivered=float(hop_ok))
                    if not hop_ok:
                        delivered = False
                        break
                    frame = got if frame is not None else None
                if not delivered:
                    continue
                eventual[j] = True
                lat[j] = t
                on_time[j] = deadline_ms is None or t <= deadline_ms
                if frame is not None:
                    _, jj, arr = channel_lib.decode_fragment(frame)
                    assert jj == j
                    received[j] = arr
        return RequestReport(rid=rid, on_time=on_time, eventual=eventual,
                             latency_ms=lat,
                             received=received if views is not None else None,
                             attempts=attempts)

    def credit_delivered(self, mask: np.ndarray) -> None:
        """Credit one completed fusion's consumed views on the delivered
        ledger: each edge earns its unit charge per payload view the fusion
        actually used (speculative patching credits stragglers here when
        their patched fusion lands)."""
        mask = np.asarray(mask, bool)
        with self._lock:
            for e in self.topo.edges:
                pay = list(self.topo.payload(e))
                n = int(mask[pay].sum())
                if n:
                    self.meter.add_delivered(
                        bits=n * self._unit_bits[e.key], edge=e.key)

    # -- training: one round ----------------------------------------------

    def round_outcome(self, round_idx: int, batch_size: int,
                      charges: Optional[Dict] = None,
                      charge: bool = True) -> RoundReport:
        """One training round's transport outcome at tick=round_idx.

        Each edge carries its round payload once (the whole batch's latent
        block, both directions — the same per-edge basis the runner's
        static `charges` use); retries/breaker/chaos apply per edge.  The
        (J,) mask composes routes exactly like the inline-mask path:
        a view fuses iff every hop delivered (dead nodes fail their own
        subtree) and its cumulative latency met cfg.fusion_deadline_ms.
        Offered/delivered are charged here (per attempt / per surviving
        payload fraction — `linkfault.round_fault_charges` convention with
        the retry multiplier on the offered side).  `charge=False` replays
        the round WITHOUT touching the ledgers — how a resumed run
        fast-forwards the transport (breaker trajectories included)
        through rounds a checkpoint already accounted for."""
        if self.on_tick is not None:
            self.on_tick(round_idx)
        topo, cfg = self.topo, self.cfg
        if charges is None:
            bits = topology_lib.round_edge_bits(topo, cfg, batch_size)
            charges = {k: (b, b / 8.0) for k, b in bits.items()}
        deadline = getattr(cfg, "fusion_deadline_ms", None)
        results: Dict[str, EdgeResult] = {}
        attempts: Dict[str, int] = {}
        with self._lock:
            if self.adaptive is not None:
                self._apply_adaptive()
            for e in topo.edges:
                et = self.edges[e.key]
                ebits, _ = charges[e.key]
                if self._node_dead(e.src, round_idx):
                    results[e.key] = EdgeResult(ok=False, latency_ms=0.0)
                    attempts[e.key] = 0
                    continue
                res = et.transmit(DOMAIN_ROUND, round_idx, ebits)
                if res.ok and et.receive() is None:
                    res = EdgeResult(ok=False, latency_ms=res.latency_ms,
                                     attempts=res.attempts)
                results[e.key] = res
                attempts[e.key] = res.attempts
            names = topo.view_nodes()
            J = len(names)
            mask = np.zeros(J, bool)
            lat = np.full(J, np.inf, np.float64)
            for j, name in enumerate(names):
                if self._node_dead(name, round_idx):
                    continue
                t, ok = 0.0, True
                for e in self._routes[name]:
                    res = results[e.key]
                    if not res.ok:
                        ok = False
                        break
                    t += res.latency_ms
                if ok:
                    lat[j] = t
                    mask[j] = deadline is None or t <= deadline
            # per-edge surviving payload fraction: the delivered basis for
            # both the ledger credit and the adaptive controller
            fracs = {}
            for e in topo.edges:
                pay = list(topo.payload(e))
                fracs[e.key] = float(mask[pay].sum()) / len(pay)
            # the controller observes every round — charged or not — so an
            # uncharged resume replay rebuilds the same knob trajectory
            if self.adaptive is not None:
                for e in topo.edges:
                    self.adaptive.observe(e.key, offered=attempts[e.key],
                                          delivered=fracs[e.key])
            # ledgers: attempts re-offer the edge's nominal charge; the
            # delivered credit is the surviving payload fraction
            if charge:
                for e in topo.edges:
                    ebits, enbytes = charges[e.key]
                    a = attempts[e.key]
                    self.meter.add_edge(e.key, bits=a * ebits,
                                        nbytes=a * enbytes)
                    frac = fracs[e.key]
                    if frac:
                        self.meter.add_delivered(bits=ebits * frac,
                                                 nbytes=enbytes * frac,
                                                 edge=e.key)
        return RoundReport(tick=round_idx, mask=mask, latency_ms=lat,
                           attempts=attempts)
