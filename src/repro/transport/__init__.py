"""Fault-tolerant edge transport: channels, retry/breaker policies, adaptive
retuning, and the per-topology `NetworkTransport` that turns
`linkfault.LinkModel` parameters into actual transport outcomes (delivered /
late / lost payloads) for the serving engine and the training round paths.

Exports resolve lazily (PEP 562): `repro.cluster.worker` processes import
`repro.transport.channel` through this package, and pulling `network` eagerly
would drag jax into every spawned worker — the channel layer itself needs
only numpy and the standard library.
"""
import importlib

_EXPORTS = {
    # channel layer (numpy + stdlib only — worker processes import these)
    "CHANNEL_KINDS": "channel", "Channel": "channel",
    "ChannelError": "channel", "HandshakeError": "channel",
    "LoopbackChannel": "channel", "SocketChannel": "channel",
    "TcpListener": "channel", "PROTOCOL_VERSION": "channel",
    "decode_fragment": "channel", "encode_fragment": "channel",
    "make_channel": "channel",
    # transport proper (imports the core ledgers -> jax)
    "DOMAIN_REQUEST": "network", "DOMAIN_ROUND": "network",
    "EdgeResult": "network", "EdgeTransport": "network",
    "NetworkTransport": "network", "RequestReport": "network",
    "RoundReport": "network",
    # policies
    "DEFAULT_RETRY": "policy", "NO_RETRY": "policy",
    "CircuitBreaker": "policy", "NoBreaker": "policy",
    "RetryPolicy": "policy",
    "AdaptiveConfig": "adaptive", "AdaptivePolicy": "adaptive",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    value = getattr(importlib.import_module(f"{__name__}.{mod}"), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
