"""Fault-tolerant edge transport: channels, retry/breaker policies, and the
per-topology `NetworkTransport` that turns `linkfault.LinkModel` parameters
into actual transport outcomes (delivered / late / lost payloads) for the
serving engine and the training round paths."""
from repro.transport.channel import (CHANNEL_KINDS, Channel, LoopbackChannel,
                                     SocketChannel, decode_fragment,
                                     encode_fragment, make_channel)
from repro.transport.network import (DOMAIN_REQUEST, DOMAIN_ROUND,
                                     EdgeResult, EdgeTransport,
                                     NetworkTransport, RequestReport,
                                     RoundReport)
from repro.transport.policy import (DEFAULT_RETRY, NO_RETRY, CircuitBreaker,
                                    NoBreaker, RetryPolicy)

__all__ = [
    "CHANNEL_KINDS", "Channel", "LoopbackChannel", "SocketChannel",
    "decode_fragment", "encode_fragment", "make_channel",
    "DOMAIN_REQUEST", "DOMAIN_ROUND", "EdgeResult", "EdgeTransport",
    "NetworkTransport", "RequestReport", "RoundReport",
    "DEFAULT_RETRY", "NO_RETRY", "CircuitBreaker", "NoBreaker", "RetryPolicy",
]
