"""Per-edge delivery policies: bounded retries and circuit breaking.

A `Channel` (transport/channel.py) moves bytes; these policies decide how
hard an edge TRIES.  Both are deliberately tiny state machines so the chaos
harness (repro/chaos.py) can assert their transitions exactly:

    RetryPolicy     how many attempts one payload gets, the per-attempt
                    timeout, and the exponential-backoff-with-jitter delay
                    between attempts.  The jitter draw is an INPUT (a
                    uniform in [0, 1) from the transport's seeded stream),
                    so a schedule replays bit-identically.

    CircuitBreaker  classic three-state breaker per edge: CLOSED counts
                    consecutive failures and OPENs at `failure_threshold`;
                    OPEN short-circuits every transmission (nothing is
                    offered to a link that is known-dead — the wasted-bits
                    bound BENCH_chaos.json asserts) until `cooldown` ticks
                    elapsed; then ONE half-open probe rides the link — its
                    success CLOSEs the breaker, its failure re-OPENs it and
                    restarts the cooldown.

Time is counted in TICKS — one tick per transmission opportunity (a
training round, or a request id at serving time) — not wall-clock, so
breaker trajectories are pure functions of the outcome sequence and the
deterministic chaos schedules stay deterministic end to end.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff and deterministic jitter.

    max_attempts     total tries per payload (1 = no retry — the legacy
                     one-shot semantics linkfault's inline masks model)
    base_backoff_ms  delay before the 2nd attempt
    backoff_mult     exponential growth per further attempt
    max_backoff_ms   backoff ceiling
    jitter           fraction of the backoff randomised away (0 = none;
                     0.5 = delay uniform in [0.5, 1.0] x backoff)
    timeout_ms       per-attempt timeout: an attempt whose link latency
                     draw exceeds it counts as FAILED (and is retried) even
                     if the payload would eventually have arrived.  None
                     disables the timeout.
    """
    max_attempts: int = 1
    base_backoff_ms: float = 1.0
    backoff_mult: float = 2.0
    max_backoff_ms: float = 64.0
    jitter: float = 0.5
    timeout_ms: Optional[float] = None

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got "
                             f"{self.max_attempts}")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")
        if self.base_backoff_ms < 0 or self.max_backoff_ms < 0:
            raise ValueError("backoff delays must be >= 0")

    def backoff_ms(self, attempt: int, u: float = 0.0) -> float:
        """Delay BEFORE attempt `attempt` (0-based; attempt 0 never waits).
        `u` is a uniform [0, 1) jitter draw from the caller's seeded stream
        — the same (attempt, u) pair always yields the same delay."""
        if attempt <= 0:
            return 0.0
        raw = min(self.base_backoff_ms * self.backoff_mult ** (attempt - 1),
                  self.max_backoff_ms)
        return raw * (1.0 - self.jitter * float(u))

    def attempt_failed(self, latency_ms: float) -> bool:
        """Whether a surviving transmission still MISSED its per-attempt
        timeout (counted as a failure and retried)."""
        return self.timeout_ms is not None and latency_ms > self.timeout_ms


#: the legacy semantics: one shot, no timeout — linkfault's inline masks
NO_RETRY = RetryPolicy(max_attempts=1)
#: a sane default for retrying transports
DEFAULT_RETRY = RetryPolicy(max_attempts=3)


class CircuitBreaker:
    """Per-edge three-state breaker over tick time.

    CLOSED    transmissions flow; `failure_threshold` CONSECUTIVE failures
              trip the breaker OPEN (a success resets the count).
    OPEN      `allow` short-circuits (False) — the edge is not even
              offered traffic — until `cooldown` ticks after the trip.
    HALF_OPEN the first `allow` after the cooldown admits one probe:
              `record_success` CLOSEs the breaker, `record_failure`
              re-OPENs it and restarts the cooldown from that tick.

    Counters (`opens`, `short_circuits`, `probes`) feed the chaos bench's
    wasted-bandwidth accounting.  Not thread-safe by itself — the owning
    NetworkTransport serialises access.
    """

    def __init__(self, failure_threshold: int = 3, cooldown: int = 4):
        if failure_threshold < 1:
            raise ValueError(f"failure_threshold must be >= 1, got "
                             f"{failure_threshold}")
        if cooldown < 1:
            raise ValueError(f"cooldown must be >= 1 tick, got {cooldown}")
        self.failure_threshold = failure_threshold
        self.cooldown = cooldown
        self.state = CLOSED
        self.consecutive_failures = 0
        self.opened_at_tick: Optional[int] = None
        self.opens = 0
        self.short_circuits = 0
        self.probes = 0

    def allow(self, tick: int) -> bool:
        """May a transmission ride the edge at `tick`?  OPEN short-circuits
        until the cooldown elapses, then admits a half-open probe."""
        if self.state == OPEN:
            if tick - self.opened_at_tick >= self.cooldown:
                self.state = HALF_OPEN
                self.probes += 1
                return True
            self.short_circuits += 1
            return False
        if self.state == HALF_OPEN:
            # one probe is already in flight this tick sequence; further
            # traffic keeps short-circuiting until its verdict lands
            self.short_circuits += 1
            return False
        return True

    def record_success(self) -> None:
        self.state = CLOSED
        self.consecutive_failures = 0
        self.opened_at_tick = None

    def record_failure(self, tick: int) -> None:
        self.consecutive_failures += 1
        if self.state == HALF_OPEN or \
                self.consecutive_failures >= self.failure_threshold:
            if self.state != OPEN:
                self.opens += 1
            self.state = OPEN
            self.opened_at_tick = tick


class NoBreaker:
    """The null object: every transmission allowed (the no-breaker baseline
    the chaos bench compares wasted offered bits against)."""

    state = "disabled"
    opens = 0
    short_circuits = 0
    probes = 0

    def allow(self, tick: int) -> bool:
        return True

    def record_success(self) -> None:
        pass

    def record_failure(self, tick: int) -> None:
        pass
