"""Adaptive fault policies: retune retry budgets and breaker thresholds
from the measured delivered/offered ratio instead of fixed constants.

The controller is deliberately boring: per edge it accumulates a WINDOW of
transport outcomes (offered attempt units vs delivered payload fraction —
the same basis as the BandwidthMeter's two ledgers), and at each window
boundary nudges two knobs one step:

    ratio < ratio_low    the link is wasting offered bandwidth — shrink the
                         retry budget toward 1 and lower the breaker's
                         open-threshold (open faster, stop re-offering into
                         a dead link);
    ratio >= ratio_high  the link is healthy — step both knobs back toward
                         their configured base.

Everything is a pure function of the observation sequence: no wall clock,
no randomness.  Replaying the same transport outcomes (e.g. the uncharged
`round_outcome(..., charge=False)` fast-forward a resumed run performs)
rebuilds the same knob trajectory, and `state_dict()`/`load_state_dict()`
round-trip the controller through the crash-atomic checkpoint sidecar —
so an adaptive run resumes bit-identically, knobs included.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

from repro.transport.policy import DEFAULT_RETRY, RetryPolicy


@dataclass(frozen=True)
class AdaptiveConfig:
    """Window rules for the controller (see module docstring)."""
    window: int = 8               # observations per edge per retune
    ratio_low: float = 0.5        # below: tighten (fewer attempts, open faster)
    ratio_high: float = 0.9       # at/above: relax back toward base
    min_attempts: int = 1
    min_threshold: int = 1


class AdaptivePolicy:
    """Per-edge retry/breaker controller driven by delivered/offered.

    base             the RetryPolicy ceiling (its max_attempts is the upper
                     bound the controller relaxes back to).
    base_threshold   the breaker open-threshold ceiling — match it to the
                     CircuitBreaker the transport installs.
    """

    def __init__(self, base: RetryPolicy = DEFAULT_RETRY,
                 base_threshold: int = 3,
                 config: Optional[AdaptiveConfig] = None):
        self.base = base
        self.base_threshold = int(base_threshold)
        self.config = config or AdaptiveConfig()
        self._attempts: Dict[str, int] = {}     # current per-edge budget
        self._thresholds: Dict[str, int] = {}   # current per-edge threshold
        # per-edge open window: [observations, offered units, delivered units]
        self._window: Dict[str, list] = {}
        self.retunes = 0

    # -- knobs --------------------------------------------------------------

    def policy_for(self, edge_key: str) -> RetryPolicy:
        n = self._attempts.get(edge_key, self.base.max_attempts)
        if n == self.base.max_attempts:
            return self.base
        return dataclasses.replace(self.base, max_attempts=n)

    def threshold_for(self, edge_key: str) -> int:
        return self._thresholds.get(edge_key, self.base_threshold)

    # -- observations -------------------------------------------------------

    def observe(self, edge_key: str, *, offered: float,
                delivered: float) -> None:
        """One transport outcome on one edge: `offered` in attempt units
        (0 when the breaker short-circuited every attempt), `delivered` as
        the payload fraction that reached the consumer (0..1)."""
        w = self._window.setdefault(edge_key, [0, 0.0, 0.0])
        w[0] += 1
        w[1] += float(offered)
        w[2] += float(delivered)
        if w[0] >= self.config.window:
            self._retune(edge_key, w)
            self._window[edge_key] = [0, 0.0, 0.0]

    def _retune(self, edge_key: str, w: list) -> None:
        self.retunes += 1
        cfg = self.config
        cur_a = self._attempts.get(edge_key, self.base.max_attempts)
        cur_t = self._thresholds.get(edge_key, self.base_threshold)
        if w[1] <= 0.0:
            # the breaker refused the whole window: nothing was offered, so
            # the ratio is uninformative — hold the knobs where they are
            return
        ratio = w[2] / w[1]
        if ratio < cfg.ratio_low:
            a = max(cfg.min_attempts, cur_a - 1)
            t = max(cfg.min_threshold, cur_t - 1)
        elif ratio >= cfg.ratio_high:
            a = min(self.base.max_attempts, cur_a + 1)
            t = min(self.base_threshold, cur_t + 1)
        else:
            return
        self._attempts[edge_key] = a
        self._thresholds[edge_key] = t

    # -- checkpointing ------------------------------------------------------

    def state_dict(self) -> Dict[str, object]:
        return {
            "attempts": dict(self._attempts),
            "thresholds": dict(self._thresholds),
            "window": {k: list(v) for k, v in self._window.items()},
            "retunes": self.retunes,
        }

    def load_state_dict(self, state: Dict[str, object]) -> None:
        self._attempts = {k: int(v) for k, v in state["attempts"].items()}
        self._thresholds = {k: int(v)
                            for k, v in state["thresholds"].items()}
        self._window = {k: [int(v[0]), float(v[1]), float(v[2])]
                        for k, v in state["window"].items()}
        self.retunes = int(state["retunes"])
