"""Edge channels: the byte-moving layer under the fault-tolerant transport.

One `Channel` per topology edge.  The interface is deliberately minimal —
length-prefixed frames in submission order — because everything clever
(retries, backoff, breakers, fault injection, routing) lives ABOVE it in
`transport/network.py`.  Two implementations share it:

    LoopbackChannel   an in-process deque — the fast path the serving
                      engine uses by default (same process, no
                      serialisation cost beyond the frame encode).

    SocketChannel     a REAL socket (`socket.socketpair()` — an AF_UNIX
                      stream pair, i.e. actual kernel buffers): frames are
                      serialised, written to one end and read back from the
                      other, so a payload served over it genuinely left
                      Python object space.  The contract tests run the same
                      suite over both transports.

Frames carry view fragments: `(request id, view index, ndarray)` encoded
with a fixed header (`encode_fragment`/`decode_fragment`), so a fragment
that crossed a socket reconstructs bit-identically on the far side.
"""
from __future__ import annotations

import collections
import socket
import struct
import threading
from typing import Optional, Tuple

import numpy as np

# frame header: magic, request id, view index, dtype tag length, ndim
_MAGIC = 0x494E4C46                     # "INLF"
_HEAD = struct.Struct("<IqiBB")
_LEN = struct.Struct("<I")
_MAX_FRAME = 1 << 28                    # 256 MB sanity bound


def encode_fragment(rid: int, view_index: int, arr: np.ndarray) -> bytes:
    """One view fragment as a self-describing byte frame."""
    arr = np.ascontiguousarray(arr)
    dt = arr.dtype.str.encode("ascii")
    head = _HEAD.pack(_MAGIC, rid, view_index, len(dt), arr.ndim)
    dims = struct.pack(f"<{arr.ndim}q", *arr.shape)
    return head + dt + dims + arr.tobytes()


def decode_fragment(frame: bytes) -> Tuple[int, int, np.ndarray]:
    """Inverse of `encode_fragment`; bit-exact round trip."""
    magic, rid, j, dtlen, ndim = _HEAD.unpack_from(frame, 0)
    if magic != _MAGIC:
        raise ValueError(f"bad fragment frame (magic {magic:#x})")
    off = _HEAD.size
    dt = np.dtype(frame[off:off + dtlen].decode("ascii"))
    off += dtlen
    shape = struct.unpack_from(f"<{ndim}q", frame, off)
    off += 8 * ndim
    arr = np.frombuffer(frame, dtype=dt, count=int(np.prod(shape, dtype=np.int64)) if ndim else 1,
                        offset=off).reshape(shape)
    return rid, j, arr.copy()


class Channel:
    """One directed edge's byte pipe: ordered, length-prefixed frames."""

    kind = "abstract"

    def send(self, frame: bytes) -> None:
        raise NotImplementedError

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        """Next frame, or None when nothing arrives within `timeout`
        seconds (None blocks; 0 polls)."""
        raise NotImplementedError

    def close(self) -> None:
        pass


class LoopbackChannel(Channel):
    """In-process channel: a bounded deque behind a condition variable."""

    kind = "loopback"

    def __init__(self):
        self._frames = collections.deque()
        self._cond = threading.Condition()
        self._closed = False

    def send(self, frame: bytes) -> None:
        with self._cond:
            if self._closed:
                raise RuntimeError("send on closed loopback channel")
            self._frames.append(bytes(frame))
            self._cond.notify()

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        with self._cond:
            if not self._frames and not self._closed:
                self._cond.wait(timeout)
            return self._frames.popleft() if self._frames else None

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()


class SocketChannel(Channel):
    """A real kernel-buffered byte pipe (`socket.socketpair()`), framed with
    a 4-byte length prefix.  send() may block briefly when the kernel buffer
    fills; recv() honours `timeout` via the socket timeout."""

    kind = "socket"

    def __init__(self):
        self._tx, self._rx = socket.socketpair()
        self._tx_lock = threading.Lock()
        self._rx_lock = threading.Lock()
        self._closed = False

    def send(self, frame: bytes) -> None:
        if self._closed:
            raise RuntimeError("send on closed socket channel")
        if len(frame) > _MAX_FRAME:
            raise ValueError(f"frame of {len(frame)} bytes exceeds the "
                             f"{_MAX_FRAME} byte channel bound")
        with self._tx_lock:
            self._tx.sendall(_LEN.pack(len(frame)) + frame)

    def _read_exact(self, n: int) -> Optional[bytes]:
        buf = bytearray()
        while len(buf) < n:
            chunk = self._rx.recv(n - len(buf))
            if not chunk:
                return None                      # peer closed mid-frame
            buf.extend(chunk)
        return bytes(buf)

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        with self._rx_lock:
            self._rx.settimeout(timeout)
            try:
                head = self._read_exact(_LEN.size)
            except (socket.timeout, TimeoutError):
                return None
            except OSError:
                return None
            if head is None:
                return None
            (n,) = _LEN.unpack(head)
            # the length prefix arrived: the body is in flight — wait for it
            self._rx.settimeout(None)
            return self._read_exact(n)

    def close(self) -> None:
        self._closed = True
        for s in (self._tx, self._rx):
            try:
                s.close()
            except OSError:
                pass


CHANNEL_KINDS = ("loopback", "socket")


def make_channel(kind: str = "loopback") -> Channel:
    """Factory the NetworkTransport uses per edge."""
    if kind == "loopback":
        return LoopbackChannel()
    if kind == "socket":
        return SocketChannel()
    raise ValueError(f"unknown channel kind {kind!r}; one of {CHANNEL_KINDS}")
