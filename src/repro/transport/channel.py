"""Edge channels: the byte-moving layer under the fault-tolerant transport.

One `Channel` per topology edge.  The interface is deliberately minimal —
length-prefixed frames in submission order — because everything clever
(retries, backoff, breakers, fault injection, routing) lives ABOVE it in
`transport/network.py`.  Implementations sharing it:

    LoopbackChannel   an in-process deque — the fast path the serving
                      engine uses by default (same process, no
                      serialisation cost beyond the frame encode).

    SocketChannel     a REAL socket, framed with a 4-byte length prefix.
                      Two modes: `SocketChannel()` wraps a
                      `socket.socketpair()` (AF_UNIX kernel buffers, both
                      ends in-process), while `TcpListener.accept()` /
                      `SocketChannel.connect()` put the two ends in
                      DIFFERENT processes over TCP with a versioned
                      handshake — the mode `repro/cluster` uses to talk to
                      supervised worker processes.

Failure semantics are typed and deliberately narrow:

    * a peer closing cleanly at a frame boundary -> `recv` returns None;
    * a peer vanishing mid-frame (short read of the 4-byte length prefix
      or of the body) -> `ChannelError` — never silent partial bytes;
    * handshake problems (bad magic, protocol version mismatch, wrong
      peer) -> `HandshakeError`;
    * `close()` is idempotent and thread-safe against concurrent
      send/recv — a blocked `recv` returns None, a subsequent `send`
      raises `ChannelError`.

Frames carry view fragments: `(request id, view index, ndarray)` encoded
with a fixed header (`encode_fragment`/`decode_fragment`), so a fragment
that crossed a socket reconstructs bit-identically on the far side.
"""
from __future__ import annotations

import collections
import socket
import struct
import threading
import time
from typing import Optional, Tuple

import numpy as np

# frame header: magic, request id, view index, dtype tag length, ndim
_MAGIC = 0x494E4C46                     # "INLF"
_HEAD = struct.Struct("<IqiBB")
_LEN = struct.Struct("<I")
_MAX_FRAME = 1 << 28                    # 256 MB sanity bound

# connection handshake: magic, protocol version, peer-name length
PROTOCOL_VERSION = 1
_HELLO_MAGIC = 0x494E4C48               # "INLH"
_HELLO = struct.Struct("<IHH")
_MAX_HELLO = 4096


class ChannelError(RuntimeError):
    """A channel failed in a way the transport should treat as a lost
    transmission: torn frame, abrupt peer close, send on a closed pipe."""


class HandshakeError(ChannelError):
    """Connection setup failed.  `fatal=True` marks mismatches reconnecting
    cannot fix (wrong protocol version, wrong peer identity)."""

    def __init__(self, msg: str, *, fatal: bool = False):
        super().__init__(msg)
        self.fatal = fatal


def encode_fragment(rid: int, view_index: int, arr: np.ndarray) -> bytes:
    """One view fragment as a self-describing byte frame."""
    arr = np.ascontiguousarray(arr)
    dt = arr.dtype.str.encode("ascii")
    head = _HEAD.pack(_MAGIC, rid, view_index, len(dt), arr.ndim)
    dims = struct.pack(f"<{arr.ndim}q", *arr.shape)
    return head + dt + dims + arr.tobytes()


def decode_fragment(frame: bytes) -> Tuple[int, int, np.ndarray]:
    """Inverse of `encode_fragment`; bit-exact round trip."""
    magic, rid, j, dtlen, ndim = _HEAD.unpack_from(frame, 0)
    if magic != _MAGIC:
        raise ValueError(f"bad fragment frame (magic {magic:#x})")
    off = _HEAD.size
    dt = np.dtype(frame[off:off + dtlen].decode("ascii"))
    off += dtlen
    shape = struct.unpack_from(f"<{ndim}q", frame, off)
    off += 8 * ndim
    arr = np.frombuffer(frame, dtype=dt, count=int(np.prod(shape, dtype=np.int64)) if ndim else 1,
                        offset=off).reshape(shape)
    return rid, j, arr.copy()


class Channel:
    """One directed edge's byte pipe: ordered, length-prefixed frames."""

    kind = "abstract"
    eof = False          # True once the peer closed cleanly (recv -> None
                         # then means "gone", not "nothing yet")

    def send(self, frame: bytes) -> None:
        raise NotImplementedError

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        """Next frame, or None when nothing arrives within `timeout`
        seconds (None blocks; 0 polls)."""
        raise NotImplementedError

    def close(self) -> None:
        pass


class LoopbackChannel(Channel):
    """In-process channel: a bounded deque behind a condition variable."""

    kind = "loopback"

    def __init__(self):
        self._frames = collections.deque()
        self._cond = threading.Condition()
        self._closed = False

    def send(self, frame: bytes) -> None:
        with self._cond:
            if self._closed:
                raise ChannelError("send on closed loopback channel")
            self._frames.append(bytes(frame))
            self._cond.notify()

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        with self._cond:
            if not self._frames and not self._closed:
                self._cond.wait(timeout)
            return self._frames.popleft() if self._frames else None

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()


def _pack_hello(name: str) -> bytes:
    nb = name.encode("utf-8")
    body = _HELLO.pack(_HELLO_MAGIC, PROTOCOL_VERSION, len(nb)) + nb
    return _LEN.pack(len(body)) + body


def _sock_read_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except (socket.timeout, TimeoutError) as e:
            raise HandshakeError("timed out waiting for hello") from e
        except OSError as e:
            raise HandshakeError(f"socket error during handshake: {e}") from e
        if not chunk:
            raise HandshakeError("peer closed during handshake")
        buf.extend(chunk)
    return bytes(buf)


def _read_hello(sock: socket.socket) -> str:
    (n,) = _LEN.unpack(_sock_read_exact(sock, _LEN.size))
    if n > _MAX_HELLO:
        raise HandshakeError(f"oversized hello ({n} bytes)", fatal=True)
    payload = _sock_read_exact(sock, n)
    if len(payload) < _HELLO.size:
        raise HandshakeError("short hello", fatal=True)
    magic, version, nlen = _HELLO.unpack_from(payload, 0)
    if magic != _HELLO_MAGIC:
        raise HandshakeError(f"bad hello magic {magic:#x}", fatal=True)
    if version != PROTOCOL_VERSION:
        raise HandshakeError(
            f"protocol version mismatch: peer speaks v{version}, "
            f"this build speaks v{PROTOCOL_VERSION}", fatal=True)
    return payload[_HELLO.size:_HELLO.size + nlen].decode("utf-8")


class SocketChannel(Channel):
    """A real kernel-buffered byte pipe framed with a 4-byte length prefix.

    `SocketChannel()` wraps a `socket.socketpair()` (both ends in this
    process); `SocketChannel.connect()` / `TcpListener.accept()` wrap one
    end of a TCP connection whose peer lives in another process.  send()
    may block briefly when the kernel buffer fills; recv() honours
    `timeout` via the socket timeout.  A timed-out recv never loses bytes:
    a partial length prefix stays buffered for the next call."""

    kind = "socket"

    def __init__(self, sock: Optional[socket.socket] = None, *, peer: str = ""):
        if sock is None:
            self._tx, self._rx = socket.socketpair()
        else:
            self._tx = self._rx = sock
        self.peer = peer
        self._tx_lock = threading.Lock()
        self._rx_lock = threading.Lock()
        self._close_lock = threading.Lock()
        self._head_buf = bytearray()
        self._closed = False

    # -- connection setup ---------------------------------------------------

    @classmethod
    def connect(cls, host: str, port: int, *, name: str = "client",
                expect_peer: Optional[str] = None, timeout: float = 5.0,
                attempts: int = 5, backoff_s: float = 0.05,
                backoff_cap_s: float = 1.0) -> "SocketChannel":
        """Dial a `TcpListener` with a bounded reconnect loop (capped
        exponential backoff).  Fatal handshake mismatches (wrong protocol
        version, wrong peer) raise immediately; refused/reset connections
        retry up to `attempts` times before raising `ChannelError`."""
        last: Optional[BaseException] = None
        for i in range(max(1, attempts)):
            if i:
                time.sleep(min(backoff_s * (2 ** (i - 1)), backoff_cap_s))
            try:
                sock = socket.create_connection((host, port), timeout=timeout)
            except OSError as e:
                last = e
                continue
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            sock.settimeout(timeout)
            try:
                sock.sendall(_pack_hello(name))
                peer = _read_hello(sock)
            except HandshakeError as e:
                sock.close()
                if e.fatal:
                    raise
                last = e
                continue
            except OSError as e:
                sock.close()
                last = e
                continue
            if expect_peer is not None and peer != expect_peer:
                sock.close()
                raise HandshakeError(
                    f"connected to {peer!r}, expected {expect_peer!r}",
                    fatal=True)
            sock.settimeout(None)
            return cls(sock=sock, peer=peer)
        raise ChannelError(
            f"could not connect to {host}:{port} after {max(1, attempts)} "
            f"attempts: {last}") from last

    # -- framing ------------------------------------------------------------

    def send(self, frame: bytes) -> None:
        if len(frame) > _MAX_FRAME:
            raise ValueError(f"frame of {len(frame)} bytes exceeds the "
                             f"{_MAX_FRAME} byte channel bound")
        with self._tx_lock:
            if self._closed:
                raise ChannelError("send on closed socket channel")
            try:
                self._tx.sendall(_LEN.pack(len(frame)) + frame)
            except OSError as e:
                if self._closed:
                    raise ChannelError("send on closed socket channel") from e
                raise ChannelError(f"send failed: {e}") from e

    def recv(self, timeout: Optional[float] = None) -> Optional[bytes]:
        with self._rx_lock:
            if self._closed:
                return None
            # 1) the 4-byte length prefix.  A timeout mid-prefix keeps the
            #    partial bytes buffered; an EOF mid-prefix is a torn frame.
            buf = self._head_buf
            try:
                self._rx.settimeout(timeout)
            except OSError:
                return None                      # closed under us
            while len(buf) < _LEN.size:
                try:
                    chunk = self._rx.recv(_LEN.size - len(buf))
                except (socket.timeout, TimeoutError):
                    return None
                except OSError as e:
                    if self._closed:
                        return None
                    raise ChannelError(
                        f"socket error while reading frame header: {e}") from e
                if not chunk:
                    if buf:
                        raise ChannelError(
                            f"peer closed mid-header "
                            f"({len(buf)}/{_LEN.size} bytes)")
                    self.eof = True
                    return None                  # clean EOF at a boundary
                buf.extend(chunk)
            (n,) = _LEN.unpack(bytes(buf))
            buf.clear()
            if n > _MAX_FRAME:
                raise ChannelError(f"frame of {n} bytes exceeds the "
                                   f"{_MAX_FRAME} byte channel bound")
            # 2) the body: the prefix arrived, so the body is in flight —
            #    wait for all of it; a short read here is a torn frame.
            self._rx.settimeout(None)
            body = bytearray()
            while len(body) < n:
                try:
                    chunk = self._rx.recv(n - len(body))
                except OSError as e:
                    if self._closed:
                        return None
                    raise ChannelError(
                        f"socket error while reading frame body: {e}") from e
                if not chunk:
                    raise ChannelError(
                        f"peer closed mid-frame ({len(body)}/{n} bytes)")
                body.extend(chunk)
            return bytes(body)

    def close(self) -> None:
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
        for s in {self._tx, self._rx}:
            try:
                s.shutdown(socket.SHUT_RDWR)     # unblock concurrent recv
            except OSError:
                pass
            try:
                s.close()
            except OSError:
                pass


class TcpListener:
    """Server side of the TCP channel mode: bind, accept, handshake.

    `accept()` validates the client hello (magic + protocol version),
    replies with this listener's name, and returns a connected
    `SocketChannel` whose `.peer` is the client's announced name — or None
    on accept timeout."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 name: str = "listener", backlog: int = 8):
        self.name = name
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(backlog)
        self.host, self.port = self._sock.getsockname()[:2]
        self._closed = False
        self._close_lock = threading.Lock()

    def accept(self, timeout: Optional[float] = None,
               *, handshake_timeout: float = 5.0) -> Optional[SocketChannel]:
        try:
            self._sock.settimeout(timeout)
            conn, _ = self._sock.accept()
        except (socket.timeout, TimeoutError):
            return None
        except OSError:
            if self._closed:
                return None
            raise
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        conn.settimeout(handshake_timeout)
        try:
            peer = _read_hello(conn)
            conn.sendall(_pack_hello(self.name))
        except (HandshakeError, OSError):
            conn.close()
            raise
        conn.settimeout(None)
        return SocketChannel(sock=conn, peer=peer)

    def close(self) -> None:
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass


CHANNEL_KINDS = ("loopback", "socket")


def make_channel(kind: str = "loopback") -> Channel:
    """Factory the NetworkTransport uses per edge."""
    if kind == "loopback":
        return LoopbackChannel()
    if kind == "socket":
        return SocketChannel()
    raise ValueError(f"unknown channel kind {kind!r}; one of {CHANNEL_KINDS}")
