"""Model zoo: full-model init/apply for every assigned architecture.

API
---
  init_params(cfg, key)                     -> params pytree
  forward(params, cfg, batch, mode, cache)  -> (logits, new_cache, aux)
  make_cache(cfg, batch_size, max_len)      -> cache pytree
  loss_and_metrics(params, cfg, batch)      -> (loss, metrics)
  param_count(cfg, active_only=False)       -> analytic N
  input_specs(cfg, shape_cfg)               -> {name: ShapeDtypeStruct}

Batch dict keys (all optional except labels in train mode):
  tokens        (B, S) int32           text / code token ids
  tokens_mc     (B, S, K) int32        audio: K parallel codebook streams
  input_embeds  (B, S, d)              audio stub frontend: frame embeddings
  patch_embeds  (B, P, d)              vlm stub frontend: patch embeddings
  labels        (B, S) or (B, S, K)    next-token targets, -1 = ignore
  cache_len     () int32               decode: #valid cache entries
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers, transformer


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {"stack": transformer.stack_init(ks[0], cfg, dtype),
         "final_norm": layers.rmsnorm_init(cfg.d_model, dtype)}
    if cfg.modality == "audio_tokens":
        # K codebook embedding tables, stored as one (K*Vpad, d) table.
        vpad = layers.pad_vocab(cfg.vocab_size)
        w = (jax.random.normal(ks[1], (cfg.num_codebooks * vpad, cfg.d_model),
                               jnp.float32) * 0.02).astype(dtype)
        p["embed"] = {"w": w}
        p["heads"] = layers.dense_init(ks[2], cfg.d_model,
                                       cfg.num_codebooks * vpad, dtype=dtype)
    else:
        p["embed"] = layers.embed_init(ks[1], cfg.vocab_size, cfg.d_model,
                                       dtype)
        if not cfg.tie_embeddings:
            p["unembed"] = layers.dense_init(
                ks[2], cfg.d_model, layers.pad_vocab(cfg.vocab_size),
                dtype=dtype)
    return p


def param_count(cfg, active_only: bool = False) -> int:
    """Analytic parameter count (matches init_params; verified in tests)."""
    from repro.models import moe as moe_mod
    n = transformer.stack_param_count(cfg) + cfg.d_model
    if active_only and cfg.is_moe:
        pat = transformer.block_pattern(cfg)
        nper = transformer.num_periods(cfg)
        n_moe_layers = nper * sum(1 for k in pat if k == "attn")
        n -= n_moe_layers * (moe_mod.moe_param_count(cfg)
                             - moe_mod.moe_active_param_count(cfg))
    vpad = layers.pad_vocab(cfg.vocab_size)
    if cfg.modality == "audio_tokens":
        n += 2 * cfg.num_codebooks * vpad * cfg.d_model
    else:
        n += vpad * cfg.d_model
        if not cfg.tie_embeddings:
            n += vpad * cfg.d_model
    return n


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed_inputs(p, cfg, batch):
    """Returns (h, positions).  Handles text / audio / vlm input plumbing."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.modality == "audio_tokens":
        if "input_embeds" in batch:           # stub EnCodec frontend output
            h = batch["input_embeds"].astype(dtype)
        else:
            vpad = layers.pad_vocab(cfg.vocab_size)
            toks = batch["tokens_mc"]         # (B,S,K)
            offs = jnp.arange(cfg.num_codebooks, dtype=jnp.int32) * vpad
            h = jnp.take(p["embed"]["w"], toks + offs, axis=0).sum(axis=2)
    elif cfg.modality == "vlm" and "patch_embeds" in batch:
        txt = layers.embed(p["embed"], batch["tokens"])
        h = jnp.concatenate([batch["patch_embeds"].astype(dtype), txt], axis=1)
    else:
        h = layers.embed(p["embed"], batch["tokens"])
    B, S = h.shape[0], h.shape[1]
    if "cache_len" in batch:                  # decode: absolute positions
        positions = jnp.broadcast_to(batch["cache_len"], (B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    return h, positions


def _project_out(p, cfg, h):
    if cfg.modality == "audio_tokens":
        vpad = layers.pad_vocab(cfg.vocab_size)
        logits = layers.dense(p["heads"], h)
        B, S = h.shape[0], h.shape[1]
        logits = logits.reshape(B, S, cfg.num_codebooks, vpad)
        return logits[..., :cfg.vocab_size]
    if cfg.tie_embeddings:
        return layers.unembed(p["embed"], h, cfg.vocab_size)
    return layers.dense(p["unembed"], h)[..., :cfg.vocab_size]


def forward(params, cfg, batch, *, mode: str = "train", cache=None,
            logits_positions: str = "all"):
    """Returns (logits, new_cache, aux).  logits_positions='last' projects
    only the final position — at 32k prefill the full (B, S, vocab) logits
    tensor is ~67 GB/device (measured), and XLA does not reliably push the
    downstream slice through the projection."""
    h, positions = _embed_inputs(params, cfg, batch)
    cache_len = batch.get("cache_len")
    h, new_cache, aux = transformer.stack_apply(
        params["stack"], cfg, h, positions, mode=mode, cache=cache,
        cache_len=cache_len)
    h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if logits_positions == "last":
        h = h[:, -1:]
    logits = _project_out(params, cfg, h)
    return logits, new_cache, aux


def make_cache(cfg, batch_size: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    return transformer.stack_make_cache(cfg, batch_size, max_len, dtype)


_CACHE_TIME_AXIS = {"k": -3, "v": -3, "c_kv": -2, "k_rope": -2}


def pad_cache(cache, extra: int):
    """Grow every attention cache's time axis by `extra` zero slots (e.g. after
    prefill, to make room for generated tokens).  SSM states are untouched."""
    def pad_leaf(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else None
        ax = _CACHE_TIME_AXIS.get(name)
        if ax is None:
            return leaf
        widths = [(0, 0)] * leaf.ndim
        widths[ax % leaf.ndim] = (0, extra)
        return jnp.pad(leaf, widths)
    return jax.tree_util.tree_map_with_path(pad_leaf, cache)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, ignore: int = -1):
    """Mean CE over non-ignored labels, fp32.  labels broadcast to logits[:-1]."""
    mask = (labels != ignore).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


CE_CHUNK = 512


def chunked_xent(params, cfg, h, labels, *, chunk: int = CE_CHUNK):
    """Sequence-chunked projection + CE: the (B, S, vocab) fp32 logits tensor
    is never materialised — each (B, chunk, vocab) tile is projected, reduced
    and (via jax.checkpoint) recomputed in the backward pass.  At 128k vocab
    and 1M tokens the unchunked logits alone are ~0.5 TB fp32 (measured;
    EXPERIMENTS.md §Perf) — this is the fused-CE analogue."""
    B, S = h.shape[0], h.shape[1]
    chunk = min(chunk, S)
    nch = -(-S // chunk)
    pad = nch * chunk - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad)) + ((0, 0),) * (h.ndim - 2))
        labels = jnp.pad(labels, ((0, 0), (0, pad))
                         + ((0, 0),) * (labels.ndim - 2),
                         constant_values=-1)

    hb = jnp.moveaxis(h.reshape(B, nch, chunk, -1), 1, 0)
    lb = jnp.moveaxis(labels.reshape((B, nch, chunk) + labels.shape[2:]), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        nll_sum, cnt = carry
        h_c, lab_c = inp
        logits = _project_out(params, cfg, h_c)
        mask = (lab_c != -1).astype(jnp.float32)
        safe = jnp.maximum(lab_c, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return (nll_sum - (ll * mask).sum(), cnt + mask.sum()), None

    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hb, lb))
    return nll / jnp.maximum(cnt, 1.0)


def loss_and_metrics(params, cfg, batch, *, mode: str = "train"):
    labels = batch["labels"]
    h, positions = _embed_inputs(params, cfg, batch)
    cache_len = batch.get("cache_len")
    h, _, aux = transformer.stack_apply(
        params["stack"], cfg, h, positions, mode=mode, cache=None,
        cache_len=cache_len)
    h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    ce = chunked_xent(params, cfg, h, labels,
                      chunk=cfg.ce_chunk or CE_CHUNK)
    loss = ce
    metrics = {"ce": ce}
    if cfg.is_moe:
        loss = loss + cfg.moe.router_aux_weight * aux["lb_loss"] \
                    + cfg.moe.router_z_weight * aux["z_loss"]
        metrics.update(lb_loss=aux["lb_loss"], z_loss=aux["z_loss"])
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg, shape_cfg):
    """Batch spec for (cfg, shape).  Decode shapes describe ONE new token; the
    KV cache spec comes from cache_specs()."""
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if shape_cfg.mode in ("train", "prefill"):
        if cfg.modality == "audio_tokens":
            return {"input_embeds": sds((B, S, cfg.d_model), dt),
                    "labels": sds((B, S, cfg.num_codebooks), i32)}
        if cfg.modality == "vlm":
            P = cfg.num_prefix_tokens
            return {"patch_embeds": sds((B, P, cfg.d_model), dt),
                    "tokens": sds((B, S - P), i32),
                    "labels": sds((B, S), i32)}
        return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
    # decode: one token against a cache of S entries
    if cfg.modality == "audio_tokens":
        return {"tokens_mc": sds((B, 1, cfg.num_codebooks), i32),
                "cache_len": sds((), i32)}
    return {"tokens": sds((B, 1), i32), "cache_len": sds((), i32)}


def cache_specs(cfg, shape_cfg):
    """ShapeDtypeStructs for the decode cache (shape only, no allocation)."""
    cache = jax.eval_shape(
        lambda: make_cache(cfg, shape_cfg.global_batch, shape_cfg.seq_len))
    return cache


def dummy_batch(cfg, shape_cfg, key=None):
    """Materialised batch for smoke tests / examples (small configs only)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape_cfg)
    out = {}
    for name, spec in specs.items():
        key, sub = jax.random.split(key)
        if spec.dtype == jnp.int32:
            if name == "cache_len":
                out[name] = jnp.asarray(shape_cfg.seq_len - 1, jnp.int32)
            else:
                out[name] = jax.random.randint(sub, spec.shape, 0,
                                               cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(sub, spec.shape, jnp.float32) \
                .astype(spec.dtype)
    return out
