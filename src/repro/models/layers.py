"""Primitive layers: dense, norms, embeddings, rotary, MLPs.

Pure-functional style: every module is an (init, apply) pair operating on
pytrees of jnp arrays.  Params are stored in the config's dtype (bf16 for
production configs); numerically sensitive reductions run in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def cdtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.bfloat16, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def pad_vocab(vocab_size: int, multiple: int = 128) -> int:
    """Pad vocab so the embedding/vocab dim shards cleanly on a 16-way axis."""
    return int(-(-vocab_size // multiple) * multiple)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    w = (jax.random.normal(key, (pad_vocab(vocab), d), jnp.float32)
         * 0.02).astype(dtype)
    return {"w": w}


def embed(p, tokens):
    return jnp.take(p["w"], tokens, axis=0)


def unembed(p, x, vocab: int):
    """Project to (padded) vocab logits; callers mask/crop to true vocab."""
    logits = x @ p["w"].T
    return logits[..., :vocab]


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv_freq = jnp.asarray(rope_frequencies(d, theta))
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (...,S,D/2)
    angles = angles[..., None, :]                                    # (...,S,1,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp_init(key, d_model: int, d_ff: int, *, act: str = "silu",
             dtype=jnp.bfloat16):
    """act == 'silu' -> gated SwiGLU (3 mats); else plain 2-layer MLP."""
    ks = jax.random.split(key, 3)
    if act == "silu":
        return {"wi": dense_init(ks[0], d_model, d_ff, dtype=dtype),
                "wg": dense_init(ks[1], d_model, d_ff, dtype=dtype),
                "wo": dense_init(ks[2], d_ff, d_model, dtype=dtype)}
    return {"wi": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "wo": dense_init(ks[2], d_ff, d_model, dtype=dtype)}


def mlp(p, x, *, act: str = "silu"):
    f = _act(act)
    if "wg" in p:
        h = f(dense(p["wi"], x)) * dense(p["wg"], x)
    else:
        h = f(dense(p["wi"], x))
    return dense(p["wo"], h)


def mlp_param_count(d_model: int, d_ff: int, act: str = "silu") -> int:
    return (3 if act == "silu" else 2) * d_model * d_ff
