"""State-space and recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM / sLSTM).

Mamba2 uses the chunked SSD formulation: intra-chunk terms are dense einsums
(MXU-friendly, fully vectorised over chunks -> visible to cost_analysis), with
a tiny lax.scan only for the inter-chunk state recurrence.  The Pallas kernel
(repro.kernels.ssm_scan) implements the same chunked contract for TPU.

xLSTM blocks use exact sequential recurrences (lax.scan over time) with
exponential gating + max-stabiliser state, faithful to arXiv:2405.04517.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (mamba's local conv)
# ---------------------------------------------------------------------------

def conv1d_init(key, channels: int, width: int, dtype):
    w = (jax.random.normal(key, (width, channels), jnp.float32)
         / np.sqrt(width)).astype(dtype)
    return {"w": w, "b": jnp.zeros((channels,), dtype)}


def conv1d_causal(p, x):
    """x: (B, S, C) -> (B, S, C), causal depthwise."""
    width = p["w"].shape[0]
    x = x.astype(p["w"].dtype)      # lax.conv requires matching dtypes
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, p["w"][:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + p["b"]


def conv1d_step(p, x_t, conv_state):
    """Single decode step.  x_t: (B, C); conv_state: (B, width-1, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,w,C)
    out = jnp.einsum("bwc,wc->bc", window, p["w"]) + p["b"]
    return out, window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def mamba2_init(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    H = s.num_heads(d)
    N = s.state_dim
    ks = jax.random.split(key, 6)
    # in_proj -> [z, x, B, C, dt]
    proj_out = 2 * d_in + 2 * N + H
    return {
        "in_proj": layers.dense_init(ks[0], d, proj_out, dtype=dtype),
        "conv": conv1d_init(ks[1], d_in + 2 * N, s.conv_width, dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),   # softplus(-2) ~ 0.127
        "norm": layers.rmsnorm_init(d_in, dtype),
        "out_proj": layers.dense_init(ks[2], d_in, d, dtype=dtype),
    }


def mamba2_param_count(cfg) -> int:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    H = s.num_heads(d)
    N = s.state_dim
    n = d * (2 * d_in + 2 * N + H)                      # in_proj
    n += s.conv_width * (d_in + 2 * N) + (d_in + 2 * N)  # conv
    n += 3 * H + d_in                                   # A_log, D, dt_bias, norm
    n += d_in * d                                       # out_proj
    return n


def _ssd_chunked(xh, dt, A, Bm, Cm, D, chunk: int, initial_state=None):
    """Chunked selective-state-space duality scan.

    xh: (B,S,H,P) inputs per head; dt: (B,S,H) post-softplus step sizes;
    A: (H,) negative decay rates; Bm, Cm: (B,S,N) input/output mixers
    (ngroups=1, shared over heads); D: (H,) skip.
    Returns (y: (B,S,H,P), final_state: (B,H,N,P)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S, f"seq {S} not divisible by chunk {chunk}"

    xc = xh.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)

    dA = dtc * A[None, None, None, :]                    # (B,nc,cs,H), <= 0
    cum = jnp.cumsum(dA, axis=2)                         # within-chunk cumsum

    # --- intra-chunk (diagonal) term
    # decay(i<-j) = exp(cum_i - cum_j), applied causally
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,i,j,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)           # (B,nc,i,j)
    att = cb[..., None] * decay * dtc[:, :, None, :, :]  # weight dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xc)

    # --- chunk-final states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (B,nc,cs,H)
    chunk_states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp",
                              Bc, decay_to_end * dtc, xc)  # (B,nc,H,N,P)

    # --- inter-chunk recurrence (tiny scan over nc)
    gamma = jnp.exp(cum[:, :, -1, :])                    # (B,nc,H) total decay

    def step(state, inp):
        g, cs_ = inp                                     # (B,H), (B,H,N,P)
        new = state * g[..., None, None] + cs_
        return new, state                                # emit state *entering* chunk

    init = (jnp.zeros((Bsz, H, N, P), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))
    final_state, entering = jax.lax.scan(
        step, init, (jnp.moveaxis(gamma, 1, 0), jnp.moveaxis(chunk_states, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)              # (B,nc,H,N,P)

    # --- inter-chunk output term
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         Cc, jnp.exp(cum), entering)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    y = y + D[None, None, :, None] * xh.astype(jnp.float32)
    return y, final_state


def mamba2_make_state(cfg, batch: int, dtype):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    H = s.num_heads(cfg.d_model)
    return {
        "ssm": jnp.zeros((batch, H, s.state_dim, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, d_in + 2 * s.state_dim),
                          dtype),
    }


def mamba2_apply(p, cfg, x, *, mode: str, state=None):
    """x: (B,S,d).  Returns (y, new_state)."""
    s = cfg.ssm
    Bsz, S, d = x.shape
    d_in = s.d_inner(d)
    H = s.num_heads(d)
    N = s.state_dim
    P = s.head_dim

    zxbcdt = layers.dense(p["in_proj"], x)
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * N], axis=-1)
    A = -jnp.exp(p["A_log"])

    if mode == "decode":
        assert S == 1 and state is not None
        xbc_t, conv_state = conv1d_step(p["conv"], xbc[:, 0], state["conv"])
        xbc_t = jax.nn.silu(xbc_t)
        xh = xbc_t[:, :d_in].reshape(Bsz, H, P)
        Bm = xbc_t[:, d_in:d_in + N]
        Cm = xbc_t[:, d_in + N:]
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
        dA = jnp.exp(dt * A)                             # (B,H)
        # state update: S <- S * exp(dt A) + dt * B (x) outer
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt, Bm.astype(jnp.float32),
                         xh.astype(jnp.float32))
        ssm_state = state["ssm"] * dA[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), ssm_state)
        y = y + p["D"][None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(Bsz, 1, d_in)
        new_state = {"ssm": ssm_state, "conv": conv_state}
    else:
        xbc = jax.nn.silu(conv1d_causal(p["conv"], xbc))
        xh = xbc[..., :d_in].reshape(Bsz, S, H, P)
        Bm = xbc[..., d_in:d_in + N]
        Cm = xbc[..., d_in + N:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        y, fin = _ssd_chunked(xh, dt, A, Bm, Cm, p["D"], s.chunk_size)
        y = y.reshape(Bsz, S, d_in)
        new_state = None
        if mode == "prefill":
            conv_tail = jnp.pad(
                xbc, ((0, 0), (max(0, s.conv_width - 1 - S), 0), (0, 0))
            )[:, -(s.conv_width - 1):]
            # NOTE: conv state must hold PRE-activation xbc; recompute cheaply.
            raw = layers.dense(p["in_proj"], x)[..., d_in:2 * d_in + 2 * N]
            raw = jnp.pad(raw, ((0, 0), (max(0, s.conv_width - 1 - S), 0),
                                (0, 0)))[:, -(s.conv_width - 1):]
            new_state = {"ssm": fin, "conv": raw}
    y = layers.rmsnorm(p["norm"], y.astype(x.dtype) * jax.nn.silu(z),
                       cfg.norm_eps)
    return layers.dense(p["out_proj"], y), new_state


def _scan_chunked_remat(cell, init, seq, S: int, chunk: int):
    """Time scan with chunk-level rematerialisation.

    A plain lax.scan over S steps saves every per-step carry for the
    backward pass — for mLSTM the carry holds the (B,H,dh,dh) matrix memory,
    i.e. 4096 x 600 MB at 4k context (measured 179 GB/device on xlstm-125m
    train_4k).  Scanning checkpointed CHUNKS saves carries only at chunk
    boundaries and recomputes inside: S/chunk boundary saves + one in-chunk
    recompute, ~chunk x less carry residency.

    cell: (carry, step_inputs) -> (carry, y); seq: tuple of time-major
    (S, ...) arrays.  Falls back to the plain scan when chunk doesn't
    divide S (smoke shapes)."""
    chunk = min(chunk, S)
    if S % chunk or S == chunk:
        return jax.lax.scan(cell, init, seq)
    nch = S // chunk
    seq_c = jax.tree.map(
        lambda t: t.reshape((nch, chunk) + t.shape[1:]), seq)

    @jax.checkpoint
    def chunk_body(carry, chunk_seq):
        return jax.lax.scan(cell, carry, chunk_seq)

    carry, ys = jax.lax.scan(chunk_body, init, seq_c)
    ys = jax.tree.map(lambda t: t.reshape((S,) + t.shape[2:]), ys)
    return carry, ys


# ---------------------------------------------------------------------------
# xLSTM: mLSTM block (matrix memory)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    d_in = cfg.ssm.expand * d                            # pf = 2 up-projection
    H = cfg.num_heads
    dh = d_in // H
    ks = jax.random.split(key, 8)
    return {
        "up": layers.dense_init(ks[0], d, 2 * d_in, dtype=dtype),  # [x_m, z]
        "conv": conv1d_init(ks[1], d_in, cfg.ssm.conv_width, dtype),
        "wq": layers.dense_init(ks[2], d_in, d_in, dtype=dtype),
        "wk": layers.dense_init(ks[3], d_in, d_in, dtype=dtype),
        "wv": layers.dense_init(ks[4], d_in, d_in, dtype=dtype),
        "w_if": layers.dense_init(ks[5], d_in, 2 * H, dtype=dtype),  # i,f gates
        "norm": layers.rmsnorm_init(d_in, dtype),
        "down": layers.dense_init(ks[6], d_in, d, dtype=dtype),
    }


def mlstm_param_count(cfg) -> int:
    d = cfg.d_model
    d_in = cfg.ssm.expand * d
    H = cfg.num_heads
    n = d * 2 * d_in                                     # up
    n += cfg.ssm.conv_width * d_in + d_in                # conv
    n += 3 * d_in * d_in                                 # q,k,v
    n += d_in * 2 * H                                    # gates
    n += d_in + d_in * d                                 # norm + down
    return n


def mlstm_make_state(cfg, batch: int, dtype):
    d_in = cfg.ssm.expand * cfg.d_model
    H = cfg.num_heads
    dh = d_in // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, d_in), dtype),
    }


def _mlstm_cell(carry, qkvif):
    """One step of the stabilised mLSTM recurrence.  All fp32."""
    C, n, m = carry
    q, k, v, i_raw, f_raw = qkvif                        # (B,H,dh) x3, (B,H) x2
    log_f = -jax.nn.softplus(-f_raw)                     # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    C_new = f_g[..., None, None] * C + i_g[..., None, None] * (
        k[..., :, None] * v[..., None, :])               # (B,H,dh_k,dh_v)
    n_new = f_g[..., None] * n + i_g[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)), 1.0)
    h = num / den[..., None]
    return (C_new, n_new, m_new), h


def mlstm_apply(p, cfg, x, *, mode: str, state=None):
    Bsz, S, d = x.shape
    d_in = cfg.ssm.expand * d
    H = cfg.num_heads
    dh = d_in // H
    up = layers.dense(p["up"], x)
    xm, z = jnp.split(up, 2, axis=-1)

    if mode == "decode":
        assert S == 1 and state is not None
        xc, conv_state = conv1d_step(p["conv"], xm[:, 0], state["conv"])
        xc = jax.nn.silu(xc)[:, None]
    else:
        xc = jax.nn.silu(conv1d_causal(p["conv"], xm))
        conv_state = None

    def heads(t):
        return t.reshape(Bsz, -1, H, dh).astype(jnp.float32)

    q = heads(layers.dense(p["wq"], xc)) / np.sqrt(dh)
    k = heads(layers.dense(p["wk"], xc)) / np.sqrt(dh)
    v = heads(layers.dense(p["wv"], xm))                  # v from pre-conv branch
    gates = layers.dense(p["w_if"], xc).astype(jnp.float32)
    i_raw, f_raw = jnp.split(gates.reshape(Bsz, -1, 2, H), 2, axis=2)
    i_raw, f_raw = i_raw[:, :, 0], f_raw[:, :, 0]         # (B,S,H)

    if mode == "decode":
        carry = (state["C"], state["n"], state["m"])
        carry, h = _mlstm_cell(carry, (q[:, 0], k[:, 0], v[:, 0],
                                       i_raw[:, 0], f_raw[:, 0]))
        h = h[:, None]                                    # (B,1,H,dh)
        new_state = {"C": carry[0], "n": carry[1], "m": carry[2],
                     "conv": conv_state}
    else:
        def scan_step(carry, t):
            return _mlstm_cell(carry, t)
        init = (jnp.zeros((Bsz, H, dh, dh), jnp.float32),
                jnp.zeros((Bsz, H, dh), jnp.float32),
                jnp.full((Bsz, H), -1e30, jnp.float32))
        seq = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
               jnp.moveaxis(v, 1, 0), jnp.moveaxis(i_raw, 1, 0),
               jnp.moveaxis(f_raw, 1, 0))
        carry, hs = _scan_chunked_remat(scan_step, init, seq, q.shape[1],
                                        cfg.ssm.chunk_size)
        h = jnp.moveaxis(hs, 0, 1)                        # (B,S,H,dh)
        new_state = None
        if mode == "prefill":
            raw_tail = jnp.pad(xm, ((0, 0), (max(0, cfg.ssm.conv_width - 1 - S),
                                             0), (0, 0)))
            new_state = {"C": carry[0], "n": carry[1], "m": carry[2],
                         "conv": raw_tail[:, -(cfg.ssm.conv_width - 1):]}

    h = h.reshape(Bsz, -1, d_in).astype(x.dtype)
    h = layers.rmsnorm(p["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    return layers.dense(p["down"], h), new_state


# ---------------------------------------------------------------------------
# xLSTM: sLSTM block (scalar memory, recurrent gates)
# ---------------------------------------------------------------------------

def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    ff = int(np.ceil(4 / 3 * d / 64) * 64)               # pf=4/3 gated FFN
    return {
        "wx": layers.dense_init(ks[0], d, 4 * d, dtype=dtype),   # i,f,z,o from x
        "r": (jax.random.normal(ks[1], (H, dh, 4 * dh), jnp.float32)
              / np.sqrt(dh)).astype(dtype),              # block-diag recurrence
        "norm": layers.rmsnorm_init(d, dtype),
        "ffn": layers.mlp_init(ks[2], d, ff, act="silu", dtype=dtype),
        "ffn_norm": layers.rmsnorm_init(d, dtype),
    }


def slstm_param_count(cfg) -> int:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ff = int(np.ceil(4 / 3 * d / 64) * 64)
    return d * 4 * d + H * dh * 4 * dh + 2 * d + 3 * d * ff


def slstm_make_state(cfg, batch: int, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    return {
        "c": jnp.zeros((batch, H, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "h": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H, dh), -1e30, jnp.float32),
    }


def _slstm_cell(p_r, carry, x_gates, H, dh):
    """x_gates: (B, 4d) pre-activations from the input path."""
    c, n, h, m = carry
    rec = jnp.einsum("bhd,hde->bhe", h, p_r.astype(jnp.float32))  # (B,H,4dh)
    g = x_gates.reshape(-1, H, 4, dh).astype(jnp.float32) \
        + rec.reshape(-1, H, 4, dh)
    i_raw, f_raw, z_raw, o_raw = (g[:, :, 0], g[:, :, 1], g[:, :, 2], g[:, :, 3])
    log_f = -jax.nn.softplus(-f_raw)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z_raw)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_apply(p, cfg, x, *, mode: str, state=None):
    Bsz, S, d = x.shape
    H = cfg.num_heads
    dh = d // H
    xg = layers.dense(p["wx"], x)                        # (B,S,4d)

    if mode == "decode":
        assert S == 1 and state is not None
        carry = (state["c"], state["n"], state["h"], state["m"])
        carry = _slstm_cell(p["r"], carry, xg[:, 0], H, dh)
        hs = carry[2][:, None]                           # (B,1,H,dh)
        new_state = {"c": carry[0], "n": carry[1], "h": carry[2],
                     "m": carry[3]}
    else:
        def step(carry, g_t):
            new = _slstm_cell(p["r"], carry, g_t, H, dh)
            return new, new[2]
        init = (jnp.zeros((Bsz, H, dh), jnp.float32),
                jnp.zeros((Bsz, H, dh), jnp.float32),
                jnp.zeros((Bsz, H, dh), jnp.float32),
                jnp.full((Bsz, H, dh), -1e30, jnp.float32))
        carry, hs = _scan_chunked_remat(step, init, jnp.moveaxis(xg, 1, 0),
                                        S, cfg.ssm.chunk_size)
        hs = jnp.moveaxis(hs, 0, 1)                      # (B,S,H,dh)
        new_state = None
        if mode == "prefill":
            new_state = {"c": carry[0], "n": carry[1], "h": carry[2],
                         "m": carry[3]}

    h = hs.reshape(Bsz, -1, d).astype(x.dtype)
    h = layers.rmsnorm(p["norm"], h, cfg.norm_eps)
    out = h + layers.mlp(
        p["ffn"], layers.rmsnorm(p["ffn_norm"], h, cfg.norm_eps), act="silu")
    return out, new_state
