"""Block composition and the generic decoder stack.

A model is a repeating `block_pattern` (period) of typed blocks scanned over
`num_layers // period` periods, with optional pre-layers outside the scan
(e.g. DeepSeek-V2's dense layer 0) and optional parameter-SHARED blocks
(Zamba2's global attention).  Scanning keeps the HLO small enough that the
80 production dry-run compiles stay tractable; `cfg.scan_layers=False`
unrolls for cost-analysis cross-checks.

Block kinds:
  attn              pre-norm attention + (MLP | MoE [+ dense residual]) block
  mamba             Mamba2 (SSD) block
  mamba+shared_attn Mamba2 block followed by the shared global attention
  mlstm / slstm     xLSTM blocks
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.models import attention, layers, moe, ssm


def block_pattern(cfg):
    if cfg.block_pattern:
        return tuple(cfg.block_pattern)
    return ("attn",)


def num_periods(cfg):
    pat = block_pattern(cfg)
    n_scanned = cfg.num_layers - cfg.moe.first_dense_layers
    assert n_scanned % len(pat) == 0, (
        f"{cfg.name}: {n_scanned} layers not divisible by period {len(pat)}")
    return n_scanned // len(pat)


# ---------------------------------------------------------------------------
# Single blocks
# ---------------------------------------------------------------------------

def _attn_block_init(key, cfg, dtype, *, use_moe: bool):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": layers.rmsnorm_init(cfg.d_model, dtype),
        "attn": attention.attn_init(ks[0], cfg, dtype),
        "ffn_norm": layers.rmsnorm_init(cfg.d_model, dtype),
    }
    if use_moe:
        p["moe"] = moe.moe_init(ks[1], cfg, dtype)
        if cfg.moe.dense_residual:
            p["mlp"] = layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff,
                                       act=cfg.act, dtype=dtype)
    else:
        p["mlp"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                   act=cfg.act, dtype=dtype)
    return p


def _attn_block_apply(p, cfg, x, positions, *, mode, cache, cache_len,
                      use_moe: bool):
    h, new_cache = attention.attn_apply(
        p["attn"], cfg, layers.rmsnorm(p["attn_norm"], x, cfg.norm_eps),
        positions, mode=mode, cache=cache, cache_len=cache_len)
    x = x + h
    x = _checkpoint_name(x, "block_out")  # post-AR (see
    hn = layers.rmsnorm(p["ffn_norm"], x, cfg.norm_eps)    # stack_apply)
    aux = _zero_aux(cfg)
    if use_moe:
        if mode == "decode":
            moe_fn = moe.moe_decode_apply
        elif cfg.moe_impl == "ep":
            moe_fn = moe.moe_apply_ep
        else:
            moe_fn = moe.moe_apply
        mo, aux = moe_fn(p["moe"], cfg, hn)
        if cfg.moe.dense_residual:
            mo = mo + layers.mlp(p["mlp"], hn, act=cfg.act)
        x = x + mo
    else:
        x = x + layers.mlp(p["mlp"], hn, act=cfg.act)
    return x, new_cache, aux


def _zero_aux(cfg):
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32),
            "expert_load": jnp.zeros((max(cfg.moe.num_experts, 1),),
                                     jnp.float32)}


# --- shared global attention (Zamba2) --------------------------------------

def _shared_attn_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    return {
        "in_proj": layers.dense_init(ks[0], 2 * cfg.d_model, cfg.d_model,
                                     dtype=dtype),
        "norm": layers.rmsnorm_init(cfg.d_model, dtype),
        "attn": attention.attn_init(ks[1], cfg, dtype),
        "ffn_norm": layers.rmsnorm_init(cfg.d_model, dtype),
        "ffn": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, act=cfg.act,
                               dtype=dtype),
    }


def _shared_attn_apply(shared, adapter, cfg, x, emb0, positions, *, mode,
                       cache, cache_len):
    g = layers.dense(shared["in_proj"], jnp.concatenate([x, emb0], axis=-1))
    h, new_cache = attention.attn_apply(
        shared["attn"], cfg, layers.rmsnorm(shared["norm"], g, cfg.norm_eps),
        positions, mode=mode, cache=cache, cache_len=cache_len)
    g = g + h
    g = g + layers.mlp(shared["ffn"],
                       layers.rmsnorm(shared["ffn_norm"], g, cfg.norm_eps),
                       act=cfg.act)
    # per-invocation (unshared) output adapter — Zamba2's LoRA analogue
    return x + layers.dense(adapter, g)


# ---------------------------------------------------------------------------
# Block dispatch
# ---------------------------------------------------------------------------

def block_init(key, cfg, kind: str, dtype, *, use_moe: bool = False):
    if kind == "attn":
        return _attn_block_init(key, cfg, dtype, use_moe=use_moe)
    if kind == "mamba":
        return {"norm": layers.rmsnorm_init(cfg.d_model, dtype),
                "mamba": ssm.mamba2_init(key, cfg, dtype)}
    if kind == "mamba+shared_attn":
        ks = jax.random.split(key, 2)
        return {"norm": layers.rmsnorm_init(cfg.d_model, dtype),
                "mamba": ssm.mamba2_init(ks[0], cfg, dtype),
                "adapter": layers.dense_init(ks[1], cfg.d_model, cfg.d_model,
                                             dtype=dtype, scale=1e-4)}
    if kind == "mlstm":
        return {"norm": layers.rmsnorm_init(cfg.d_model, dtype),
                "mlstm": ssm.mlstm_init(key, cfg, dtype)}
    if kind == "slstm":
        return {"norm": layers.rmsnorm_init(cfg.d_model, dtype),
                "slstm": ssm.slstm_init(key, cfg, dtype)}
    raise ValueError(kind)


def block_make_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    if kind == "attn":
        return attention.attn_make_cache(cfg, batch, max_len, dtype)
    if kind == "mamba":
        return ssm.mamba2_make_state(cfg, batch, dtype)
    if kind == "mamba+shared_attn":
        return {"mamba": ssm.mamba2_make_state(cfg, batch, dtype),
                "attn": attention.attn_make_cache(cfg, batch, max_len, dtype)}
    if kind == "mlstm":
        return ssm.mlstm_make_state(cfg, batch, dtype)
    if kind == "slstm":
        return ssm.slstm_make_state(cfg, batch, dtype)
    raise ValueError(kind)


def block_apply(p, cfg, kind: str, x, positions, *, mode, cache=None,
                cache_len=None, shared=None, emb0=None, use_moe=False):
    """Returns (x, new_cache, aux)."""
    if kind == "attn":
        return _attn_block_apply(p, cfg, x, positions, mode=mode, cache=cache,
                                 cache_len=cache_len, use_moe=use_moe)
    aux = _zero_aux(cfg)
    if kind == "mamba":
        h, st = ssm.mamba2_apply(p["mamba"], cfg,
                                 layers.rmsnorm(p["norm"], x, cfg.norm_eps),
                                 mode=mode, state=cache)
        return x + h, st, aux
    if kind == "mamba+shared_attn":
        mcache = cache["mamba"] if cache is not None else None
        acache = cache["attn"] if cache is not None else None
        h, mst = ssm.mamba2_apply(p["mamba"], cfg,
                                  layers.rmsnorm(p["norm"], x, cfg.norm_eps),
                                  mode=mode, state=mcache)
        x = x + h
        # shared attention needs a dedicated sub-call to capture its cache
        g = layers.dense(shared["in_proj"], jnp.concatenate([x, emb0], -1))
        hh, ast = attention.attn_apply(
            shared["attn"], cfg,
            layers.rmsnorm(shared["norm"], g, cfg.norm_eps),
            positions, mode=mode, cache=acache, cache_len=cache_len)
        g = g + hh
        g = g + layers.mlp(shared["ffn"],
                           layers.rmsnorm(shared["ffn_norm"], g, cfg.norm_eps),
                           act=cfg.act)
        x = x + layers.dense(p["adapter"], g)
        new_cache = None if mode == "train" else {"mamba": mst, "attn": ast}
        return x, new_cache, aux
    if kind == "mlstm":
        h, st = ssm.mlstm_apply(p["mlstm"], cfg,
                                layers.rmsnorm(p["norm"], x, cfg.norm_eps),
                                mode=mode, state=cache)
        return x + h, st, aux
    if kind == "slstm":
        h, st = ssm.slstm_apply(p["slstm"], cfg,
                                layers.rmsnorm(p["norm"], x, cfg.norm_eps),
                                mode=mode, state=cache)
        return x + h, st, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# The stack
# ---------------------------------------------------------------------------

def stack_init(key, cfg, dtype):
    pat = block_pattern(cfg)
    nper = num_periods(cfg)
    ks = jax.random.split(key, 4)
    p = {}
    # pre-layers outside the scan (deepseek-v2 dense layer 0)
    if cfg.moe.first_dense_layers:
        pre_keys = jax.random.split(ks[0], cfg.moe.first_dense_layers)
        p["pre"] = [
            _attn_block_init(k, cfg, dtype, use_moe=False) for k in pre_keys]
    # scanned periods: one stacked param tree per position in the period
    pos_params = []
    for i, kind in enumerate(pat):
        keys = jax.random.split(jax.random.fold_in(ks[1], i), nper)
        use_moe = cfg.is_moe and kind == "attn"
        stacked = jax.vmap(
            lambda k: block_init(k, cfg, kind, dtype, use_moe=use_moe))(keys)
        pos_params.append(stacked)
    p["pattern"] = pos_params
    if any("shared_attn" in k for k in pat):
        p["shared"] = _shared_attn_init(ks[2], cfg, dtype)
    return p


def stack_param_count(cfg) -> int:
    pat = block_pattern(cfg)
    nper = num_periods(cfg)
    n = 0
    per_kind = {
        "attn": lambda: (attention.attn_param_count(cfg) + 2 * cfg.d_model
                         + (moe.moe_param_count(cfg)
                            + (layers.mlp_param_count(cfg.d_model, cfg.d_ff,
                                                      cfg.act)
                               if cfg.moe.dense_residual else 0)
                            if cfg.is_moe
                            else layers.mlp_param_count(cfg.d_model, cfg.d_ff,
                                                        cfg.act))),
        "mamba": lambda: ssm.mamba2_param_count(cfg) + cfg.d_model,
        "mamba+shared_attn": lambda: (ssm.mamba2_param_count(cfg) + cfg.d_model
                                      + cfg.d_model * cfg.d_model),
        "mlstm": lambda: ssm.mlstm_param_count(cfg) + cfg.d_model,
        "slstm": lambda: ssm.slstm_param_count(cfg) + cfg.d_model,
    }
    for kind in pat:
        n += nper * per_kind[kind]()
    if cfg.moe.first_dense_layers:
        n += cfg.moe.first_dense_layers * (
            attention.attn_param_count(cfg) + 2 * cfg.d_model
            + layers.mlp_param_count(cfg.d_model, cfg.d_ff, cfg.act))
    if any("shared_attn" in k for k in pat):
        n += (2 * cfg.d_model * cfg.d_model + 2 * cfg.d_model
              + attention.attn_param_count(cfg)
              + layers.mlp_param_count(cfg.d_model, cfg.d_ff, cfg.act))
    return n


def stack_make_cache(cfg, batch: int, max_len: int, dtype):
    pat = block_pattern(cfg)
    nper = num_periods(cfg)
    cache = {}
    if cfg.moe.first_dense_layers:
        cache["pre"] = [block_make_cache(cfg, "attn", batch, max_len, dtype)
                        for _ in range(cfg.moe.first_dense_layers)]
    cache["pattern"] = [
        jax.tree.map(lambda x: jnp.broadcast_to(x, (nper,) + x.shape).copy(),
                     block_make_cache(cfg, kind, batch, max_len, dtype))
        for kind in pat]
    return cache


def stack_apply(p, cfg, x, positions, *, mode, cache=None, cache_len=None):
    """x: (B,S,d) -> (x, new_cache, aux_sum)."""
    pat = block_pattern(cfg)
    nper = num_periods(cfg)
    shared = p.get("shared")
    emb0 = x if shared is not None else None
    aux_sum = _zero_aux(cfg)
    new_cache = {"pattern": []} if mode != "train" else None

    if "pre" in p:
        if mode != "train":
            new_cache["pre"] = []
        for i, bp in enumerate(p["pre"]):
            c = cache["pre"][i] if cache is not None else None
            x, nc, aux = block_apply(bp, cfg, "attn", x, positions, mode=mode,
                                     cache=c, cache_len=cache_len,
                                     use_moe=False)
            aux_sum = jax.tree.map(jnp.add, aux_sum, aux)
            if mode != "train":
                new_cache["pre"].append(nc)

    def period_body(carry, scanned):
        xx, aux_acc = carry
        caches_in = scanned["cache"] if mode == "decode" else [None] * len(pat)
        caches_out = []
        for i, kind in enumerate(pat):
            use_moe = cfg.is_moe and kind == "attn"
            xx, nc, aux = block_apply(
                scanned["params"][i], cfg, kind, xx, positions, mode=mode,
                cache=caches_in[i], cache_len=cache_len, shared=shared,
                emb0=emb0, use_moe=use_moe)
            # named so the remat policy can keep the post-all-reduce block
            # output: avoids re-running the TP output all-reduces during
            # backward recompute (EXPERIMENTS.md §Perf iter. 3)
            xx = _checkpoint_name(xx, "block_out")
            aux_acc = jax.tree.map(jnp.add, aux_acc, aux)
            caches_out.append(nc)
        out = {"cache": caches_out} if mode != "train" else {"cache": None}
        return (xx, aux_acc), out

    scanned_in = {"params": p["pattern"]}
    if mode == "decode":
        scanned_in["cache"] = cache["pattern"]

    if cfg.scan_layers:
        body = period_body
        if cfg.remat and mode == "train":
            # NOTE: save_only_these_names("block_out") was measured to cut
            # all-reduce by only 0.9% while adding 9 GB/device (the backward
            # recompute still needs the attention-internal all-reduces) —
            # full remat wins; see EXPERIMENTS.md §Perf iter. 3.
            body = jax.checkpoint(
                period_body,
                policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux_sum), outs = jax.lax.scan(body, (x, aux_sum), scanned_in)
        if mode != "train":
            new_cache["pattern"] = outs["cache"]
    else:
        carry = (x, aux_sum)
        outs = []
        for per in range(nper):
            sl = jax.tree.map(lambda t: t[per], scanned_in)
            carry, out = period_body(carry, sl)
            outs.append(out)
        x, aux_sum = carry
        if mode != "train":
            new_cache["pattern"] = jax.tree.map(
                lambda *ts: jnp.stack(ts), *[o["cache"] for o in outs])
    return x, new_cache, aux_sum
