"""Mixture-of-experts: top-k router with capacity-based scatter dispatch,
shared experts (DeepSeek-V2) and dense parallel residual (Arctic).

Dispatch is scatter/gather-based (token -> (expert, slot) buffers) rather than
one-hot-einsum-based: the (E, C, d) buffers stay small enough to shard the
expert axis over the 'model' mesh axis (expert parallelism), and the scatter
lowers to collectives chosen by the SPMD partitioner.  The §Perf pass replaces
the partitioner's choice with an explicit shard_map all_to_all schedule.

Aux losses: switch-style load-balance loss and router z-loss, returned to the
caller for accumulation across layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


def expert_capacity(num_tokens: int, cfg_moe) -> int:
    """Per-expert buffer slots, from static shapes."""
    k, E = cfg_moe.experts_per_token, cfg_moe.num_experts
    cap = int(np.ceil(num_tokens * k / E * cfg_moe.capacity_factor))
    return max(cap, k)


def moe_init(key, cfg, dtype):
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 6)

    def stack(key, d_in, d_out, n):
        w = (jax.random.normal(key, (n, d_in, d_out), jnp.float32)
             / np.sqrt(d_in)).astype(dtype)
        return w

    p = {
        "router": layers.dense_init(ks[0], d, m.num_experts, dtype=jnp.float32),
        "wi": stack(ks[1], d, f, m.num_experts),
        "wg": stack(ks[2], d, f, m.num_experts),
        "wo": stack(ks[3], f, d, m.num_experts),
    }
    if m.num_shared_experts:
        p["shared"] = layers.mlp_init(ks[4], d, f * m.num_shared_experts,
                                      act="silu", dtype=dtype)
    return p


def moe_param_count(cfg) -> int:
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    n = d * m.num_experts + 3 * m.num_experts * d * f
    if m.num_shared_experts:
        n += 3 * d * f * m.num_shared_experts
    return n


def moe_active_param_count(cfg) -> int:
    """Params touched per token (for 6·N_active·D roofline accounting)."""
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    n = d * m.num_experts + 3 * m.experts_per_token * d * f
    if m.num_shared_experts:
        n += 3 * d * f * m.num_shared_experts
    return n


def moe_apply(p, cfg, x):
    """x: (B, S, d) -> (y, aux) with aux = {'lb_loss', 'z_loss', 'router_probs'}."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.experts_per_token
    C = expert_capacity(T, m)

    xf = x.reshape(T, d)
    logits = layers.dense(p["router"], xf.astype(jnp.float32))      # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                          # (T,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- slot assignment: rank of each (token, slot) inside its expert
    flat_e = top_e.reshape(T * k)                                    # token-major
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)              # (Tk,E)
    ranks = jnp.cumsum(onehot, axis=0) * onehot                      # 1-based
    pos = (ranks.sum(axis=-1) - 1)                                   # (Tk,)
    keep = pos < C
    slot_e = jnp.where(keep, flat_e, 0)
    slot_p = jnp.where(keep, pos, 0)

    tok_id = jnp.repeat(jnp.arange(T), k)
    gathered = jnp.take(xf, tok_id, axis=0)                          # (Tk,d)
    gathered = gathered * keep[:, None].astype(xf.dtype)

    buf = jnp.zeros((E, C, d), xf.dtype).at[slot_e, slot_p].add(gathered)

    # ---- expert FFN (einsum over stacked expert weights; E shardable)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wi"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"])                     # (E,C,d)

    # ---- combine
    slots_out = out[slot_e, slot_p]                                  # (Tk,d)
    w = (top_w.reshape(T * k) * keep).astype(xf.dtype)
    y = jnp.zeros((T, d), xf.dtype).at[tok_id].add(slots_out * w[:, None])

    if m.num_shared_experts:
        y = y + layers.mlp(p["shared"], xf, act="silu")

    # ---- aux losses (fp32)
    me = probs.mean(axis=0)                                          # (E,)
    ce = jax.nn.one_hot(top_e, E, dtype=jnp.float32).sum(axis=(0, 1)) / (T * k)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss,
           "expert_load": jax.lax.stop_gradient(ce)}
    return y.reshape(B, S, d), aux


def moe_apply_ep(p, cfg, x):
    """Expert-parallel MoE via shard_map (the §Perf alternative to the
    GSPMD-partitioned scatter of moe_apply).

    Layout insight: activations are batch-sharded over (pod, data) and
    REPLICATED over 'model', while experts are sharded over 'model' — so no
    dispatch collective is needed at all.  Each device routes its local
    tokens, keeps only the slots destined for its OWN E/16 experts, runs
    them, scatters back into a local (T_loc, d) partial, and a single
    psum over 'model' combines the k expert contributions per token.
    Comm per layer = one (T_loc, d) all-reduce instead of the partitioner's
    gather/scatter storm (measured ~100 GB/layer/device on deepseek-v2;
    EXPERIMENTS.md §Perf iteration 5).

    Falls back to moe_apply when no mesh with a 'model' axis is active.
    """
    from repro.launch.mesh import current_abstract_mesh
    mesh = current_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return moe_apply(p, cfg, x)
    from jax.sharding import PartitionSpec as P
    batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.experts_per_token
    msize = mesh.shape["model"]
    assert E % msize == 0
    e_loc = E // msize

    def local_fn(xf, router, wi, wg, wo):
        # xf (T_loc, d); router (d, E); wi/wg (e_loc, d, f); wo (e_loc, f, d)
        T_loc = xf.shape[0]
        C = expert_capacity(T_loc, m)
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        my_shard = jax.lax.axis_index("model")
        flat_e = top_e.reshape(T_loc * k)
        mine = (flat_e // e_loc) == my_shard
        loc_e = jnp.where(mine, flat_e % e_loc, 0)
        onehot = jax.nn.one_hot(loc_e, e_loc, dtype=jnp.int32) \
            * mine[:, None].astype(jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
        keep = mine & (pos >= 0) & (pos < C)
        slot_e = jnp.where(keep, loc_e, 0)
        slot_p = jnp.where(keep, pos, 0)
        tok_id = jnp.repeat(jnp.arange(T_loc), k)
        gathered = jnp.take(xf, tok_id, axis=0) \
            * keep[:, None].astype(xf.dtype)
        buf = jnp.zeros((e_loc, C, d), xf.dtype).at[slot_e, slot_p] \
            .add(gathered)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wi)) * \
            jnp.einsum("ecd,edf->ecf", buf, wg)
        out = jnp.einsum("ecf,efd->ecd", h, wo)

        slots_out = out[slot_e, slot_p]
        w = (top_w.reshape(T_loc * k) * keep).astype(xf.dtype)
        y = jnp.zeros((T_loc, d), xf.dtype).at[tok_id] \
            .add(slots_out * w[:, None])
        y = jax.lax.psum(y, "model")                  # combine k experts

        me = probs.mean(axis=0)
        ce = jax.nn.one_hot(top_e, E, dtype=jnp.float32) \
            .sum(axis=(0, 1)) / (T_loc * k)
        lb = E * jnp.sum(me * ce)
        z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        # aux stats differ per batch shard: emit them with a sharded leading
        # dim and average OUTSIDE the shard_map (pmean-inside trips a jax
        # psum_invariant issue on meshes with extra axes, e.g. INL's client)
        return y, lb[None], z[None], jax.lax.stop_gradient(ce)[None]

    xf = x.reshape(B * S, d)
    spec_tok = P(batch_axes or None, None)
    aux_spec = P(batch_axes or None)
    y, lb, z, ce = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(spec_tok, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(spec_tok, aux_spec, aux_spec,
                   P(batch_axes or None, None)),
    )(xf, p["router"]["w"], p["wi"], p["wg"], p["wo"])
    y = y.reshape(B, S, d)
    if m.num_shared_experts:
        y = y + layers.mlp(p["shared"], x.reshape(B, S, d), act="silu")
    aux = {"lb_loss": lb.mean(), "z_loss": z.mean(),
           "expert_load": ce.mean(axis=0)}
    return y, aux


def moe_decode_apply(p, cfg, x):
    """Decode-friendly MoE: with one token per sequence, skip buffers and use
    a dense gather of the k selected experts per token (k small)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    logits = layers.dense(p["router"], xf.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.experts_per_token)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    wi = jnp.take(p["wi"], top_e, axis=0)                            # (T,k,d,f)
    wg = jnp.take(p["wg"], top_e, axis=0)
    wo = jnp.take(p["wo"], top_e, axis=0)
    h = jax.nn.silu(jnp.einsum("td,tkdf->tkf", xf, wi)) * \
        jnp.einsum("td,tkdf->tkf", xf, wg)
    out = jnp.einsum("tkf,tkfd->tkd", h, wo)
    y = jnp.einsum("tkd,tk->td", out, top_w.astype(out.dtype))
    if m.num_shared_experts:
        y = y + layers.mlp(p["shared"], xf, act="silu")
    aux = {"lb_loss": jnp.zeros((), jnp.float32),
           "z_loss": jnp.zeros((), jnp.float32),
           "expert_load": jnp.zeros((m.num_experts,), jnp.float32)}
    return y.reshape(B, S, d), aux
