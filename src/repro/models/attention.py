"""Attention: GQA/MQA/MHA with RoPE, sliding windows, KV caches, and
DeepSeek-V2 MLA (multi-head latent attention) with compressed-cache decode.

The full-sequence path uses a blockwise online-softmax formulation (a pure-jnp
"reference flash") via lax.scan over KV chunks so 32k-token prefill never
materialises an (S x S) score matrix.  On TPU the Pallas flash kernel
(repro.kernels.flash_attention) implements the same contract; repro.kernels.ops
dispatches between them.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blockwise (flash-reference) multi-head attention
# ---------------------------------------------------------------------------

def _mask_for(q_pos, k_pos, Sk, *, causal, window):
    mask = k_pos[None, :] <= q_pos[:, None] if causal else \
        jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if window:
        mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
    return mask & (k_pos[None, :] < Sk)


def _flash_fwd_core(q, k, v, *, causal, window, q_offset, block_q,
                    block_k, scale):
    """Doubly-blocked online-softmax forward: an outer scan over query tiles,
    an inner scan over key tiles — peak transient is one (block_q x block_k)
    score tile, the same tiling discipline as the Pallas kernel.
    Returns (out fp32 (B,Sq,KV,g,Dh), lse fp32 (B,Sq,KV,g))."""
    B, Sq, H, Dh = q.shape
    _, Sk, KV, _ = k.shape
    g = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    qpad = nq * block_q - Sq
    kpad = nk * block_k - Sk
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))

    qb = jnp.moveaxis((q.astype(jnp.float32) * scale)
                      .reshape(B, nq, block_q, KV, g, Dh), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, block_k, KV, Dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, block_k, KV, Dh), 1, 0)

    def q_step(_, qin):
        qc, qi = qin                                     # (B,bq,KV,g,Dh)
        q_pos = qi * block_q + jnp.arange(block_q) + q_offset

        def k_step(carry, kin):
            m, l, acc = carry
            kc, vc, ki = kin
            s = jnp.einsum("bqkgd,btkd->bqkgt", qc, kc.astype(jnp.float32))
            k_pos = ki * block_k + jnp.arange(block_k)
            mask = _mask_for(q_pos, k_pos, Sk, causal=causal, window=window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgt,btkd->bqkgd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, block_q, KV, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, block_q, KV, g), jnp.float32)
        a0 = jnp.zeros((B, block_q, KV, g, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0),
                                      (kb, vb, jnp.arange(nk)))
        out_c = acc / jnp.maximum(l[..., None], 1e-30)
        lse_c = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out_c, lse_c)

    _, (ob, lseb) = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)))
    out = jnp.moveaxis(ob, 0, 1).reshape(B, nq * block_q, KV, g, Dh)
    lse = jnp.moveaxis(lseb, 0, 1).reshape(B, nq * block_q, KV, g)
    if qpad:
        out, lse = out[:, :Sq], lse[:, :Sq]
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _blockwise_attention_vjp(q, k, v, causal: bool = True, window: int = 0,
                             q_offset: int = 0, block_q: int = 512,
                             block_k: int = 512, scale=None):
    """Online-softmax attention with a flash-style custom VJP.

    q: (B, Sq, H, Dh); k, v: (B, Sk, KV, Dh) with H % KV == 0.  q_offset:
    absolute position of q[0] minus k[0]; window > 0 = sliding window.

    The custom backward recomputes score blocks from saved (q, k, v, out,
    lse) instead of differentiating through the forward scan — plain AD
    stores the (B,Sq,H,Dh) fp32 accumulator carry per kv block, which at 32k
    context costs >100 GB/device (measured; see EXPERIMENTS.md §Perf).
    Returns (B, Sq, H, Dh) in q.dtype.
    """
    B, Sq, H, Dh = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(Dh)
    out, _ = _flash_fwd_core(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, block_q=block_q,
                             block_k=block_k, scale=scale)
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def _flash_fwd(q, k, v, causal, window, q_offset, block_q, block_k, scale):
    B, Sq, H, Dh = q.shape
    scale_ = scale if scale is not None else 1.0 / np.sqrt(Dh)
    out, lse = _flash_fwd_core(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_k=block_k, scale=scale_)
    out_lp = out.reshape(B, Sq, H, Dh).astype(q.dtype)
    return out_lp, (q, k, v, out_lp, lse)


def _flash_bwd(causal, window, q_offset, block_q, block_k, scale, res, dout):
    """Flash backward, doubly blocked: outer scan over key tiles (emitting
    dk/dv tiles), inner scan over query tiles (accumulating dq in a carried
    full-size fp32 buffer via dynamic_update_slice)."""
    q, k, v, out, lse = res
    B, Sq, H, Dh = q.shape
    _, Sk, KV, _ = k.shape
    g = H // KV
    scale_ = scale if scale is not None else 1.0 / np.sqrt(Dh)
    block_q_ = min(block_q, Sq)
    block_k_ = min(block_k, Sk)
    nq = -(-Sq // block_q_)
    nk = -(-Sk // block_k_)
    qpad = nq * block_q_ - Sq
    kpad = nk * block_k_ - Sk
    do = dout.astype(jnp.float32).reshape(B, Sq, KV, g, Dh)
    delta = jnp.sum(do * out.astype(jnp.float32)
                    .reshape(B, Sq, KV, g, Dh), axis=-1)    # (B,Sq,KV,g)
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        do = jnp.pad(do, ((0, 0), (0, qpad), (0, 0), (0, 0), (0, 0)))
        delta = jnp.pad(delta, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        lse = jnp.pad(lse, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))

    qb = jnp.moveaxis(q.astype(jnp.float32)
                      .reshape(B, nq, block_q_, KV, g, Dh), 1, 0)
    dob = jnp.moveaxis(do.reshape(B, nq, block_q_, KV, g, Dh), 1, 0)
    deltab = jnp.moveaxis(delta.reshape(B, nq, block_q_, KV, g), 1, 0)
    lseb = jnp.moveaxis(lse.reshape(B, nq, block_q_, KV, g), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, block_k_, KV, Dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, block_k_, KV, Dh), 1, 0)

    def k_step(dq_full, kin):
        kc, vc, ki = kin
        kcf, vcf = kc.astype(jnp.float32), vc.astype(jnp.float32)
        k_pos = ki * block_k_ + jnp.arange(block_k_)

        def q_step(carry, qin):
            dq_full_, dk_acc, dv_acc = carry
            qc, doc, dc, lc, qi = qin
            q_pos = qi * block_q_ + jnp.arange(block_q_) + q_offset
            s = jnp.einsum("bqkgd,btkd->bqkgt", qc, kcf) * scale_
            mask = _mask_for(q_pos, k_pos, Sk, causal=causal, window=window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            p = jnp.exp(s - lc[..., None])
            dv_acc = dv_acc + jnp.einsum("bqkgt,bqkgd->btkd", p, doc)
            dp = jnp.einsum("bqkgd,btkd->bqkgt", doc, vcf)
            ds = p * (dp - dc[..., None])
            dq_c = jnp.einsum("bqkgt,btkd->bqkgd", ds, kcf) * scale_
            prev = jax.lax.dynamic_slice_in_dim(dq_full_, qi * block_q_,
                                                block_q_, axis=1)
            dq_full_ = jax.lax.dynamic_update_slice_in_dim(
                dq_full_, prev + dq_c, qi * block_q_, axis=1)
            dk_acc = dk_acc + jnp.einsum("bqkgt,bqkgd->btkd", ds, qc) * scale_
            return (dq_full_, dk_acc, dv_acc), None

        dk0 = jnp.zeros((B, block_k_, KV, Dh), jnp.float32)
        dv0 = jnp.zeros((B, block_k_, KV, Dh), jnp.float32)
        (dq_full, dk_blk, dv_blk), _ = jax.lax.scan(
            q_step, (dq_full, dk0, dv0),
            (qb, dob, deltab, lseb, jnp.arange(nq)))
        return dq_full, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, nq * block_q_, KV, g, Dh), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(k_step, dq0, (kb, vb, jnp.arange(nk)))
    dk = jnp.moveaxis(dk_b, 0, 1).reshape(B, nk * block_k_, KV, Dh)
    dv = jnp.moveaxis(dv_b, 0, 1).reshape(B, nk * block_k_, KV, Dh)
    if kpad:
        dk, dv = dk[:, :Sk], dv[:, :Sk]
    if qpad:
        dq = dq[:, :Sq]
    return (dq.reshape(B, Sq, H, Dh).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


_blockwise_attention_vjp.defvjp(_flash_fwd, _flash_bwd)


def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0, block_q: int = 512,
                        block_k: int = 512, scale=None):
    """Keyword-friendly front for the custom-VJP flash attention."""
    return _blockwise_attention_vjp(q, k, v, causal, window, q_offset,
                                    block_q, block_k, scale)


def decode_attention(q, k_cache, v_cache, cache_len, k_new=None, v_new=None,
                     *, window: int = 0, scale=None, exclude_slot=None):
    """Single-token attention against a cache.

    q: (B, 1, H, Dh); caches: (B, W, KV, Dh); cache_len: scalar count of valid
    entries (for a ring buffer, W once wrapped).  Entries >= cache_len masked.

    k_new/v_new (B, 1, KV, Dh): the CURRENT token's kv, attended explicitly so
    the caller can keep the cache read-only here and write the ring-buffer
    update as a separate in-place dynamic_update_slice — reading the updated
    cache forces XLA to keep a full pre-update copy alive (a cache-sized temp,
    measured at 32k decode).
    """
    B, _, H, Dh = q.shape
    _, W, KV, _ = k_cache.shape
    g = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(Dh)
    # NOTE: never .astype(fp32) the cache — a materialised fp32 copy doubles
    # decode memory; accumulate via preferred_element_type instead.
    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, g, Dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qf.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32)
    valid = jnp.arange(W) < cache_len
    if exclude_slot is not None:
        # ring buffer wrapped: the stale entry that the current token is
        # about to overwrite must not be attended
        valid = valid & (jnp.arange(W) != exclude_slot)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    if k_new is not None:
        s_new = jnp.einsum("bkgd,bkd->bkg", qf.astype(k_new.dtype),
                           k_new[:, 0], preferred_element_type=jnp.float32)
        m = jnp.maximum(s.max(axis=-1), s_new)
        p = jnp.exp(s - m[..., None])
        p_new = jnp.exp(s_new - m)
        denom = p.sum(axis=-1) + p_new
        out = (jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                          preferred_element_type=jnp.float32)
               + p_new[..., None] * v_new[:, 0, :, None, :].astype(jnp.float32)
               ) / denom[..., None]
    else:
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype):
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": layers.dense_init(ks[0], d, H * Dh, bias=cfg.qkv_bias, dtype=dtype),
        "wk": layers.dense_init(ks[1], d, KV * Dh, bias=cfg.qkv_bias, dtype=dtype),
        "wv": layers.dense_init(ks[2], d, KV * Dh, bias=cfg.qkv_bias, dtype=dtype),
        "wo": layers.dense_init(ks[3], H * Dh, d, dtype=dtype),
    }


def gqa_param_count(cfg) -> int:
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    n = d * H * Dh * 2 + d * KV * Dh * 2
    if cfg.qkv_bias:
        n += H * Dh + 2 * KV * Dh
    return n


def gqa_make_cache(cfg, batch: int, max_len: int, dtype):
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, W, KV, Dh), dtype),
            "v": jnp.zeros((batch, W, KV, Dh), dtype)}


def gqa_apply(p, cfg, x, positions, *, mode: str, cache=None, cache_len=None):
    """x: (B,S,d).  mode 'train'/'prefill' -> full-seq blockwise attention
    (prefill also returns a filled cache); mode 'decode' -> S==1 against cache.
    Returns (y, new_cache)."""
    B, S, d = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = layers.dense(p["wq"], x).reshape(B, S, H, Dh)
    k = layers.dense(p["wk"], x).reshape(B, S, KV, Dh)
    v = layers.dense(p["wv"], x).reshape(B, S, KV, Dh)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)

    if mode == "decode":
        assert cache is not None and S == 1
        W = cache["k"].shape[1]
        slot = (cache_len % W) if cfg.sliding_window else cache_len
        # attend over the READ-ONLY old cache + the new token explicitly;
        # the ring-buffer write below is then a pure in-place update.
        n_valid = jnp.minimum(cache_len, W)
        excl = slot if cfg.sliding_window else None
        out = decode_attention(q, cache["k"], cache["v"], n_valid,
                               k_new=k, v_new=v, window=cfg.sliding_window,
                               exclude_slot=excl)
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        out = blockwise_attention(q, k, v, causal=True,
                                  window=cfg.sliding_window,
                                  block_q=cfg.attn_block_q or 512,
                                  block_k=cfg.attn_block_k or 512)
        new_cache = None
        if mode == "prefill":
            W = min(S, cfg.sliding_window) if cfg.sliding_window else S
            kc, vc = k[:, S - W:], v[:, S - W:]
            if cfg.sliding_window and S > W:
                # ring alignment: slot j must hold the token with pos%W == j
                shift = (S - W) % W
                kc = jnp.roll(kc, shift, axis=1)
                vc = jnp.roll(vc, shift, axis=1)
            new_cache = {"k": kc, "v": vc}
    y = layers.dense(p["wo"], out.reshape(B, S, H * Dh))
    return y, new_cache


# ---------------------------------------------------------------------------
# DeepSeek-V2 MLA
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 8)
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = layers.dense_init(ks[0], d, m.q_lora_rank, dtype=dtype)
        p["q_norm"] = layers.rmsnorm_init(m.q_lora_rank, dtype)
        p["wq_b"] = layers.dense_init(ks[1], m.q_lora_rank, H * m.qk_head_dim,
                                      dtype=dtype)
    else:
        p["wq"] = layers.dense_init(ks[0], d, H * m.qk_head_dim, dtype=dtype)
    p["wkv_a"] = layers.dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                                   dtype=dtype)
    p["kv_norm"] = layers.rmsnorm_init(m.kv_lora_rank, dtype)
    p["wk_b"] = layers.dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_head_dim,
                                  dtype=dtype)
    p["wv_b"] = layers.dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim,
                                  dtype=dtype)
    p["wo"] = layers.dense_init(ks[5], H * m.v_head_dim, d, dtype=dtype)
    return p


def mla_param_count(cfg) -> int:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    n = 0
    if m.q_lora_rank:
        n += d * m.q_lora_rank + m.q_lora_rank + m.q_lora_rank * H * m.qk_head_dim
    else:
        n += d * H * m.qk_head_dim
    n += d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank
    n += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
    n += H * m.v_head_dim * d
    return n


def mla_make_cache(cfg, batch: int, max_len: int, dtype):
    m = cfg.mla
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {"c_kv": jnp.zeros((batch, W, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, W, m.qk_rope_head_dim), dtype)}


def _mla_q(p, cfg, x):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    if m.q_lora_rank:
        cq = layers.rmsnorm(p["q_norm"], layers.dense(p["wq_a"], x), cfg.norm_eps)
        q = layers.dense(p["wq_b"], cq)
    else:
        q = layers.dense(p["wq"], x)
    q = q.reshape(B, S, H, m.qk_head_dim)
    return q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]


def mla_apply(p, cfg, x, positions, *, mode: str, cache=None, cache_len=None):
    """MLA.  Prefill/train expand the compressed kv; decode runs in the
    compressed space via weight absorption (the cache holds c_kv + k_rope,
    rank kv_lora + rope_dim per token — DeepSeek-V2's ~1/24 cache)."""
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(p, cfg, x)
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = layers.dense(p["wkv_a"], x)
    c_kv = layers.rmsnorm(p["kv_norm"], kv_a[..., :m.kv_lora_rank], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank:][:, :, None, :]       # single shared head
    k_rope = layers.apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]

    scale = 1.0 / np.sqrt(m.qk_head_dim)

    if mode == "decode":
        assert cache is not None and S == 1
        W = cache["c_kv"].shape[1]
        slot = (cache_len % W) if cfg.sliding_window else cache_len
        c_cache = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, slot, 0))
        r_cache = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, slot, 0))
        n_valid = jnp.minimum(cache_len + 1, W)
        # --- weight absorption: score/combine entirely in rank-kv_lora space.
        # fp32 accumulation via preferred_element_type — never cast the cache
        # itself (a materialised fp32 copy doubles decode memory).
        cdt = c_cache.dtype
        wk_b = p["wk_b"]["w"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
        q_abs = jnp.einsum("bshd,chd->bshc", q_nope, wk_b,
                           preferred_element_type=jnp.float32)  # (B,1,H,rank)
        s = (jnp.einsum("bshc,btc->bhst", q_abs.astype(cdt), c_cache,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshd,btd->bhst", q_rope.astype(cdt), r_cache,
                          preferred_element_type=jnp.float32)) * scale
        valid = jnp.arange(W) < n_valid
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhst,btc->bshc", w.astype(cdt), c_cache,
                         preferred_element_type=jnp.float32)
        wv_b = p["wv_b"]["w"].reshape(m.kv_lora_rank, H, m.v_head_dim)
        out = jnp.einsum("bshc,chd->bshd", ctx.astype(jnp.float32),
                         wv_b.astype(jnp.float32))
        out = out.astype(x.dtype).reshape(B, S, H * m.v_head_dim)
        new_cache = {"c_kv": c_cache, "k_rope": r_cache}
    else:
        k_nope = layers.dense(p["wk_b"], c_kv).reshape(B, S, H, m.qk_nope_head_dim)
        v = layers.dense(p["wv_b"], c_kv).reshape(B, S, H, m.v_head_dim)
        k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                    (B, S, H, m.qk_rope_head_dim))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        # pad v up to qk_head_dim so blockwise_attention can run one einsum
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                            (0, m.qk_head_dim - m.v_head_dim)))
        out = blockwise_attention(q, k, v_pad, causal=True,
                                  window=cfg.sliding_window, scale=scale,
                                  block_q=cfg.attn_block_q or 512,
                                  block_k=cfg.attn_block_k or 512)
        out = out[..., :m.v_head_dim].reshape(B, S, H * m.v_head_dim)
        new_cache = None
        if mode == "prefill":
            W = min(S, cfg.sliding_window) if cfg.sliding_window else S
            cc, rc = c_kv[:, S - W:], k_rope[:, S - W:]
            if cfg.sliding_window and S > W:
                shift = (S - W) % W          # ring alignment (see gqa_apply)
                cc = jnp.roll(cc, shift, axis=1)
                rc = jnp.roll(rc, shift, axis=1)
            new_cache = {"c_kv": cc, "k_rope": rc}
    y = layers.dense(p["wo"], out)
    return y, new_cache


# ---------------------------------------------------------------------------
# Unified front
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype):
    return mla_init(key, cfg, dtype) if cfg.use_mla else gqa_init(key, cfg, dtype)


def attn_param_count(cfg) -> int:
    return mla_param_count(cfg) if cfg.use_mla else gqa_param_count(cfg)


def attn_make_cache(cfg, batch: int, max_len: int, dtype):
    return (mla_make_cache if cfg.use_mla else gqa_make_cache)(
        cfg, batch, max_len, dtype)


def attn_apply(p, cfg, x, positions, *, mode: str, cache=None, cache_len=None):
    f = mla_apply if cfg.use_mla else gqa_apply
    return f(p, cfg, x, positions, mode=mode, cache=cache, cache_len=cache_len)
