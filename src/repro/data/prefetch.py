"""Host -> device input pipeline: double-buffered batch prefetch.

The whole-epoch scan (`Scheme.make_epoch`, `launch/steps.make_scan_train_step`)
turns an epoch into ONE dispatch — which moves the bottleneck to the
host->device transfer of the epoch's stacked batches.  This module overlaps
that transfer with the previous epoch's compute: a producer THREAD pulls the
iterator up to ``size`` items ahead and `jax.device_put`s each immediately
(async on accelerators), so by the time the consumer asks for epoch e+1 its
buffers are already resident — and already laid out with the batch sharding
when a mesh is in play (`shardings`), so the jitted epoch never re-shards its
inputs.

Failure containment: an exception anywhere in the producer (the source
iterator, host-side batch assembly, `device_put`) is captured and RE-RAISED
on the consumer side at the next pull — the consumer never hangs on a dead
producer, and the traceback points at the real data-pipeline fault rather
than a queue timeout.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Iterable, Iterator

import jax

# queue sentinels: exhaustion vs producer fault (the exception rides along)
_DONE = object()


class _Failure:
    __slots__ = ("exc",)

    def __init__(self, exc: BaseException):
        self.exc = exc


def prefetch_to_device(iterator: Iterable, *, size: int = 2,
                       shardings: Any = None) -> Iterator:
    """Yield items from `iterator`, keeping up to `size` device transfers in
    flight ahead of the consumer (double-buffered at the default size=2).

    Each item is a pytree of host arrays; it is moved with `jax.device_put`
    before being buffered.  `shardings` is None (default device placement),
    one `jax.sharding.Sharding` applied to every leaf, or a pytree of
    shardings matching the item structure — the layout the jitted consumer
    expects, so no resharding happens at dispatch.

    The producer runs in a daemon thread, overlapping host-side batch
    assembly (index/stack) AND the device transfer with device compute of
    the current item.  If the producer raises, the exception is re-raised
    here — from the generator, on the consumer's thread — instead of the
    consumer blocking forever on an empty queue.  Dropping the generator
    early (``close()``/GC) signals the producer to stop.
    """
    if size < 1:
        raise ValueError(f"prefetch size must be >= 1, got {size}")

    def _put(item):
        if shardings is None:
            return jax.device_put(item)
        return jax.device_put(item, shardings)

    # maxsize bounds host+device memory: at most `size` items buffered plus
    # the one the producer is transferring
    buf: "queue.Queue" = queue.Queue(maxsize=size)
    stop = threading.Event()

    def _offer(item) -> bool:
        """put() that gives up when the consumer dropped the generator."""
        while not stop.is_set():
            try:
                buf.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _producer():
        try:
            for item in iterator:
                if not _offer(_put(item)):
                    return
            _offer(_DONE)
        except BaseException as exc:  # re-raised consumer-side, never lost
            _offer(_Failure(exc))

    def _drain():
        """Release every buffered item (each pins a device buffer until
        dropped) and unblock a producer stuck in put()."""
        while True:
            try:
                buf.get_nowait()
            except queue.Empty:
                return

    thread = threading.Thread(target=_producer, name="prefetch_to_device",
                              daemon=True)
    thread.start()
    try:
        while True:
            got = buf.get()
            if got is _DONE:
                return
            if isinstance(got, _Failure):
                raise got.exc
            yield got
    finally:
        # A consumer that drops the generator early (close()/GC) used to
        # leave the producer thread alive and up to `size` device_put items
        # queued, pinning their device buffers until GC.  Drain + join: the
        # producer observes `stop` within its 0.1 s put timeout, so the
        # bounded join only trips if an item's device_put itself hangs —
        # in which case the daemon thread cannot block interpreter exit.
        stop.set()
        _drain()
        thread.join(timeout=5.0)
        _drain()
