"""Host -> device input pipeline: double-buffered batch prefetch.

The whole-epoch scan (`Scheme.make_epoch`, `launch/steps.make_scan_train_step`)
turns an epoch into ONE dispatch — which moves the bottleneck to the
host->device transfer of the epoch's stacked batches.  This module overlaps
that transfer with the previous epoch's compute: the iterator is pulled
``size`` items ahead and each item is `jax.device_put` immediately (async on
accelerators), so by the time the consumer asks for epoch e+1 its buffers are
already resident — and already laid out with the batch sharding when a mesh
is in play (`shardings`), so the jitted epoch never re-shards its inputs.
"""
from __future__ import annotations

import collections
from typing import Any, Iterable, Iterator

import jax


def prefetch_to_device(iterator: Iterable, *, size: int = 2,
                       shardings: Any = None) -> Iterator:
    """Yield items from `iterator`, keeping up to `size` device transfers in
    flight ahead of the consumer (double-buffered at the default size=2).

    Each item is a pytree of host arrays; it is moved with `jax.device_put`
    before being buffered.  `shardings` is None (default device placement),
    one `jax.sharding.Sharding` applied to every leaf, or a pytree of
    shardings matching the item structure — the layout the jitted consumer
    expects, so no resharding happens at dispatch.

    Pulling the source iterator ahead also overlaps any host-side batch
    assembly it performs (index/stack) with device compute of the current
    item — the data-loading boundary the whole-epoch scan needs hidden.
    """
    if size < 1:
        raise ValueError(f"prefetch size must be >= 1, got {size}")

    def _put(item):
        if shardings is None:
            return jax.device_put(item)
        return jax.device_put(item, shardings)

    buf = collections.deque()
    it = iter(iterator)
    done = False
    while True:
        while not done and len(buf) < size:
            try:
                buf.append(_put(next(it)))
            except StopIteration:
                done = True
        if not buf:
            return
        yield buf.popleft()
