"""Synthetic token streams for LLM smoke training / examples.

The stream has learnable first-order structure (a noisy affine Markov chain
over the vocab) so a few hundred training steps visibly reduce loss — the
end-to-end driver (examples/train_llm.py) relies on this.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


def markov_stream(vocab_size: int, n_tokens: int, *, seed: int = 0,
                  noise: float = 0.2) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = int(rng.integers(3, 17)) | 1                  # odd multiplier
    b = int(rng.integers(1, vocab_size))
    toks = np.empty(n_tokens, np.int32)
    toks[0] = rng.integers(0, vocab_size)
    rand = rng.integers(0, vocab_size, size=n_tokens)
    use_rand = rng.random(n_tokens) < noise
    for t in range(1, n_tokens):
        toks[t] = rand[t] if use_rand[t] else (a * int(toks[t - 1]) + b) % vocab_size
    return toks


def lm_batches(cfg, batch_size: int, seq_len: int, *, steps: int,
               seed: int = 0) -> Iterator[dict]:
    """Yields batch dicts matching repro.models.zoo input conventions."""
    stream = markov_stream(cfg.vocab_size,
                           batch_size * (seq_len + 1) * max(steps, 1) + 1,
                           seed=seed)
    rng = np.random.default_rng(seed + 1)
    per = batch_size * (seq_len + 1)
    for s in range(steps):
        chunk = stream[s * per:(s + 1) * per + 1]
        x = chunk[:per].reshape(batch_size, seq_len + 1)
        tokens, labels = x[:, :-1], x[:, 1:].astype(np.int32)
        if cfg.modality == "audio_tokens":
            k = cfg.num_codebooks
            mc = np.stack([(tokens + i * 7) % cfg.vocab_size
                           for i in range(k)], axis=-1).astype(np.int32)
            lab = np.stack([(labels + i * 7) % cfg.vocab_size
                            for i in range(k)], axis=-1).astype(np.int32)
            yield {"tokens_mc": mc, "labels": lab}
        elif cfg.modality == "vlm":
            P = cfg.num_prefix_tokens
            patches = rng.normal(size=(batch_size, P, cfg.d_model)) \
                .astype(np.float32)
            lab = np.concatenate(
                [np.full((batch_size, P), -1, np.int32), labels], axis=1)
            yield {"patch_embeds": patches, "tokens": tokens, "labels": lab}
        else:
            yield {"tokens": tokens, "labels": labels}
