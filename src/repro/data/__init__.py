from repro.data import multiview, tokens  # noqa
