"""Synthetic multi-view image-classification data (the paper's §IV setting).

CIFAR-10 is not downloadable in this container, so we generate a CIFAR-like
dataset that preserves the structure the experiments depend on: 10 classes,
32x32x3 normalised images with intra-class variation, and J noisy VIEWS of
each image (additive Gaussian noise, sigma per client = 0.4, 1, 2, 3, 4).
Relative scheme ordering (INL vs FL vs SL) and the accuracy/bandwidth
trade-off remain meaningful; absolute CIFAR accuracies do not transfer.

Experiment 1 (paper §IV-A): the dataset is PARTITIONED per scheme's needs —
INL: every client sees its own noisy view of every image; FL: disjoint
1/J-th shards, all J views of an image go to the same client; SL: same
partition as FL.

Experiment 2 (paper §IV-B): all clients see ALL images; clients differ only
by their noise level.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def make_base_dataset(n: int, num_classes: int = 10,
                      image_shape=(32, 32, 3), seed: int = 0):
    """Returns (images (n,H,W,C) float32 normalised, labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    H, W, C = image_shape
    # class prototypes: smooth low-frequency patterns, distinct per class
    fx = rng.normal(size=(num_classes, 4, 4, C)).astype(np.float32)
    protos = np.stack([_upsample(fx[c], H, W) for c in range(num_classes)])
    protos = protos / protos.std(axis=(1, 2, 3), keepdims=True)
    labels = rng.integers(0, num_classes, size=n).astype(np.int32)
    # intra-class variation: per-sample smooth deformation + pixel noise
    var = rng.normal(size=(n, 4, 4, C)).astype(np.float32) * 0.6
    images = protos[labels] + np.stack([_upsample(v, H, W) for v in var])
    images += rng.normal(size=images.shape).astype(np.float32) * 0.1
    images = (images - images.mean()) / images.std()    # "normalised CIFAR"
    return images.astype(np.float32), labels


def _upsample(x, H, W):
    """Bilinear-ish upsample of a (h,w,C) grid to (H,W,C) via np.kron+smooth."""
    h, w, C = x.shape
    up = np.kron(x.transpose(2, 0, 1), np.ones((H // h, W // w))) \
        .transpose(1, 2, 0)
    # cheap smoothing: two passes of a box filter
    for axis in (0, 1):
        up = (np.roll(up, 1, axis) + up + np.roll(up, -1, axis)) / 3.0
    return up.astype(np.float32)


def make_views(images: np.ndarray, noise_stds, seed: int = 1) -> np.ndarray:
    """(n,H,W,C) -> (J,n,H,W,C): view j = image + N(0, sigma_j^2)."""
    rng = np.random.default_rng(seed)
    return np.stack([
        images + rng.normal(size=images.shape).astype(np.float32) * s
        for s in noise_stds])


def average_view(views: np.ndarray) -> np.ndarray:
    """FL inference input for Experiment 2: the average-quality image."""
    return views.mean(axis=0)


# ---------------------------------------------------------------------------
# Per-scheme splits
# ---------------------------------------------------------------------------

def split_experiment1(views, labels, num_clients: int, seed: int = 2):
    """Paper Exp-1 partition.

    INL: client j gets view j of ALL images (+ labels at node J+1).
    FL/SL: disjoint shards of the image index set; client j receives all J
    views of its shard's images (FL trains the full Fig.-4 network on them).
    Returns dict with 'inl' -> (views, labels) and 'fl' -> list of
    (views_shard (J,n_j,...), labels_shard).
    """
    n = labels.shape[0]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    shards = np.array_split(perm, num_clients)
    fl = [(views[:, idx], labels[idx]) for idx in shards]
    return {"inl": (views, labels), "fl": fl, "sl": fl}


def split_experiment2(views, labels, num_clients: int):
    """Paper Exp-2: every client sees all images; only the noise differs."""
    per_client = [(views[j], labels) for j in range(num_clients)]
    return {"inl": (views, labels), "fl": per_client, "sl": per_client}


def batch_indices(n: int, batch_size: int, *, seed: int = 0,
                  epochs: int = 1) -> Iterator[np.ndarray]:
    """Seeded, shuffled, DROP-REMAINDER minibatch index stream.

    The single source of batching truth for every scheme/trainer: each epoch
    is a fresh permutation of [0, n) cut into exactly ``n // batch_size``
    full-size batches.  The trailing partial batch is always dropped — a
    short batch would retrace/recompile every jitted step it reaches and
    shape-mismatch a stacked whole-epoch `lax.scan`."""
    rng = np.random.default_rng(seed)
    per_epoch = (n // batch_size) * batch_size
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, per_epoch, batch_size):
            yield perm[i:i + batch_size]


def multiview_batches(views: np.ndarray, labels: np.ndarray, batch_size: int,
                      *, seed: int = 0, epochs: int = 1
                      ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Shuffled mini-batches of ((J,b,H,W,C) views, (b,) labels)."""
    for idx in batch_indices(labels.shape[0], batch_size, seed=seed,
                             epochs=epochs):
        yield views[:, idx], labels[idx]


def image_batches(images: np.ndarray, labels: np.ndarray, batch_size: int,
                  *, seed: int = 0, epochs: int = 1
                  ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Shuffled mini-batches of ((b,H,W,C) images, (b,) labels)."""
    for idx in batch_indices(labels.shape[0], batch_size, seed=seed,
                             epochs=epochs):
        yield images[idx], labels[idx]
