"""INL serving plane: continuous-batching inference over a topology.

    engine    per-node request queues, bucketed jitted predict (one compile
              per bucket size), per-request fuse-what-arrived fault draws,
              two-ledger bandwidth metering.
    batching  the pad-to-bucket grid ({1, 4, 16, 64} by default).
    metering  per-request per-edge bit/byte charges (forward direction).
    loadgen   seeded Poisson offered-load runs + serial-capacity anchor.

`launch/serve.py` is the CLI front end; `benchmarks/serve_bench.py` sweeps
offered load per topology and wire format into BENCH_serve.json.
"""
from repro.serving.batching import BUCKETS, pad_to_bucket, pick_bucket
from repro.serving.engine import (EngineShutdown, Rejected, ServedRequest,
                                  ServeStats, ServingEngine)
from repro.serving.loadgen import measure_serial_capacity, run_poisson
from repro.serving.metering import request_bits, request_edge_bits

__all__ = [
    "BUCKETS", "pad_to_bucket", "pick_bucket",
    "EngineShutdown", "Rejected", "ServedRequest", "ServeStats",
    "ServingEngine",
    "measure_serial_capacity", "run_poisson",
    "request_bits", "request_edge_bits",
]
