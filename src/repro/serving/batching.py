"""Bucketed batch assembly: pad in-flight requests to a fixed size grid.

A jitted predict retraces on every new batch shape, so a serving loop that
launches whatever happens to be queued would recompile continuously under
request churn.  Instead the engine coalesces requests into the smallest
BUCKET that holds them (default grid {1, 4, 16, 64}, `Scheme.serve_buckets`)
and pads the batch up to that size — so the engine compiles AT MOST one
predict per bucket size for its whole lifetime, and a steady stream of
mixed-size batches reuses the same four executables forever.

Padding is row-wise inert: inference has no cross-sample ops (BatchNorm
runs on running stats, the fusion concatenation is per sample), so a real
request's probabilities are bit-identical whether it rides a full bucket,
a padded one, or a bucket of one (tests/test_serving.py pins this).  Pad
rows replicate the last real request — a grid value the compiled network
has certainly seen — and their outputs are dropped before completion.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

BUCKETS: Tuple[int, ...] = (1, 4, 16, 64)


def validate_buckets(buckets: Sequence[int]) -> Tuple[int, ...]:
    """Ascending, positive, deduplicated — the engine's static size grid."""
    out = tuple(sorted(set(int(b) for b in buckets)))
    if not out or out[0] < 1:
        raise ValueError(f"buckets must be positive ints, got {buckets}")
    return out


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """The smallest bucket holding n requests (callers cap collection at
    max(buckets), so n never exceeds the grid)."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"batch of {n} exceeds the largest bucket "
                     f"{buckets[-1]}; collect at most max(buckets) requests")


def pad_to_bucket(views: np.ndarray, rids: np.ndarray, bucket: int):
    """((J, n, ...) views, (n,) ids) -> ((J, bucket, ...), (bucket,)).

    Pad rows repeat the last real request (ids included, so their fault
    draws are well-defined); the engine slices the first n rows of the
    result and never completes a pad row."""
    n = views.shape[1]
    if n > bucket:
        raise ValueError(f"batch of {n} does not fit bucket {bucket}")
    if n == bucket:
        return views, rids
    pad = bucket - n
    views = np.concatenate(
        [views, np.repeat(views[:, -1:], pad, axis=1)], axis=1)
    rids = np.concatenate([rids, np.repeat(rids[-1:], pad)])
    return views, rids
