"""Per-request bandwidth: what ONE inference puts on each topology edge.

Training rounds charge both directions (§III-C: activations forward, eq.-
(10) error vectors back); a served request ships each edge's payload ONCE,
forward only — every view latent traverses its route to the fusion center
and nothing returns.  Closed-form charge per edge is therefore
|payload| * d_bottleneck * link_bits, and the measured bytes are the
forward leg of the same `core/wirefmt.py` accounting the training ledgers
use (`shipped_nbytes` over the real pack/ship ops) — so the serving meter
and the training meter cannot drift apart.

The engine charges these static per-request figures on the OFFERED ledger
for every completed request, and credits the DELIVERED ledger with each
edge's surviving payload fraction from the request's fuse-what-arrived
mask — the same convention `linkfault.round_fault_charges` applies to
training rounds, at batch granularity there and request granularity here.
"""
from __future__ import annotations

from typing import Dict

from repro.core import topology as topology_lib
from repro.core import wirefmt


def request_edge_bits(topo, cfg) -> Dict[str, float]:
    """Closed-form bits ONE request offers each edge (forward only)."""
    return {e.key: float(len(topo.payload(e)) * cfg.d_bottleneck
                         * topology_lib.edge_bits(e, cfg))
            for e in topo.topo_edges()}


def request_edge_wire_bytes(topo, cfg, *, wire: str = "dense"
                            ) -> Dict[str, float]:
    """Measured bytes ONE request's payload occupies on each edge under
    `wire` (the edge's own wire/dtype overrides win, as in training)."""
    return {e.key: float(wirefmt.shipped_nbytes(
                len(topo.payload(e)), cfg.d_bottleneck,
                link_bits=topology_lib.edge_bits(e, cfg),
                wire=topology_lib.edge_wire(e, wire),
                dtype=topology_lib.edge_dtype(e, cfg)))
            for e in topo.topo_edges()}


def request_bits(topo, cfg) -> float:
    return float(sum(request_edge_bits(topo, cfg).values()))


def meter_served_batch(meter, topo, cfg, mask, *, edge_bits: Dict[str, float],
                       edge_nbytes: Dict[str, float]) -> None:
    """Charge one completed batch on a BandwidthMeter's two ledgers.

    mask — the (J, n) delivery mask of the n REAL requests (pad rows
    already sliced off).  Offered: every request charges every edge in
    full (the schedule transmitted; the network dropped).  Delivered: each
    edge credits the fraction of its payload views that reached the fusion,
    summed over the batch — all-ones masks credit delivered == offered
    exactly, so a clean network keeps delivery_ratio at 1.0."""
    n = int(mask.shape[1])
    for e in topo.topo_edges():
        pay = list(topo.payload(e))
        bits, nbytes = edge_bits[e.key], edge_nbytes[e.key]
        meter.add_edge(e.key, bits=n * bits, nbytes=n * nbytes)
        frac = float(mask[pay, :].sum()) / len(pay)   # sums over requests
        meter.add_delivered(bits=bits * frac, nbytes=nbytes * frac,
                            edge=e.key)
