"""Poisson load generation against a live ServingEngine.

The benchmarkable question for the serving plane is not "how fast is one
predict" but "what latency does a request see at a given OFFERED LOAD" —
the continuous-batching argument only shows up under contention, when
arrivals outpace serial service and the engine coalesces the backlog into
wide buckets.  `run_poisson` drives exactly that experiment: seeded
exponential inter-arrivals at a target rate, every request's views drawn
from a fixed pool, and a summary with the three serving numbers that
matter — p50/p99 latency, goodput, and the per-request delivered-bits
ledger snapshotted off the engine's BandwidthMeter.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np


def percentile_ms(latencies, q: float) -> float:
    """`np.percentile` with the degenerate sample sizes guarded: an empty
    list is 0.0 (not a ValueError mid-benchmark) and a single sample IS
    every percentile — so `run_poisson` with one request reports a real
    p99 instead of crashing the summary."""
    lats = np.asarray(latencies, np.float64)
    if lats.size == 0:
        return 0.0
    if lats.size == 1:
        return float(lats[0])
    return float(np.percentile(lats, q))


def run_poisson(engine, views_pool: np.ndarray, *, rate_rps: float,
                num_requests: int, seed: int = 0,
                timeout: float = 600.0) -> Dict[str, float]:
    """Offer `num_requests` to a STARTED engine at `rate_rps` (Poisson:
    seeded exponential inter-arrivals), wait for all completions, and
    summarise.

    views_pool — (J, n_pool, ...) request views, cycled through in order so
    a fixed (pool, seed) pair replays an identical arrival stream.  When
    the generator falls behind its schedule (a long batch blocked the
    clock) it submits immediately and catches up rather than silently
    thinning the offered load.

    Returns {offered_rps, goodput_rps, p50_ms, p99_ms, served, mean_views_fused,
    offered_gbits, delivered_gbits, delivery_ratio, wall_s} — goodput is
    completions over the span from first submit to last completion, and the
    bit ledgers are this run's delta on the engine meter.
    """
    rng = np.random.default_rng(seed)
    n_pool = views_pool.shape[1]
    gaps = rng.exponential(1.0 / rate_rps, size=num_requests)

    bits0, dbits0 = engine.meter.total_bits, engine.meter.delivered_bits
    futs = []
    t0 = time.perf_counter()
    due = t0
    for i in range(num_requests):
        due += gaps[i]
        now = time.perf_counter()
        if due > now:
            time.sleep(due - now)
        futs.append(engine.submit(views_pool[:, i % n_pool])[1])

    results = [f.result(timeout=timeout) for f in futs]
    # an engine with max_queue= resolves shed requests to Rejected — they
    # count against goodput (offered but not served), not against latency
    served = [r for r in results if hasattr(r, "probs")]
    # num_requests=0 (or 1) must yield a NaN-free summary: guard the empty
    # max()/mean() and let percentile_ms handle the sub-2-sample lists
    t_end = max((r.t_done for r in results), default=t0)
    span = max(t_end - t0, 1e-9)

    lats = [r.latency_ms for r in served]
    fused = [r.views_fused for r in served]
    offered_bits = engine.meter.total_bits - bits0
    delivered_bits = engine.meter.delivered_bits - dbits0
    return {
        "offered_rps": float(rate_rps),
        "goodput_rps": len(served) / span,
        "p50_ms": percentile_ms(lats, 50),
        "p99_ms": percentile_ms(lats, 99),
        "served": len(served),
        "shed": len(results) - len(served),
        "mean_views_fused": float(np.mean(fused)) if fused else 0.0,
        "offered_gbits": offered_bits / 1e9,
        "delivered_gbits": delivered_bits / 1e9,
        "delivery_ratio": (delivered_bits / offered_bits
                           if offered_bits else 1.0),
        "wall_s": span,
    }


def measure_serial_capacity(engine, views_pool: np.ndarray, *,
                            num_requests: int = 32,
                            timeout: float = 600.0) -> float:
    """Requests-per-second of STRICTLY SERIAL service on a started engine:
    submit one, wait, submit the next.  The calibration anchor for the
    sweep's offered-load points — and the baseline the continuous-batching
    goodput is asserted against."""
    n_pool = views_pool.shape[1]
    t0 = time.perf_counter()
    last = t0
    for i in range(num_requests):
        _, fut = engine.submit(views_pool[:, i % n_pool])
        last = fut.result(timeout=timeout).t_done
    return num_requests / max(last - t0, 1e-9)
