"""The INL serving plane: continuous batching over a network topology.

The training side of this repo ends at a trained scheme state; this module
is the inference side the paper actually argues for (§III): distributively
extracted features travel as narrow quantized latents over the topology's
edges to the fusion center, which answers requests.  The engine turns that
into a serving loop shaped like an inference platform:

    per-node request queues   a request fans its J views out to one queue
                              per view node (`submit` enqueues all J
                              fragments atomically, so the queues stay
                              aligned); the fusion-side scheduler pops the
                              oldest coalescible prefix of every queue.
    continuous batching       the scheduler thread loops: grab EVERYTHING
                              queued (up to the largest bucket), launch,
                              complete, repeat — new arrivals coalesce into
                              the next launch instead of waiting behind a
                              fixed-size batch barrier.
    pad-to-bucket             batches pad to the smallest bucket in
                              `Scheme.serve_buckets` ({1, 4, 16, 64}), so
                              the engine compiles AT MOST one predict per
                              bucket size — no retracing under churn
                              (`trace_counts` exposes the proof).
    fuse-what-arrived         per REQUEST: fault draws are keyed by request
                              id (`linkfault.request_delivery_mask`), so a
                              straggling view misses only its own fusion,
                              never its batchmates' — and a request's mask
                              is identical whether it rides a full bucket
                              or is served alone.
    packed-wire hops          the engine's `wire=` threads through
                              `Scheme.predict_batched` into the topology's
                              relay hops (`wirefmt` / `graph_cut_and_ship`)
                              and into the per-request bytes ledger.
    two-ledger metering       every completed request charges the offered /
                              delivered `BandwidthMeter` ledgers per edge
                              (serving/metering.py).

Numerics contract (pinned by tests/test_serving.py, asserted in
benchmarks/serve_bench.py): WITHIN a bucket executable, padding and batch
composition cannot move any request's output — bit for bit, clean or
faulty (padding is row-inert and fault draws are request-id-keyed).
ACROSS bucket sizes — and against a jit(scheme.predict) reference at a
different batch shape — outputs agree to tight float tolerance with
identical argmax decisions: XLA compiles each batch shape separately and
the executables may round the last ulp differently.  The EAGER
scheme.predict is one more step removed (~1e-7: jit fuses op chains — the
graph hops' re-quantization especially — differently from op-by-op
dispatch).  Boolean delivery masks are exact everywhere: a request's mask
is a pure function of (seed, request id, edge), whatever rides alongside.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bandwidth, linkfault
from repro.core import topology as topology_lib
from repro.serving import batching, metering


@dataclass(frozen=True)
class ServedRequest:
    """One completed request, as its Future resolves it."""
    rid: int
    probs: np.ndarray            # (C,) class probabilities
    views_fused: int             # how many of the J views made the fusion
    latency_ms: float            # submit -> completion (queue + batch + run)
    t_done: float                # perf_counter stamp at completion


@dataclass
class ServeStats:
    """Aggregates the engine accumulates while serving."""
    completed: int = 0
    latencies_ms: List[float] = field(default_factory=list)
    views_fused: List[int] = field(default_factory=list)
    launches: int = 0
    launched_rows: int = 0       # bucket rows launched (padding included)

    @property
    def pad_fraction(self) -> float:
        """Fraction of launched rows that were padding — the price of the
        bucket grid (0.0 when every batch lands exactly on a bucket)."""
        if not self.launched_rows:
            return 0.0
        return 1.0 - self.completed / self.launched_rows


class ServingEngine:
    """Continuous-batching inference over one trained scheme state.

    scheme/state/cfg — a registered Scheme, its trained state pytree, and
    the experiment config.  topology (None = the implicit star) may carry
    LinkModels; any link model — or an explicit `deadline_ms` — switches
    serving onto per-request fuse-what-arrived masks.  `wire` is the hop
    encoding AND the measured-bytes convention.  `buckets` overrides the
    scheme's grid (a serial baseline is `buckets=(1,)`).

    Thread model: `submit` is called from any thread; one scheduler thread
    (started by `start()` / the context manager) runs the collect -> pad ->
    launch -> complete loop.  `stop()` drains everything queued before
    joining.  The engine also works fully synchronously: `serve()` submits
    a block and waits, and `step()` runs one scheduler iteration inline —
    tests use the inline mode for determinism.
    """

    def __init__(self, scheme, state, cfg, *, topology=None,
                 wire: str = "dense", buckets: Sequence[int] = None,
                 deadline_ms: Optional[float] = None, seed: int = 0,
                 meter: Optional[bandwidth.BandwidthMeter] = None):
        self.scheme, self.state, self.cfg = scheme, state, cfg
        self.topology = topology
        self.topo = topology_lib.resolve(topology, cfg)
        self.wire = wire
        self.deadline_ms = deadline_ms
        self.buckets = batching.validate_buckets(
            buckets if buckets is not None else scheme.serve_buckets)
        # any link model (or an explicit deadline) switches serving onto
        # per-request delivery masks; a bare topology stays on the plain
        # predict path — bit-identical to scheme.predict
        self.faulty = (linkfault.has_link_models(self.topo)
                       or deadline_ms is not None)
        self._key = jax.random.PRNGKey(seed)
        self._queues: Dict[str, collections.deque] = {
            name: collections.deque() for name in self.topo.view_nodes()}
        self._futures: Dict[int, Future] = {}
        self._submit_t: Dict[int, float] = {}
        self._next_rid = 0
        self._work = threading.Condition()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # one jitted predict per bucket; the list inside each closure is
        # appended to at TRACE time only, so trace_counts[b] is the number
        # of compilations bucket b ever paid (the no-retracing contract)
        self.trace_counts: Dict[int, int] = {b: 0 for b in self.buckets}
        self._predict = {b: self._make_bucket_predict(b)
                         for b in self.buckets}
        self.meter = bandwidth.BandwidthMeter() if meter is None else meter
        self._edge_bits = metering.request_edge_bits(self.topo, cfg)
        self._edge_nbytes = metering.request_edge_wire_bytes(
            self.topo, cfg, wire=wire)
        self.stats = ServeStats()

    # -- the bucketed predict ---------------------------------------------

    def _make_bucket_predict(self, bucket: int):
        scheme, cfg = self.scheme, self.cfg
        topo_arg, topo = self.topology, self.topo
        wire, deadline, faulty = self.wire, self.deadline_ms, self.faulty
        counts = self.trace_counts

        def fn(state, views, rids, key):
            counts[bucket] += 1          # trace-time side effect only
            if faulty:
                delivery = linkfault.request_delivery_mask(
                    key, topo, cfg, rids, deadline=deadline)
                probs = scheme.predict_batched(
                    state, views, delivery=delivery, topology=topo_arg,
                    cfg=cfg, wire=wire)
            else:
                # clean network: no masks at all — the plain predict graph,
                # bit-identical to scheme.predict on the same rows
                delivery = jnp.ones((topo.num_views(), bucket), bool)
                probs = scheme.predict_batched(
                    state, views, topology=topo_arg, cfg=cfg, wire=wire)
            return probs, delivery
        return jax.jit(fn)

    def warmup(self) -> None:
        """Pay every bucket's compile up front (latency measurements then
        never include a trace)."""
        J = self.topo.num_views()
        H, W, C = self.cfg.image_shape
        for b in self.buckets:
            views = jnp.zeros((J, b, H, W, C), jnp.float32)
            rids = jnp.zeros((b,), jnp.int32)
            out, _ = self._predict[b](self.state, views, rids, self._key)
            out.block_until_ready()

    # -- request intake ----------------------------------------------------

    def submit(self, views) -> Tuple[int, Future]:
        """Enqueue one request's (J, H, W, C) views — one fragment per
        measure/relay node queue, atomically, so the per-node queues always
        pop aligned.  Returns (request id, Future resolving to a
        ServedRequest)."""
        views = np.asarray(views)
        if views.shape[0] != self.topo.num_views():
            raise ValueError(
                f"request has {views.shape[0]} views; topology "
                f"{self.topo.describe()} expects {self.topo.num_views()}")
        fut: Future = Future()
        with self._work:
            rid = self._next_rid
            self._next_rid += 1
            for j, name in enumerate(self.topo.view_nodes()):
                self._queues[name].append((rid, views[j]))
            self._futures[rid] = fut
            self._submit_t[rid] = time.perf_counter()
            self._work.notify()
        return rid, fut

    def pending(self) -> int:
        with self._work:
            return len(self._futures)

    # -- the scheduler -----------------------------------------------------

    def _collect(self):
        """Pop the oldest <= max-bucket requests off every node queue
        (caller holds the lock).  Returns ((n,) rids, (J, n, ...) views)
        or None when idle."""
        names = self.topo.view_nodes()
        m = min(len(self._queues[nm]) for nm in names)
        m = min(m, self.buckets[-1])
        if m == 0:
            return None
        rids, frags = None, []
        for nm in names:
            row = [self._queues[nm].popleft() for _ in range(m)]
            got = [r for r, _ in row]
            if rids is None:
                rids = got
            # submit() appends to every queue under the lock, so the
            # aligned-prefix invariant cannot break
            assert got == rids, (got, rids)
            frags.append(np.stack([f for _, f in row]))
        return np.asarray(rids, np.int32), np.stack(frags)

    def _execute(self, rids: np.ndarray, views: np.ndarray) -> None:
        n = len(rids)
        bucket = batching.pick_bucket(n, self.buckets)
        pviews, prids = batching.pad_to_bucket(views, rids, bucket)
        probs, delivery = self._predict[bucket](
            self.state, jnp.asarray(pviews), jnp.asarray(prids), self._key)
        probs_np = np.asarray(probs)[:n]          # blocks until ready
        mask_np = np.asarray(delivery)[:, :n]
        t_done = time.perf_counter()
        metering.meter_served_batch(self.meter, self.topo, self.cfg,
                                    mask_np, edge_bits=self._edge_bits,
                                    edge_nbytes=self._edge_nbytes)
        self.stats.launches += 1
        self.stats.launched_rows += bucket
        for i, rid in enumerate(rids):
            rid = int(rid)
            with self._work:
                fut = self._futures.pop(rid)
                t_sub = self._submit_t.pop(rid)
            lat = (t_done - t_sub) * 1e3
            fused = int(mask_np[:, i].sum())
            self.stats.completed += 1
            self.stats.latencies_ms.append(lat)
            self.stats.views_fused.append(fused)
            fut.set_result(ServedRequest(rid=rid, probs=probs_np[i],
                                         views_fused=fused, latency_ms=lat,
                                         t_done=t_done))

    def step(self, timeout: float = 0.0) -> int:
        """One scheduler iteration inline: collect -> launch -> complete.
        Returns the number of requests completed (0 when idle past
        `timeout`)."""
        with self._work:
            batch = self._collect()
            if batch is None and timeout > 0:
                self._work.wait(timeout)
                batch = self._collect()
        if batch is None:
            return 0
        rids, views = batch
        self._execute(rids, views)
        return len(rids)

    def _loop(self) -> None:
        while True:
            with self._work:
                batch = self._collect()
                if batch is None:
                    if self._stop.is_set():
                        return                     # queues drained: done
                    self._work.wait(timeout=0.05)
                    continue
            rids, views = batch
            self._execute(rids, views)

    def start(self) -> "ServingEngine":
        if self._thread is not None:
            raise RuntimeError("engine already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="inl-serving-engine",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 60.0) -> None:
        """Drain the queues, complete everything in flight, join."""
        if self._thread is None:
            return
        self._stop.set()
        with self._work:
            self._work.notify()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            raise RuntimeError("serving engine failed to drain and stop")
        self._thread = None

    def __enter__(self) -> "ServingEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- synchronous conveniences -----------------------------------------

    def serve(self, views, timeout: float = 120.0):
        """Submit a (J, n, ...) block and wait for all n answers.

        Returns ((n, C) probabilities, list of ServedRequest in submit
        order).  Runs through the live scheduler thread when started, else
        inline."""
        n = views.shape[1]
        futs = [self.submit(views[:, i])[1] for i in range(n)]
        if self._thread is None:
            while any(not f.done() for f in futs):
                if self.step() == 0:
                    break
        results = [f.result(timeout=timeout) for f in futs]
        return np.stack([r.probs for r in results]), results
