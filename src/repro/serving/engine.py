"""The INL serving plane: continuous batching over a network topology.

The training side of this repo ends at a trained scheme state; this module
is the inference side the paper actually argues for (§III): distributively
extracted features travel as narrow quantized latents over the topology's
edges to the fusion center, which answers requests.  The engine turns that
into a serving loop shaped like an inference platform:

    per-node request queues   a request fans its J views out to one queue
                              per view node (`submit` enqueues all J
                              fragments atomically, so the queues stay
                              aligned); the fusion-side scheduler pops the
                              oldest coalescible prefix of every queue.
    continuous batching       the scheduler thread loops: grab EVERYTHING
                              queued (up to the largest bucket), launch,
                              complete, repeat — new arrivals coalesce into
                              the next launch instead of waiting behind a
                              fixed-size batch barrier.
    pad-to-bucket             batches pad to the smallest bucket in
                              `Scheme.serve_buckets` ({1, 4, 16, 64}), so
                              the engine compiles AT MOST one predict per
                              bucket size — no retracing under churn
                              (`trace_counts` exposes the proof).
    fuse-what-arrived         per REQUEST: fault draws are keyed by request
                              id (`linkfault.request_delivery_mask`), so a
                              straggling view misses only its own fusion,
                              never its batchmates' — and a request's mask
                              is identical whether it rides a full bucket
                              or is served alone.
    packed-wire hops          the engine's `wire=` threads through
                              `Scheme.predict_batched` into the topology's
                              relay hops (`wirefmt` / `graph_cut_and_ship`)
                              and into the per-request bytes ledger.
    two-ledger metering       every completed request charges the offered /
                              delivered `BandwidthMeter` ledgers per edge
                              (serving/metering.py).

Numerics contract (pinned by tests/test_serving.py, asserted in
benchmarks/serve_bench.py): WITHIN a bucket executable, padding and batch
composition cannot move any request's output — bit for bit, clean or
faulty (padding is row-inert and fault draws are request-id-keyed).
ACROSS bucket sizes — and against a jit(scheme.predict) reference at a
different batch shape — outputs agree to tight float tolerance with
identical argmax decisions: XLA compiles each batch shape separately and
the executables may round the last ulp differently.  The EAGER
scheme.predict is one more step removed (~1e-7: jit fuses op chains — the
graph hops' re-quantization especially — differently from op-by-op
dispatch).  Boolean delivery masks are exact everywhere: a request's mask
is a pure function of (seed, request id, edge), whatever rides alongside.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bandwidth, linkfault
from repro.core import topology as topology_lib
from repro.serving import batching, metering


class EngineShutdown(RuntimeError):
    """The engine is shutting down: new submits are refused with this, and
    requests still pending when the drain window closes fail with it."""


@dataclass(frozen=True)
class Rejected:
    """One request refused at admission (its Future resolves to THIS, not
    to an exception: shedding is an expected overload outcome the caller
    handles inline, not a programming error)."""
    rid: int
    reason: str
    t_done: float                # perf_counter stamp at rejection


@dataclass(frozen=True)
class ServedRequest:
    """One completed request, as its Future resolves it."""
    rid: int
    probs: np.ndarray            # (C,) class probabilities
    views_fused: int             # how many of the J views made the fusion
    latency_ms: float            # submit -> completion (queue + batch + run)
    t_done: float                # perf_counter stamp at completion
    # which fusion answered (speculative-fusion accounting): "first" — the
    # at-deadline fusion; "patched" — a later bucket after the request's
    # stragglers arrived and were patched in
    served_by: str = "first"
    views_recovered: int = 0     # late views the patched fusion added


@dataclass
class ServeStats:
    """Aggregates the engine accumulates while serving."""
    completed: int = 0
    latencies_ms: List[float] = field(default_factory=list)
    views_fused: List[int] = field(default_factory=list)
    launches: int = 0
    launched_rows: int = 0       # bucket rows launched (padding included)
    patched: int = 0             # requests answered by a patched fusion
    views_recovered: int = 0     # straggler views patched fusions added
    shed: int = 0                # requests refused at admission (Rejected)

    @property
    def pad_fraction(self) -> float:
        """Fraction of launched rows that were padding — the price of the
        bucket grid (0.0 when every batch lands exactly on a bucket)."""
        if not self.launched_rows:
            return 0.0
        return 1.0 - self.completed / self.launched_rows


class ServingEngine:
    """Continuous-batching inference over one trained scheme state.

    scheme/state/cfg — a registered Scheme, its trained state pytree, and
    the experiment config.  topology (None = the implicit star) may carry
    LinkModels; any link model — or an explicit `deadline_ms` — switches
    serving onto per-request fuse-what-arrived masks.  `wire` is the hop
    encoding AND the measured-bytes convention.  `buckets` overrides the
    scheme's grid (a serial baseline is `buckets=(1,)`).

    Thread model: `submit` is called from any thread; one scheduler thread
    (started by `start()` / the context manager) runs the collect -> pad ->
    launch -> complete loop.  `stop()` drains everything queued before
    joining.  The engine also works fully synchronously: `serve()` submits
    a block and waits, and `step()` runs one scheduler iteration inline —
    tests use the inline mode for determinism.  A scheduler-thread
    exception fails every pending Future and re-raises on the next
    `submit` / `stop` / `__exit__` (mirroring the data/prefetch.py
    producer-exception contract) — it never strands a blocked submitter.

    `transport=` (a repro/transport.NetworkTransport over the same
    topology) moves fault semantics OFF the jitted graph: each submitted
    request rides the transport's retrying channels and its delivery
    outcome (on-time / late / lost per view) becomes the explicit fusion
    mask — the engine then meters through the transport's offered /
    delivered ledgers.  `speculative=True` adds speculative fusion: a
    request whose views straggled past the deadline is answered by a
    LATER fusion that patches the stragglers in (`ServedRequest.served_by
    == "patched"`), instead of dropping them.
    """

    def __init__(self, scheme, state, cfg, *, topology=None,
                 wire: str = "dense", buckets: Sequence[int] = None,
                 deadline_ms: Optional[float] = None, seed: int = 0,
                 meter: Optional[bandwidth.BandwidthMeter] = None,
                 transport=None, speculative: bool = False,
                 max_queue: Optional[int] = None):
        self.scheme, self.state, self.cfg = scheme, state, cfg
        self.topology = topology
        self.topo = topology_lib.resolve(topology, cfg)
        self.wire = wire
        self.deadline_ms = deadline_ms
        self.buckets = batching.validate_buckets(
            buckets if buckets is not None else scheme.serve_buckets)
        # any link model (or an explicit deadline) switches serving onto
        # per-request delivery masks; a bare topology stays on the plain
        # predict path — bit-identical to scheme.predict
        self.faulty = (linkfault.has_link_models(self.topo)
                       or deadline_ms is not None)
        self.transport = transport
        self.speculative = bool(speculative)
        # bounded per-node queues: None = unbounded (the historical
        # behaviour); an int sheds at admission once any node's queue —
        # plus transport submissions still in flight — reaches the bound,
        # resolving the Future with a typed `Rejected` instead of growing
        # deques without limit.  Overload then degrades (shed counter,
        # caller-visible) instead of OOMing.
        self.max_queue = max_queue
        self._reserved = 0           # admitted, riding the transport,
                                     # not yet enqueued
        self._draining = False
        if speculative and transport is None:
            raise ValueError("speculative fusion needs a transport= — only "
                             "a transport distinguishes LATE views (worth "
                             "patching) from LOST ones")
        self._key = jax.random.PRNGKey(seed)
        self._queues: Dict[str, collections.deque] = {
            name: collections.deque() for name in self.topo.view_nodes()}
        self._futures: Dict[int, Future] = {}
        self._submit_t: Dict[int, float] = {}
        self._reports: Dict[int, object] = {}    # rid -> RequestReport
        self._patches: collections.deque = collections.deque()
        self._next_rid = 0
        self._work = threading.Condition()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # one jitted predict per bucket; the list inside each closure is
        # appended to at TRACE time only, so trace_counts[b] is the number
        # of compilations bucket b ever paid (the no-retracing contract)
        self.trace_counts: Dict[int, int] = {b: 0 for b in self.buckets}
        if transport is None:
            self._predict = {b: self._make_bucket_predict(b)
                             for b in self.buckets}
        else:
            self._predict = {b: self._make_bucket_predict_masked(b)
                             for b in self.buckets}
        # in transport mode the transport's meter IS the serving ledger
        # (offered accrues per attempt at transmit time, delivered per
        # consumed fusion via credit_delivered)
        if transport is not None and meter is None:
            self.meter = transport.meter
        else:
            self.meter = bandwidth.BandwidthMeter() if meter is None \
                else meter
        self._edge_bits = metering.request_edge_bits(self.topo, cfg)
        self._edge_nbytes = metering.request_edge_wire_bytes(
            self.topo, cfg, wire=wire)
        self.stats = ServeStats()

    # -- the bucketed predict ---------------------------------------------

    def _make_bucket_predict(self, bucket: int):
        scheme, cfg = self.scheme, self.cfg
        topo_arg, topo = self.topology, self.topo
        wire, deadline, faulty = self.wire, self.deadline_ms, self.faulty
        counts = self.trace_counts

        def fn(state, views, rids, key):
            counts[bucket] += 1          # trace-time side effect only
            if faulty:
                delivery = linkfault.request_delivery_mask(
                    key, topo, cfg, rids, deadline=deadline)
                probs = scheme.predict_batched(
                    state, views, delivery=delivery, topology=topo_arg,
                    cfg=cfg, wire=wire)
            else:
                # clean network: no masks at all — the plain predict graph,
                # bit-identical to scheme.predict on the same rows
                delivery = jnp.ones((topo.num_views(), bucket), bool)
                probs = scheme.predict_batched(
                    state, views, topology=topo_arg, cfg=cfg, wire=wire)
            return probs, delivery
        return jax.jit(fn)

    def _make_bucket_predict_masked(self, bucket: int):
        """The transport-mode variant: the delivery mask is an EXPLICIT
        argument (the transport's measured outcome), not an in-graph
        draw — same one-compile-per-bucket contract."""
        scheme, cfg = self.scheme, self.cfg
        topo_arg, wire = self.topology, self.wire
        counts = self.trace_counts

        def fn(state, views, delivery):
            counts[bucket] += 1          # trace-time side effect only
            return scheme.predict_batched(
                state, views, delivery=delivery, topology=topo_arg,
                cfg=cfg, wire=wire)
        return jax.jit(fn)

    def warmup(self) -> None:
        """Pay every bucket's compile up front (latency measurements then
        never include a trace)."""
        J = self.topo.num_views()
        H, W, C = self.cfg.image_shape
        for b in self.buckets:
            views = jnp.zeros((J, b, H, W, C), jnp.float32)
            if self.transport is not None:
                out = self._predict[b](self.state, views,
                                       jnp.ones((J, b), bool))
            else:
                rids = jnp.zeros((b,), jnp.int32)
                out, _ = self._predict[b](self.state, views, rids, self._key)
            out.block_until_ready()

    # -- scheduler-failure propagation ------------------------------------

    def _check_error(self) -> None:
        if self._error is not None:
            raise RuntimeError(
                "serving engine scheduler failed; no further requests will "
                "be served") from self._error

    def _fail_pending(self, exc: BaseException) -> None:
        """Scheduler died: record the error, fail EVERY pending Future
        (blocked waiters wake with the real exception instead of hanging),
        drop the queues."""
        with self._work:
            self._error = exc
            for fut in self._futures.values():
                if not fut.done():
                    fut.set_exception(exc)
            self._futures.clear()
            self._submit_t.clear()
            self._reports.clear()
            self._patches.clear()
            for q in self._queues.values():
                q.clear()
            self._work.notify_all()

    # -- request intake ----------------------------------------------------

    def submit(self, views) -> Tuple[int, Future]:
        """Enqueue one request's (J, H, W, C) views — one fragment per
        measure/relay node queue, atomically, so the per-node queues always
        pop aligned.  Returns (request id, Future resolving to a
        ServedRequest).

        With a transport, the fragments first RIDE it: the request's id is
        the transport tick, its delivery report (per-view on-time / late /
        lost after retries, breakers and chaos) is recorded for the
        scheduler, and the channels genuinely carry the fragment bytes.

        With `max_queue=`, a request that would push any per-node queue
        past the bound is SHED: its Future resolves immediately to a
        `Rejected` (it never rides the transport, never launches)."""
        self._check_error()
        self._check_shutdown()
        views = np.asarray(views)
        if views.shape[0] != self.topo.num_views():
            raise ValueError(
                f"request has {views.shape[0]} views; topology "
                f"{self.topo.describe()} expects {self.topo.num_views()}")
        fut: Future = Future()
        if self.transport is None:
            with self._work:
                self._check_shutdown()
                rid, admitted = self._admit_locked(fut)
                if admitted:
                    for j, name in enumerate(self.topo.view_nodes()):
                        self._queues[name].append((rid, views[j]))
                    self._futures[rid] = fut
                    self._submit_t[rid] = time.perf_counter()
                    self._work.notify()
            return rid, fut
        with self._work:
            self._check_shutdown()
            rid, admitted = self._admit_locked(fut)
            if admitted:
                self._reserved += 1
        if not admitted:
            return rid, fut
        # the channel walk happens OUTSIDE the scheduler lock (the
        # transport serialises itself); the enqueue below is atomic, so
        # the per-node queues still pop aligned
        try:
            report = self.transport.send_request(
                rid, views, deadline_ms=self.deadline_ms)
        finally:
            with self._work:
                self._reserved -= 1
        with self._work:
            self._check_error()
            self._futures[rid] = fut
            self._submit_t[rid] = time.perf_counter()
            self._reports[rid] = report
            for j, name in enumerate(self.topo.view_nodes()):
                self._queues[name].append((rid, views[j]))
            self._work.notify()
        return rid, fut

    def _admit_locked(self, fut: Future) -> Tuple[int, bool]:
        """(caller holds _work) Allocate a rid; shed when the queues are at
        the admission bound."""
        rid = self._next_rid
        self._next_rid += 1
        if self.max_queue is not None:
            depth = max((len(q) for q in self._queues.values()),
                        default=0) + self._reserved
            if depth >= self.max_queue:
                self.stats.shed += 1
                fut.set_result(Rejected(
                    rid=rid, t_done=time.perf_counter(),
                    reason=f"queue depth {depth} at max_queue="
                           f"{self.max_queue}"))
                return rid, False
        return rid, True

    def _check_shutdown(self) -> None:
        if self._draining:
            raise EngineShutdown(
                "serving engine is shutting down; request not accepted")

    def pending(self) -> int:
        with self._work:
            return len(self._futures)

    # -- the scheduler -----------------------------------------------------

    def _collect(self):
        """Pop the oldest <= max-bucket requests off every node queue
        (caller holds the lock).  Returns ((n,) rids, (J, n, ...) views)
        or None when idle."""
        names = self.topo.view_nodes()
        m = min(len(self._queues[nm]) for nm in names)
        m = min(m, self.buckets[-1])
        if m == 0:
            return None
        rids, frags = None, []
        for nm in names:
            row = [self._queues[nm].popleft() for _ in range(m)]
            got = [r for r, _ in row]
            if rids is None:
                rids = got
            # submit() appends to every queue under the lock, so the
            # aligned-prefix invariant cannot break
            assert got == rids, (got, rids)
            frags.append(np.stack([f for _, f in row]))
        return np.asarray(rids, np.int32), np.stack(frags)

    def _collect_transport(self):
        """Transport-mode collect (caller holds the lock): pending PATCH
        rows first (stragglers whose views have now arrived — appended by
        the previous launch), then the oldest aligned new requests, up to
        the largest bucket.  Returns a list of
        (rid, (J, ...) views, (J,) mask, resolve?, served_by) rows."""
        rows = []
        cap = self.buckets[-1]
        while self._patches and len(rows) < cap:
            rows.append(self._patches.popleft())
        names = self.topo.view_nodes()
        m = min(len(self._queues[nm]) for nm in names)
        m = min(m, cap - len(rows))
        for _ in range(m):
            popped = [self._queues[nm].popleft() for nm in names]
            rid = popped[0][0]
            assert all(r == rid for r, _ in popped), (rid, popped)
            views = np.stack([f for _, f in popped])
            report = self._reports[rid]
            if self.speculative and bool(report.stragglers.any()):
                # serve the at-deadline fusion speculatively, but answer
                # from the NEXT bucket once the stragglers are patched in
                rows.append((rid, views, report.on_time, False, "first"))
            else:
                rows.append((rid, views, report.on_time, True, "first"))
        return rows or None

    def _execute(self, rids: np.ndarray, views: np.ndarray) -> None:
        n = len(rids)
        bucket = batching.pick_bucket(n, self.buckets)
        pviews, prids = batching.pad_to_bucket(views, rids, bucket)
        probs, delivery = self._predict[bucket](
            self.state, jnp.asarray(pviews), jnp.asarray(prids), self._key)
        probs_np = np.asarray(probs)[:n]          # blocks until ready
        mask_np = np.asarray(delivery)[:, :n]
        t_done = time.perf_counter()
        metering.meter_served_batch(self.meter, self.topo, self.cfg,
                                    mask_np, edge_bits=self._edge_bits,
                                    edge_nbytes=self._edge_nbytes)
        self.stats.launches += 1
        self.stats.launched_rows += bucket
        for i, rid in enumerate(rids):
            rid = int(rid)
            with self._work:
                fut = self._futures.pop(rid)
                t_sub = self._submit_t.pop(rid)
            lat = (t_done - t_sub) * 1e3
            fused = int(mask_np[:, i].sum())
            self.stats.completed += 1
            self.stats.latencies_ms.append(lat)
            self.stats.views_fused.append(fused)
            fut.set_result(ServedRequest(rid=rid, probs=probs_np[i],
                                         views_fused=fused, latency_ms=lat,
                                         t_done=t_done))

    def _execute_transport(self, rows) -> None:
        """Launch one transport-mode batch: explicit per-row masks, padded
        to the bucket grid (padding repeats the last row with an all-True
        mask — row-inert either way).  Resolving rows complete their
        Future and credit the delivered ledger; non-resolving rows
        (speculative stragglers) re-enter as patch rows carrying their
        EVENTUAL mask."""
        n = len(rows)
        bucket = batching.pick_bucket(n, self.buckets)
        views = np.stack([v for _, v, _, _, _ in rows], axis=1)
        mask = np.stack([m for _, _, m, _, _ in rows], axis=1)
        pad = bucket - n
        if pad:
            views = np.concatenate(
                [views, np.repeat(views[:, -1:], pad, axis=1)], axis=1)
            mask = np.concatenate(
                [mask, np.ones((mask.shape[0], pad), bool)], axis=1)
        probs = self._predict[bucket](self.state, jnp.asarray(views),
                                      jnp.asarray(mask))
        probs_np = np.asarray(probs)[:n]          # blocks until ready
        t_done = time.perf_counter()
        self.stats.launches += 1
        self.stats.launched_rows += bucket
        for i, (rid, vrow, mrow, resolve, served_by) in enumerate(rows):
            rid = int(rid)
            if not resolve:
                report = self._reports[rid]
                self._patches.append(
                    (rid, vrow, np.asarray(report.eventual, bool), True,
                     "patched"))
                continue
            with self._work:
                fut = self._futures.pop(rid)
                t_sub = self._submit_t.pop(rid)
                report = self._reports.pop(rid)
            self.transport.credit_delivered(mrow)
            lat = (t_done - t_sub) * 1e3
            fused = int(np.asarray(mrow).sum())
            recovered = int(report.stragglers.sum()) \
                if served_by == "patched" else 0
            self.stats.completed += 1
            self.stats.latencies_ms.append(lat)
            self.stats.views_fused.append(fused)
            if served_by == "patched":
                self.stats.patched += 1
                self.stats.views_recovered += recovered
            fut.set_result(ServedRequest(
                rid=rid, probs=probs_np[i], views_fused=fused,
                latency_ms=lat, t_done=t_done, served_by=served_by,
                views_recovered=recovered))

    def _collect_any(self):
        return self._collect_transport() if self.transport is not None \
            else self._collect()

    def _execute_any(self, batch) -> None:
        if self.transport is not None:
            self._execute_transport(batch)
        else:
            self._execute(*batch)

    def step(self, timeout: float = 0.0) -> int:
        """One scheduler iteration inline: collect -> launch -> complete.
        Returns the number of requests completed (0 when idle past
        `timeout`)."""
        self._check_error()
        with self._work:
            batch = self._collect_any()
            if batch is None and timeout > 0:
                self._work.wait(timeout)
                batch = self._collect_any()
        if batch is None:
            return 0
        self._execute_any(batch)
        return len(batch) if self.transport is not None else len(batch[0])

    def _loop(self) -> None:
        try:
            while True:
                with self._work:
                    batch = self._collect_any()
                    if batch is None:
                        if self._stop.is_set():
                            return                 # queues drained: done
                        self._work.wait(timeout=0.05)
                        continue
                self._execute_any(batch)
        except BaseException as exc:               # noqa: BLE001
            # a dead scheduler must not strand blocked submitters: fail
            # every pending Future now, re-raise on the next submit/stop
            self._fail_pending(exc)

    def start(self) -> "ServingEngine":
        if self._thread is not None:
            raise RuntimeError("engine already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="inl-serving-engine",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 60.0, reraise: bool = True) -> None:
        """Drain the queues, complete everything in flight, join.  If the
        scheduler thread died, its exception re-raises here (pending
        Futures were already failed with it)."""
        if self._thread is None:
            if reraise:
                self._check_error()
            return
        self._stop.set()
        with self._work:
            self._work.notify()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            raise RuntimeError("serving engine failed to drain and stop")
        self._thread = None
        if reraise:
            self._check_error()

    def shutdown(self, drain_timeout: float = 30.0) -> None:
        """GRACEFUL shutdown (the SIGTERM/Ctrl-C path `launch/serve.py`
        installs): stop admitting — further `submit` calls raise
        `EngineShutdown` — then drain what is already queued for up to
        `drain_timeout` seconds, and fail whatever remains pending with
        `EngineShutdown` so no waiter ever hangs on a dead engine.

        Idempotent, and safe to call from a signal handler while the
        scheduler thread runs (the inline-drain branch is for engines that
        were never start()ed — call that one from a normal frame)."""
        with self._work:
            self._draining = True
            self._work.notify_all()
        if self._thread is not None:
            self._stop.set()
            with self._work:
                self._work.notify()
            self._thread.join(timeout=drain_timeout)
            if not self._thread.is_alive():
                self._thread = None
        elif self._error is None:
            deadline = time.perf_counter() + drain_timeout
            try:
                while self.pending() and time.perf_counter() < deadline:
                    if self.step() == 0:
                        break
            except RuntimeError:
                pass                      # a dying drain still fails pending
        exc = EngineShutdown(
            "serving engine shut down before this request completed")
        with self._work:
            for fut in self._futures.values():
                if not fut.done():
                    fut.set_exception(exc)
            self._futures.clear()
            self._submit_t.clear()
            self._reports.clear()
            self._patches.clear()
            for q in self._queues.values():
                q.clear()
            self._work.notify_all()

    def __enter__(self) -> "ServingEngine":
        return self.start()

    def __exit__(self, exc_type, *exc) -> None:
        # don't mask an in-flight body exception with the scheduler's
        self.stop(reraise=exc_type is None)

    # -- synchronous conveniences -----------------------------------------

    def serve(self, views, timeout: float = 120.0):
        """Submit a (J, n, ...) block and wait for all n answers.

        Returns ((n, C) probabilities, list of ServedRequest in submit
        order).  Runs through the live scheduler thread when started, else
        inline."""
        n = views.shape[1]
        futs = [self.submit(views[:, i])[1] for i in range(n)]
        if self._thread is None:
            while any(not f.done() for f in futs):
                if self.step() == 0:
                    break
        results = [f.result(timeout=timeout) for f in futs]
        return np.stack([r.probs for r in results]), results
