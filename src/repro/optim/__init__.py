"""Optimizers (pure pytree transforms, optax-style but self-contained):
SGD(+momentum), Adam, AdamW with decoupled weight decay, global-norm clipping,
and LR schedules.  Mixed precision: if params are low-precision (bf16), the
optimizer keeps an fp32 master copy in its state and casts on update.

ZeRO-1: optimizer state tensors inherit the *sharded* layout assigned by the
launcher via shard_optimizer_state() — m/v/master are sharded over the
('pod','data') axes regardless of param layout.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class Optimizer(NamedTuple):
    init: Callable
    update: Callable      # (grads, state, params, step) -> (new_params, new_state)


def _tree_zeros_like(tree, dtype=jnp.float32):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype), tree)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                           final_frac: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else \
            jnp.asarray(step, jnp.float32)
        warm = step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(np.pi * prog))
        return peak_lr * jnp.where(step < warmup_steps, warm, cos)
    return sched


def linear_schedule(peak_lr: float, warmup_steps: int, total_steps: int):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / max(warmup_steps, 1)
        decay = jnp.clip(1.0 - (step - warmup_steps)
                         / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        return peak_lr * jnp.where(step < warmup_steps, warm, decay)
    return sched


def _as_schedule(lr):
    return lr if callable(lr) else constant_schedule(lr)


# ---------------------------------------------------------------------------
# SGD
# ---------------------------------------------------------------------------

def sgd(lr, momentum: float = 0.0, clip_norm: Optional[float] = None):
    sched = _as_schedule(lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mom"] = _tree_zeros_like(params)
        return state

    def update(grads, state, params, step=None):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state["step"]
        lr_t = sched(step)
        new_state = {"step": step + 1}
        if momentum:
            mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mom"], grads)
            new_state["mom"] = mom
            upd = mom
        else:
            upd = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - lr_t * u).astype(p.dtype),
            params, upd)
        return new_params, new_state

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adam / AdamW (with fp32 master weights when params are low-precision)
# ---------------------------------------------------------------------------

def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, clip_norm: Optional[float] = 1.0,
          keep_master: bool = True):
    sched = _as_schedule(lr)

    def _needs_master(params):
        return keep_master and any(
            x.dtype != jnp.float32 for x in jax.tree.leaves(params))

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32),
                 "m": _tree_zeros_like(params),
                 "v": _tree_zeros_like(params)}
        if _needs_master(params):
            state["master"] = jax.tree.map(
                lambda x: x.astype(jnp.float32), params)
        return state

    def update(grads, state, params, step=None):
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr_t = sched(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) *
                         g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        base = state.get("master", params)

        def upd(p, m_, v_):
            mh = m_ / bc1
            vh = v_ / bc2
            step_ = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return p.astype(jnp.float32) - lr_t * step_

        new_master = jax.tree.map(upd, base, m, v)
        new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype),
                                  new_master, params)
        new_state = {"step": step, "m": m, "v": v}
        if "master" in state:
            new_state["master"] = new_master
        return new_params, new_state

    return Optimizer(init, update)


def adam(lr, **kw):
    return adamw(lr, weight_decay=0.0, **kw)
