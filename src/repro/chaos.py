"""Deterministic chaos: scripted process/link faults + the crash-resume rig.

The transport layer (repro/transport/) consults a `ChaosSchedule` on every
transmission: the schedule answers three pure queries over TICK time (a
training round index, or a serving request id) —

    edge_down(key, tick)     the edge drops every attempt in the window
    slow_factor(key, tick)   latency multiplier (a 10x-slowed client)
    node_dead(name, tick)    the node is killed: it sends nothing, and
                             every route THROUGH it fails

Windows are half-open [start, stop) in ticks; `stop=None` means forever.
Because the queries are pure functions of (schedule, tick) and every
transport fault draw is already counter-seeded, a chaos run replays
bit-identically — the property every assertion in benchmarks/chaos_bench.py
stands on.  `ChaosSchedule.seeded` scripts a reproducible random schedule
from an integer seed; the builder methods (`kill_node`, `down_edge`,
`flap_edge`, `slow_edge`) script exact scenarios.

The second half of this module is the CRASH-RESUME rig the CI leg runs:
`crash_resume_check` trains `launch/train.py` in a subprocess, SIGKILLs it
mid-run at a scripted step, reruns with `--resume`, and asserts the resumed
trajectory (the per-group metric lines AND the final checkpoint arrays)
matches an uninterrupted golden run bit for bit.

    PYTHONPATH=src python -m repro.chaos --arch llama3.2-1b --steps 12 \
        --scan-steps 2 --kill-after-step 6
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
from dataclasses import dataclass, replace
from typing import Optional, Sequence, Tuple

import numpy as np

EVENT_KINDS = ("edge_down", "edge_flap", "edge_slow", "node_kill",
               "node_freeze")


@dataclass(frozen=True)
class ChaosEvent:
    """One scripted fault window over tick time, [start, stop)."""
    kind: str                     # one of EVENT_KINDS
    target: str                   # edge key ("m0->fuse") or node name
    start: int = 0
    stop: Optional[int] = None    # None = never recovers
    factor: float = 1.0           # edge_slow: latency multiplier
    period: int = 2               # edge_flap: down `duty` of every `period`
    duty: int = 1

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown chaos event kind {self.kind!r}; "
                             f"one of {EVENT_KINDS}")
        if self.stop is not None and self.stop <= self.start:
            raise ValueError(f"empty window [{self.start}, {self.stop})")
        if self.kind == "edge_flap" and not 0 < self.duty <= self.period:
            raise ValueError(f"flap needs 0 < duty <= period, got "
                             f"duty={self.duty} period={self.period}")

    def active(self, tick: int) -> bool:
        return tick >= self.start and (self.stop is None or tick < self.stop)


@dataclass(frozen=True)
class ChaosSchedule:
    """An immutable script of fault windows; builders return new schedules
    so scenarios compose fluently:

        ChaosSchedule().kill_node("m1", at=4, duration=3) \\
                       .flap_edge("m0->fuse", start=2, stop=10, period=2)
    """
    events: Tuple[ChaosEvent, ...] = ()

    # -- the three transport queries --------------------------------------

    def edge_down(self, key: str, tick: int) -> bool:
        for e in self.events:
            if e.target != key or not e.active(tick):
                continue
            if e.kind == "edge_down":
                return True
            if e.kind == "edge_flap" and \
                    (tick - e.start) % e.period < e.duty:
                return True
        return False

    def slow_factor(self, key: str, tick: int) -> float:
        f = 1.0
        for e in self.events:
            if e.kind == "edge_slow" and e.target == key and e.active(tick):
                f *= e.factor
        return f

    def node_dead(self, name: str, tick: int) -> bool:
        return any(e.kind == "node_kill" and e.target == name
                   and e.active(tick) for e in self.events)

    def node_frozen(self, name: str, tick: int) -> bool:
        """The node is SIGSTOPped: its process exists but answers nothing.
        Unlike `node_dead` the transport does NOT consult this directly —
        a frozen worker is discovered the hard way (probe and data-path
        timeouts walking the membership ladder), which is the point."""
        return any(e.kind == "node_freeze" and e.target == name
                   and e.active(tick) for e in self.events)

    # -- builders ----------------------------------------------------------

    def _with(self, ev: ChaosEvent) -> "ChaosSchedule":
        return ChaosSchedule(self.events + (ev,))

    def kill_node(self, name: str, at: int,
                  duration: Optional[int] = None) -> "ChaosSchedule":
        """SIGKILL node `name` at tick `at`; it rejoins after `duration`
        ticks (None: never — a permanent client leave)."""
        return self._with(ChaosEvent(
            "node_kill", name, start=at,
            stop=None if duration is None else at + duration))

    def freeze_node(self, name: str, at: int,
                    duration: Optional[int] = None) -> "ChaosSchedule":
        """SIGSTOP node `name` at tick `at`, SIGCONT after `duration` ticks
        (None: never).  Realised by the cluster Supervisor on real worker
        processes; in-process transports ignore freeze windows."""
        return self._with(ChaosEvent(
            "node_freeze", name, start=at,
            stop=None if duration is None else at + duration))

    def down_edge(self, key: str, at: int, duration: int = 1):
        return self._with(ChaosEvent("edge_down", key, start=at,
                                     stop=at + duration))

    def flap_edge(self, key: str, start: int, stop: int, period: int = 2,
                  duty: int = 1):
        """The edge goes down for `duty` of every `period` ticks in
        [start, stop) — the breaker-exercising pattern."""
        return self._with(ChaosEvent("edge_flap", key, start=start,
                                     stop=stop, period=period, duty=duty))

    def slow_edge(self, key: str, start: int, stop: Optional[int],
                  factor: float = 10.0):
        """Multiply the edge's latency by `factor` — the 10x-slowed client
        whose payloads turn into deadline stragglers."""
        return self._with(ChaosEvent("edge_slow", key, start=start,
                                     stop=stop, factor=factor))

    @classmethod
    def seeded(cls, seed: int, *, edge_keys: Sequence[str] = (),
               nodes: Sequence[str] = (), ticks: int = 64,
               p_edge_down: float = 0.1, p_node_kill: float = 0.02,
               max_outage: int = 4) -> "ChaosSchedule":
        """A reproducible random schedule: per tick, each edge goes down
        with `p_edge_down` and each node dies with `p_node_kill`, for an
        outage of 1..max_outage ticks — same seed, same script."""
        rng = np.random.default_rng((seed, 0xC4A05))
        sched = cls()
        for key in edge_keys:
            for t in range(ticks):
                if rng.random() < p_edge_down:
                    sched = sched.down_edge(
                        key, t, int(rng.integers(1, max_outage + 1)))
        for name in nodes:
            for t in range(ticks):
                if rng.random() < p_node_kill:
                    sched = sched.kill_node(
                        name, t, int(rng.integers(1, max_outage + 1)))
        return sched

    def describe(self) -> str:
        if not self.events:
            return "ChaosSchedule(empty)"
        spans = [f"{e.kind}:{e.target}@[{e.start},"
                 f"{'inf' if e.stop is None else e.stop})"
                 for e in self.events]
        return f"ChaosSchedule({len(self.events)} events: " \
               f"{'; '.join(spans[:8])}{'...' if len(spans) > 8 else ''})"


# ---------------------------------------------------------------------------
# Process-kill drill: SIGKILL/SIGSTOP real supervised workers, assert masks
# ---------------------------------------------------------------------------

def cluster_drill(args) -> dict:
    """The `--procs` CI drill: a 3-process cluster under a scripted kill
    AND a scripted freeze, asserted at the mask level.

      * SIGKILL m1 for 3 ticks: its vote is lost for EXACTLY that window
        (the supervisor respawns it the first tick the schedule allows,
        incarnation bumped);
      * SIGSTOP m2 for 3 ticks: the process survives but answers nothing —
        data-path timeouts cost its vote, the membership ladder walks
        up -> suspect -> down, and the first pong after SIGCONT rejoins
        the SAME incarnation (no respawn);
      * the whole story replays: a second cluster run over the same
        schedule produces identical masks.
    """
    from repro.cluster import Cluster
    from repro.configs.paper_inl import PaperExperimentConfig
    from repro.transport import NO_RETRY

    cfg = PaperExperimentConfig(
        num_clients=3, noise_stds=(0.4, 1.0, 2.0), conv_channels=(4,),
        d_bottleneck=8, dense_units=(32,), image_shape=(16, 16, 3),
        dataset_size=128)
    kill = ("m1", 6, 3)
    freeze = ("m2", 12, 3)
    ticks = 20
    sched = (ChaosSchedule()
             .kill_node(kill[0], at=kill[1], duration=kill[2])
             .freeze_node(freeze[0], at=freeze[1], duration=freeze[2]))

    def run():
        # NO_RETRY + no breaker keep the mask windows exact: one attempt
        # per edge per tick, no open-breaker tail after recovery
        with Cluster(cfg, seed=args.seed, chaos=sched, policy=NO_RETRY,
                     breaker=None) as cl:
            names = cl.topo.view_nodes()
            masks = [cl.transport.round_outcome(t, 32).mask.tolist()
                     for t in range(ticks)]
            return (names, masks, cl.supervisor.events(),
                    dict(cl.supervisor.membership().incarnations),
                    cl.supervisor.respawns)

    names, masks, events, incarnations, respawns = run()
    idx = {n: j for j, n in enumerate(names)}
    for t in range(ticks):
        for name, at, dur in (kill, freeze):
            want = not (at <= t < at + dur)
            assert masks[t][idx[name]] == want, \
                (f"tick {t}: {name} vote {masks[t][idx[name]]}, "
                 f"want {want}; masks={masks}")
        for name in names:
            if name not in (kill[0], freeze[0]):
                assert masks[t][idx[name]], f"healthy {name} lost tick {t}"
    assert incarnations[kill[0]] == 2, incarnations     # respawned once
    assert incarnations[freeze[0]] == 1, incarnations   # rejoined, same proc
    assert respawns == 1, respawns
    transitions = [ev[2] for ev in events if ev[1] == freeze[0]]
    assert "up->suspect" in transitions and "suspect->down" in transitions \
        and transitions[-1] == "down->up", transitions

    _, masks2, *_ = run()
    assert masks == masks2, "cluster drill did not replay identically"
    return {"nodes": list(names), "ticks": ticks,
            "kill": {"node": kill[0], "window": [kill[1], kill[1] + kill[2]]},
            "freeze": {"node": freeze[0],
                       "window": [freeze[1], freeze[1] + freeze[2]]},
            "respawns": respawns,
            "incarnations": incarnations,
            "membership_events": [list(ev) for ev in events],
            "replay_identical": True}


# ---------------------------------------------------------------------------
# Crash-resume rig: SIGKILL a real training process, resume, compare
# ---------------------------------------------------------------------------

def _train_argv(args, ckpt_dir: str, resume: bool):
    argv = [sys.executable, "-m", "repro.launch.train",
            "--arch", args.arch, "--smoke", "--scheme", args.scheme,
            "--steps", str(args.steps), "--batch", str(args.batch),
            "--seq", str(args.seq), "--scan-steps", str(args.scan_steps),
            "--seed", str(args.seed), "--prefetch", "1",
            "--ckpt-dir", ckpt_dir, "--ckpt-every", str(args.ckpt_every)]
    if resume:
        argv.append("--resume")
    return argv


def _run_until_kill(argv, kill_after_step: Optional[int]):
    """Run the training subprocess, streaming its JSON metric lines; when
    `kill_after_step` is reached, SIGKILL the process mid-run (the crash
    under test — no atexit, no flush, no goodbye).  Returns (metric lines,
    killed?)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ["src", env.get("PYTHONPATH", "")] if p)
    proc = subprocess.Popen(argv, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, env=env)
    lines, killed = [], False
    assert proc.stdout is not None
    for line in proc.stdout:
        line = line.strip()
        if not line.startswith("{"):
            continue
        try:
            m = json.loads(line)
        except json.JSONDecodeError:
            continue
        if "step" in m:
            lines.append(m)
            if kill_after_step is not None and not killed \
                    and m["step"] >= kill_after_step:
                proc.send_signal(signal.SIGKILL)
                killed = True
                break
    proc.stdout.read()
    proc.wait()
    if not killed and proc.returncode != 0:
        raise RuntimeError(f"training run failed (rc={proc.returncode}); "
                           f"argv={argv}")
    return lines, killed


def _final_arrays(ckpt_dir: str):
    from repro import checkpoint
    step = checkpoint.latest_step(ckpt_dir)
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        return step, {k: data[k].copy() for k in data.files}


def crash_resume_check(args) -> dict:
    """The CI crash-resume assertion, end to end:

      1. GOLDEN: an uninterrupted run, metrics + final checkpoint kept;
      2. CRASH:  the same run SIGKILLed once step `kill_after_step` prints;
      3. RESUME: rerun with --resume — it restores the last checkpoint,
         fast-forwards the data/rng streams, finishes the schedule;
      4. assert every post-resume metric line equals the golden line for
         the same step, and the final checkpoints match BIT FOR BIT.

    Returns the comparison record (chaos_bench.py embeds it)."""
    golden_dir = os.path.join(args.workdir, "golden")
    crash_dir = os.path.join(args.workdir, "crash")
    golden, killed = _run_until_kill(
        _train_argv(args, golden_dir, resume=False), None)
    assert golden, "golden run produced no metric lines"

    partial, killed = _run_until_kill(
        _train_argv(args, crash_dir, resume=False), args.kill_after_step)
    assert killed, (f"run finished before step {args.kill_after_step}; "
                    f"raise --steps or lower --kill-after-step")
    from repro import checkpoint
    resume_from = checkpoint.latest_step(crash_dir)
    assert resume_from is not None, \
        "crash left no checkpoint; lower --ckpt-every"

    resumed, _ = _run_until_kill(_train_argv(args, crash_dir, resume=True),
                                 None)
    assert resumed, "resumed run produced no metric lines"

    by_step = {m["step"]: m for m in golden}
    mismatches = []
    for m in resumed:
        g = by_step.get(m["step"])
        if g is None or any(g.get(k) != v for k, v in m.items()
                            if k != "wall_s"):
            mismatches.append((m, g))
    assert not mismatches, \
        f"resumed trajectory diverged from golden: {mismatches[:3]}"

    gstep, garr = _final_arrays(golden_dir)
    rstep, rarr = _final_arrays(crash_dir)
    assert gstep == rstep, (gstep, rstep)
    assert set(garr) == set(rarr)
    diff = [k for k in garr if not np.array_equal(garr[k], rarr[k])]
    assert not diff, f"final checkpoints differ bitwise on {diff[:5]}"
    return {"resume_from_step": resume_from,
            "final_step": gstep,
            "metric_lines_compared": len(resumed),
            "tensors_compared": len(garr),
            "bitwise_identical": True}


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="crash-resume chaos check over launch/train.py")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--scheme", default="inl",
                    choices=["standard", "inl"])
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--scan-steps", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=2)
    ap.add_argument("--kill-after-step", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workdir", default="chaos_workdir")
    ap.add_argument("--procs", action="store_true",
                    help="run the multi-process cluster drill (real worker "
                         "SIGKILL/SIGSTOP under a scripted schedule) "
                         "instead of the training crash-resume check")
    args = ap.parse_args(argv)
    if args.procs:
        record = cluster_drill(args)
        print(json.dumps({"cluster_drill": record}, indent=2))
        return record
    os.makedirs(args.workdir, exist_ok=True)
    record = crash_resume_check(args)
    print(json.dumps({"crash_resume": record}, indent=2))
    return record


if __name__ == "__main__":
    main()
