"""Stage 1 of the search: price every point from the closed-form ledgers —
no training, no compilation of a round function — then prune with rules
that are SOUND, not heuristic: a pruned point's trained result is provably
(bit-identically) equal to a surviving point's at equal-or-higher cost, so
pruning can never discard a frontier config.  `frontier_bench.py --smoke`
verifies exactly that by exhaustively training the pruned points too.

Rule 1 — wire equivalence.  "packed" is a lossless re-encoding of the
same quantized values ("dense" at the same link width): trajectories are
bit-identical (pinned by tests/test_wireformat.py) and the closed-form
charge only depends on the width, so of {dense, packed} at one
(scheme, topology, link_bits, cut_depth) only one representative trains —
the accuracy axis AND the accounted-Gbit axis are shared.  NOT
"packed_duplex": its backward path genuinely quantizes the error chunks,
a different trajectory.

Rule 2 — star dominance.  A constructor graph (edge-homogeneous, widths
inherited from cfg) at link_bits=32 executes every relay hop as the exact
identity (the uniform quantizer is idempotent, fp32 storage round-trips),
so training and inference are bit-identical to the star on the same
views — while the multi-hop ledger charges every edge for its full
payload, strictly more than the star's J single-latent links.  When the
star sibling is in the grid, the non-star point is weakly dominated by
construction and skips training.

Everything else trains: narrow links on a graph are NOT pruned (hops
re-quantize at inference — accuracy genuinely moves), and no accuracy
estimate is ever used to prune (the ledgers know bits, not accuracy).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax

from repro.core import schemes
from repro.core.schemes import runner as runner_lib
from repro.search.space import ConfigPoint

CANDIDATE = "candidate"
PRUNED_WIRE = "pruned:wire-equivalent"
PRUNED_STAR = "pruned:star-dominated"


@dataclass
class PricedPoint:
    point: ConfigPoint
    cfg: object
    topology: object              # resolved Topology or None (default star)
    rounds_per_epoch: int
    round_bits: float             # closed-form §III-C charge, one round
    round_nbytes: float           # measured wire bytes, one round
    overhead_bits: float          # once-per-epoch charges (SL hand-offs)
    overhead_nbytes: float
    status: str = CANDIDATE
    stand_in: Optional[str] = None   # key of the point that trains instead

    @property
    def key(self) -> str:
        return self.point.key

    def epoch_bits(self) -> float:
        return self.rounds_per_epoch * self.round_bits + self.overhead_bits

    def epoch_nbytes(self) -> float:
        return self.rounds_per_epoch * self.round_nbytes \
            + self.overhead_nbytes

    def total_gbits(self, epochs: int) -> float:
        return epochs * self.epoch_bits() / 1e9

    def record(self) -> dict:
        return {"key": self.key, "scheme": self.point.scheme,
                "topology": self.point.topology,
                "link_bits": self.point.link_bits, "wire": self.point.wire,
                "cut_depth": self.point.cut_depth, "status": self.status,
                "stand_in": self.stand_in,
                "rounds_per_epoch": self.rounds_per_epoch,
                "epoch_bits": self.epoch_bits(),
                "epoch_wire_bytes": self.epoch_nbytes()}


def price_point(point: ConfigPoint, base_cfg, *, batch_size: int,
                train_n: int) -> PricedPoint:
    """Exact per-epoch pricing from the scheme's own ledgers — the same
    closed forms the runner's BandwidthMeter charges, via the same
    `rounds_per_epoch` rule, so priced == metered bit for bit."""
    cfg, topo = point.resolve(base_cfg)
    scheme = schemes.get(point.scheme)
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    return PricedPoint(
        point=point, cfg=cfg, topology=topo,
        rounds_per_epoch=runner_lib.rounds_per_epoch(scheme, cfg, train_n,
                                                     batch_size),
        round_bits=scheme.bits_per_round(cfg, state, batch_size,
                                         topology=topo),
        round_nbytes=scheme.wire_bytes_per_round(cfg, state, batch_size,
                                                 wire=point.wire,
                                                 topology=topo),
        overhead_bits=scheme.epoch_overhead_bits(cfg, state),
        overhead_nbytes=scheme.epoch_overhead_wire_bytes(cfg, state))


def _apply_wire_equivalence(priced: list) -> None:
    groups: dict = {}
    for pp in priced:
        p = pp.point
        if p.wire in ("dense", "packed"):
            groups.setdefault(
                (p.scheme, p.topology, p.link_bits, p.cut_depth),
                []).append(pp)
    for members in groups.values():
        if len(members) < 2:
            continue
        rep = next((m for m in members if m.point.wire == "dense"),
                   members[0])
        for m in members:
            if m is rep:
                continue
            if m.round_bits != rep.round_bits:     # closed forms must agree
                raise AssertionError(
                    f"wire-equivalence violated: {m.key} charges "
                    f"{m.round_bits} vs {rep.key} {rep.round_bits}")
            m.status, m.stand_in = PRUNED_WIRE, rep.key


def _apply_star_dominance(priced: list) -> None:
    by_key = {pp.key: pp for pp in priced}
    for pp in priced:
        p = pp.point
        if pp.status != CANDIDATE or p.link_bits != 32 \
                or p.topology.startswith("star("):
            continue
        star_key = ConfigPoint(p.scheme, f"star({pp.cfg.num_clients})",
                               p.link_bits, p.wire, p.cut_depth).key
        sibling = by_key.get(star_key)
        if sibling is None or sibling.status != CANDIDATE:
            continue                     # nothing to stand in — train it
        if pp.round_bits < sibling.round_bits:
            raise AssertionError(
                f"star dominance violated: {pp.key} charges {pp.round_bits}"
                f" < star sibling {sibling.round_bits}")
        pp.status, pp.stand_in = PRUNED_STAR, star_key


def price(points, base_cfg, *, batch_size: int, train_n: int) -> list:
    """Price every point, then mark the provably-redundant ones."""
    priced = [price_point(p, base_cfg, batch_size=batch_size,
                          train_n=train_n) for p in points]
    _apply_wire_equivalence(priced)
    _apply_star_dominance(priced)
    return priced
