"""Pareto extraction on the (accuracy up, accounted Gbits down) plane."""
from __future__ import annotations


def dominates(a, b, *, acc=lambda p: p.accuracy,
              cost=lambda p: p.gbits) -> bool:
    """a weakly better on both axes, strictly better on at least one."""
    return (acc(a) >= acc(b) and cost(a) <= cost(b)
            and (acc(a) > acc(b) or cost(a) < cost(b)))


def pareto_frontier(points, *, acc=lambda p: p.accuracy,
                    cost=lambda p: p.gbits) -> list:
    """Non-dominated subset, sorted by cost ascending.  Duplicates on both
    axes keep their first spelling (stable for the bench artifact).  A
    point ties onto the frontier only if nothing dominates it — equal
    (acc, cost) pairs are mutually non-dominating and both survive."""
    items = sorted(points, key=lambda p: (cost(p), -acc(p)))
    out = []
    best_acc = None
    for p in items:
        if best_acc is None or acc(p) > best_acc:
            out.append(p)
            best_acc = acc(p)
        elif acc(p) == best_acc and out and cost(out[-1]) == cost(p):
            out.append(p)            # exact tie with the incumbent
    return out


def best_under_budget(points, budget, *, acc=lambda p: p.accuracy,
                      cost=lambda p: p.gbits):
    """Highest accuracy reachable at cost <= budget; None if nothing
    fits."""
    feasible = [p for p in points if cost(p) <= budget]
    return max(feasible, key=acc) if feasible else None
