"""The search space: hashable configuration points and their validity.

A `ConfigPoint` is one placement decision — which scheme runs, how deep
the client-side cut sits, what graph the exchange routes over, how wide
and in which wire format the links run.  Points carry the topology as its
`core/topology.from_name` spec string so a whole space is hashable and
JSON-able; `resolve()` turns a point into the (cfg, topology) pair the
runner consumes, adapting `num_clients`/`noise_stds` to the graph's view
count (extra views cycle the paper's noise ladder).

`SearchSpace.points()` enumerates the VALID product only; the rules that
exclude a combination are structural, not heuristic:

  * packed wire formats need 1 <= link_bits <= 16 (uint32 codeword lanes);
  * FL and SL are star-only by construction (`topology.require_star` —
    weight broadcast / the single client->server boundary have no
    multi-hop reading);
  * FL moves fp32 weights whatever cfg.link_bits says, so only the
    (link_bits=32, wire="dense") spelling prices truthfully — narrower
    points would charge a quantized exchange the wire never implements;
  * SL is width-limited the same way: the paper's Table-I closed form
    (2pq + eta*N*J)*s charges the per-epoch weight hand-offs at the link
    width s, but the wire ships the fp32 client masters — only s=32
    makes the charge and the shipment the same number;
  * cut_depth parameterises the hybrid schemes only (splitfed/hybrid);
    for the pure schemes the knob does not exist.

`excluded()` returns the rejected combinations with their reasons, so the
bench artifact records what the grid did NOT cover.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

from repro.core import topology as topology_lib

HYBRID_SCHEMES = ("splitfed", "hybrid")
PACKED_WIRES = ("packed", "packed_duplex")


@dataclass(frozen=True)
class ConfigPoint:
    scheme: str
    topology: str                 # a from_name spec: "star(5)", "tree(2,2)"
    link_bits: int = 32
    wire: str = "dense"
    cut_depth: Optional[int] = None

    @property
    def key(self) -> str:
        depth = "full" if self.cut_depth is None else str(self.cut_depth)
        return (f"{self.scheme}/{self.topology}/q{self.link_bits}/"
                f"{self.wire}/d{depth}")

    def resolve(self, base_cfg):
        """(cfg, topology-or-None) for the runner: the base experiment
        re-shaped to this point.  None topology = the default star (the
        legacy bit-identical fast path)."""
        topo = topology_lib.from_name(self.topology)
        J = topo.num_views()
        noise = tuple(base_cfg.noise_stds[j % len(base_cfg.noise_stds)]
                      for j in range(J))
        fl_idx = tuple(j for j in getattr(base_cfg, "hybrid_fl_clients",
                                          (0,)) if j < J) or (0,)
        cfg = dataclasses.replace(
            base_cfg, num_clients=J, noise_stds=noise,
            link_bits=self.link_bits, cut_depth=self.cut_depth,
            hybrid_fl_clients=fl_idx, topology=None)
        return cfg, (None if topo.is_default_star() else topo)


@dataclass(frozen=True)
class SearchSpace:
    """A product grid.  Combine several spaces (e.g. a graph sweep for INL
    plus a cut-depth sweep for the hybrids) by concatenating `points()`."""
    schemes: Tuple[str, ...]
    topologies: Tuple[str, ...]
    link_bits: Tuple[int, ...] = (32,)
    wires: Tuple[str, ...] = ("dense",)
    cut_depths: Tuple[Optional[int], ...] = (None,)

    def _enumerate(self):
        for s in self.schemes:
            depths = self.cut_depths if s in HYBRID_SCHEMES else (None,)
            for t in self.topologies:
                for q in self.link_bits:
                    for w in self.wires:
                        for d in depths:
                            yield ConfigPoint(s, t, q, w, d)

    def _reject(self, p: ConfigPoint) -> Optional[str]:
        if p.wire in PACKED_WIRES and not 1 <= p.link_bits <= 16:
            return "packed wires need 1 <= link_bits <= 16"
        star_only = p.scheme in ("fl", "sl")
        if star_only and not p.topology.startswith("star("):
            return f"scheme {p.scheme} requires a star topology"
        if p.scheme == "fl" and (p.link_bits != 32 or p.wire != "dense"):
            return ("fl exchanges fp32 weights; only (q32, dense) prices "
                    "truthfully")
        if p.scheme == "sl" and p.link_bits != 32:
            return ("sl's Table-I form charges weight hand-offs at the "
                    "link width but the wire ships fp32 masters; only "
                    "q32 prices truthfully")
        return None

    def points(self):
        out, seen = [], set()
        for p in self._enumerate():
            if p.key in seen or self._reject(p):
                continue
            seen.add(p.key)
            out.append(p)
        return out

    def excluded(self):
        out, seen = [], set()
        for p in self._enumerate():
            reason = self._reject(p)
            if reason and p.key not in seen:
                seen.add(p.key)
                out.append((p, reason))
        return out


def merge_points(*spaces) -> list:
    """Concatenate several spaces' valid points, first spelling wins."""
    out, seen = [], set()
    for sp in spaces:
        for p in sp.points():
            if p.key not in seen:
                seen.add(p.key)
                out.append(p)
    return out
