"""Auto-placement search: the paper's three-way comparison as a DESIGN
SPACE (Neurosurgeon / Auto-Split mold).

The registry (core/schemes), first-class topologies (core/topology) and
exact per-edge ledgers already price any (scheme, cut depth, topology,
link width, wire) configuration in closed form — so instead of tabulating
three fixed schemes, this package enumerates the space (`space.py`),
prices every point WITHOUT training (`pricing.py` — exact, and the basis
of two provably-sound prunes), trains the surviving candidates through
`runner.run_scheme` (`driver.py`), and extracts the accuracy-per-Gbit
Pareto frontier (`pareto.py`).  `benchmarks/frontier_bench.py` turns the
whole pipeline into a CI-asserted artifact (BENCH_frontier.json).
"""
from repro.search.pareto import dominates, pareto_frontier  # noqa: F401
from repro.search.pricing import PricedPoint, price  # noqa: F401
from repro.search.space import ConfigPoint, SearchSpace  # noqa: F401
from repro.search.driver import MeasuredPoint, SearchResult, \
    run_search  # noqa: F401
