"""Stage 2 of the search: train the surviving candidates and extract the
accuracy-per-Gbit Pareto frontier.

Every trained point runs through the SAME `runner.run_scheme` pipeline the
paper curves use — one metered run per point, accuracy from the shared
eval split, bandwidth from the runner's BandwidthMeter — and the driver
checks the stage-1 pricing against the meter EXACTLY (both sides are sums
of the same integer-valued per-round charges, so equality is ==, not
isclose).  `train_pruned=True` additionally trains the pruned points
(the smoke-grid soundness audit frontier_bench asserts on).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.core import bandwidth
from repro.core.schemes import runner as runner_lib
from repro.data import multiview
from repro.search import pareto
from repro.search.pricing import CANDIDATE, PricedPoint, price
from repro.search.space import merge_points


@dataclass
class MeasuredPoint:
    key: str
    status: str
    stand_in: Optional[str]
    accuracy: float
    gbits: float                  # accounted (closed-form), cumulative
    measured_gbits: float
    delivered_gbits: float
    priced_gbits: float           # stage-1 prediction of `gbits`
    priced_measured_gbits: float
    trained: bool                 # False = inherited from its stand-in

    def record(self) -> dict:
        return dict(self.__dict__)


@dataclass
class SearchResult:
    priced: List[PricedPoint]
    measured: Dict[str, MeasuredPoint]
    frontier: List[MeasuredPoint] = field(default_factory=list)

    def candidates(self):
        return [m for m in self.measured.values()
                if m.status == CANDIDATE and m.trained]

    def record(self) -> dict:
        return {"grid": [pp.record() for pp in self.priced],
                "measured": [m.record() for m in self.measured.values()],
                "frontier": [m.key for m in self.frontier]}


class _DataCache:
    """One base image set, one view stack per noise ladder — points that
    share a view count share their data, so star/chain/tree comparisons
    are apples-to-apples."""

    def __init__(self, base_cfg):
        self.images, self.labels = multiview.make_base_dataset(
            base_cfg.dataset_size, num_classes=base_cfg.num_classes,
            image_shape=base_cfg.image_shape, seed=base_cfg.seed)
        self._views: dict = {}

    def views(self, cfg):
        key = cfg.noise_stds
        if key not in self._views:
            self._views[key] = jnp.asarray(
                multiview.make_views(self.images, cfg.noise_stds))
        return self._views[key], jnp.asarray(self.labels)


def _train_one(pp: PricedPoint, data: _DataCache, *, epochs, batch_size,
               lr, seed, eval_n) -> MeasuredPoint:
    views, labels = data.views(pp.cfg)
    meter = bandwidth.BandwidthMeter()
    curve = runner_lib.run_scheme(
        pp.point.scheme, views, labels, pp.cfg, epochs=epochs,
        batch_size=batch_size, lr=lr, seed=seed, eval_n=eval_n,
        wire=pp.point.wire, topology=pp.topology, meter=meter)
    last = curve[-1]
    return MeasuredPoint(
        key=pp.key, status=pp.status, stand_in=pp.stand_in,
        accuracy=last.accuracy, gbits=last.gbits,
        measured_gbits=last.measured_gbits,
        delivered_gbits=last.delivered_gbits,
        priced_gbits=pp.total_gbits(epochs),
        priced_measured_gbits=epochs * pp.epoch_nbytes() * 8 / 1e9,
        trained=True)


def run_search(spaces, base_cfg, *, epochs: int, batch_size: int,
               lr: float = 2e-3, seed: int = 0, eval_n: int = 256,
               train_pruned: bool = False, log=print) -> SearchResult:
    """The two-stage driver.  `spaces`: SearchSpace instances (their valid
    points are merged, first spelling wins) or a ready list of
    ConfigPoints."""
    points = spaces if isinstance(spaces, list) else merge_points(*spaces)
    train_n = (base_cfg.dataset_size // batch_size) * batch_size
    priced = price(points, base_cfg, batch_size=batch_size, train_n=train_n)
    todo = [pp for pp in priced
            if pp.status == CANDIDATE or train_pruned]
    n_pruned = len(priced) - sum(pp.status == CANDIDATE for pp in priced)
    log(f"search: {len(priced)} valid points, {n_pruned} pruned by ledger, "
        f"training {len(todo)}")

    data = _DataCache(base_cfg)
    result = SearchResult(priced=priced, measured={})
    for i, pp in enumerate(todo):
        m = _train_one(pp, data, epochs=epochs, batch_size=batch_size,
                       lr=lr, seed=seed, eval_n=eval_n)
        result.measured[m.key] = m
        log(f"  [{i + 1}/{len(todo)}] {m.key}: acc {m.accuracy:.3f}, "
            f"{m.gbits:.5f} Gbit ({m.status})")

    # pruned points that did not train inherit their stand-in's measured
    # result — sound by construction (bit-identical trajectory at equal
    # accuracy; the wire twin also shares the accounted-Gbit axis, the
    # star-dominated point keeps its own, strictly larger, price)
    for pp in priced:
        if pp.key in result.measured or pp.stand_in is None:
            continue
        rep = result.measured.get(pp.stand_in)
        if rep is None:
            continue
        result.measured[pp.key] = MeasuredPoint(
            key=pp.key, status=pp.status, stand_in=pp.stand_in,
            accuracy=rep.accuracy, gbits=pp.total_gbits(epochs),
            measured_gbits=epochs * pp.epoch_nbytes() * 8 / 1e9,
            delivered_gbits=pp.total_gbits(epochs),
            priced_gbits=pp.total_gbits(epochs),
            priced_measured_gbits=epochs * pp.epoch_nbytes() * 8 / 1e9,
            trained=False)

    result.frontier = pareto.pareto_frontier(result.candidates())
    return result
