"""Checkpointing: numpy-archive based pytree save/restore with step metadata.

No orbax dependency — flattens a pytree to path-keyed arrays inside a single
``.npz`` plus a JSON sidecar recording the treedef, step, config name, and
every leaf's ORIGINAL dtype.  Restore validates structure/shape/dtype
against a template pytree so a mismatched config fails loudly instead of
silently mis-assigning (or silently casting) tensors.

bf16 leaves are stored as fp32 — npz has no native bf16, and fp32 holds
every bf16 value exactly, so the bf16 -> fp32 -> bf16 round trip is
bitwise lossless (tests/test_checkpoint.py pins it).  The sidecar records
the leaf as "bfloat16", so restoring into a non-bf16 template still fails
loudly.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _path_part(p) -> str:
    # DictKey/FlattenedIndexKey carry .key, SequenceKey .idx, GetAttrKey
    # (NamedTuple fields, e.g. optimizer state) .name
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten_with_paths(tree):
    """Path-keyed leaves, npz-storable: (arrays, original dtype per key)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out, dtypes = {}, {}
    for path, leaf in flat:
        key = "/".join(_path_part(p) for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            # npz has no native bf16; fp32 round-trips bf16 losslessly
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, dtypes


def save(directory: str, step: int, params, *, extra: Optional[dict] = None,
         name: str = "ckpt") -> str:
    """CRASH-ATOMIC: both files are written to a temp name and os.replace'd
    into place, npz first and the JSON sidecar LAST — a SIGKILL mid-save
    (the repro/chaos.py scenario) leaves either the previous complete
    checkpoint or the new one, never a torn npz.  `latest_step` keys on the
    sidecar, so a checkpoint without one (the replace window) is invisible
    to resume."""
    os.makedirs(directory, exist_ok=True)
    arrays, dtypes = _flatten_with_paths(params)
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)
    meta = {"step": step, "num_tensors": len(arrays),
            "total_params": int(sum(a.size for a in arrays.values())),
            "dtypes": dtypes}
    if extra:
        meta.update(extra)
    meta_path = path.replace(".npz", ".json")
    with open(meta_path + ".tmp", "w") as f:
        json.dump(meta, f, indent=2)
    os.replace(meta_path + ".tmp", meta_path)
    return path


def latest_step(directory: str, name: str = "ckpt") -> Optional[int]:
    """The newest COMPLETE checkpoint: the npz counts only once its JSON
    sidecar (written last, atomically) is in place."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for fn in os.listdir(directory):
        m = re.match(rf"{name}_(\d+)\.npz$", fn)
        if m and os.path.exists(os.path.join(
                directory, fn.replace(".npz", ".json"))):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load_meta(directory: str, step: Optional[int] = None,
              name: str = "ckpt") -> dict:
    """The JSON sidecar of one checkpoint (latest when `step` is None) —
    the place runners keep their resume context (epoch/curve/meter)."""
    if step is None:
        step = latest_step(directory, name)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"{name}_{step:08d}.json")
    with open(path) as f:
        return json.load(f)


def restore(directory: str, template, *, step: Optional[int] = None,
            name: str = "ckpt"):
    """Restore into the structure of `template`.

    Structure, shape AND dtype are validated: a leaf whose recorded dtype
    differs from the template's raises instead of silently casting — a
    checkpoint from a bf16 run cannot quietly load into an fp32 config
    (and vice versa).  Checkpoints written before dtypes were recorded
    skip the dtype check (nothing to compare against)."""
    if step is None:
        step = latest_step(directory, name)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    data = np.load(path)
    want, want_dtypes = _flatten_with_paths(template)
    missing = set(want) - set(data.files)
    extra_keys = set(data.files) - set(want)
    if missing or extra_keys:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra_keys)[:5]}")
    try:
        saved_dtypes = load_meta(directory, step, name).get("dtypes")
    except FileNotFoundError:
        saved_dtypes = None
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(_path_part(q) for q in p)
        arr = data[key]
        if arr.shape != np.shape(leaf):
            raise ValueError(f"{key}: shape {arr.shape} != {np.shape(leaf)}")
        if saved_dtypes is not None and key in saved_dtypes \
                and saved_dtypes[key] != want_dtypes[key]:
            raise ValueError(
                f"{key}: checkpoint dtype {saved_dtypes[key]} != template "
                f"dtype {want_dtypes[key]} — refusing the silent cast")
        leaves.append(jnp.asarray(arr, np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
