"""Checkpointing: numpy-archive based pytree save/restore with step metadata.

No orbax dependency — flattens a pytree to path-keyed arrays inside a single
``.npz`` plus a JSON sidecar recording the treedef, step, and config name.
Restore validates structure/shape/dtype against a template pytree so a
mismatched config fails loudly instead of silently mis-assigning tensors.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # npz has no native bf16; fp32 round-trips bf16 losslessly
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save(directory: str, step: int, params, *, extra: Optional[dict] = None,
         name: str = "ckpt") -> str:
    os.makedirs(directory, exist_ok=True)
    arrays = _flatten_with_paths(params)
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    np.savez(path, **arrays)
    meta = {"step": step, "num_tensors": len(arrays),
            "total_params": int(sum(a.size for a in arrays.values()))}
    if extra:
        meta.update(extra)
    with open(path.replace(".npz", ".json"), "w") as f:
        json.dump(meta, f, indent=2)
    return path


def latest_step(directory: str, name: str = "ckpt") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for fn in os.listdir(directory):
        m = re.match(rf"{name}_(\d+)\.npz$", fn)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(directory: str, template, *, step: Optional[int] = None,
            name: str = "ckpt"):
    """Restore into the structure of `template` (shape/dtype validated)."""
    if step is None:
        step = latest_step(directory, name)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    data = np.load(path)
    want = _flatten_with_paths(template)
    missing = set(want) - set(data.files)
    extra_keys = set(data.files) - set(want)
    if missing or extra_keys:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra_keys)[:5]}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(q.key) if hasattr(q, "key") else str(q.idx)
                       for q in p)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
