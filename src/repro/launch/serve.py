"""Serving driver: batched prefill + decode loop with a continuous-batching
style request queue (reduced configs on CPU; the same step functions lower
for the production mesh in the dry-run).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --prompt-len 64 --gen-len 32

`--arch paper-inl` serves the paper's in-network model instead: each request
fans its J views through a lossy star (core/linkfault.py link models) and
the fusion center fuses WHAT ARRIVED by the per-request deadline
(`--deadline-ms`, straggler latents dropped, survivors renormalised) —
the inference-side reading of cfg.fusion_deadline_ms.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import tokens as token_data
from repro.launch import steps as steps_lib
from repro.models import zoo


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def serve_batch(cfg, params, prompts, gen_len: int, *, temperature=0.0):
    """prompts: (B, P) int32.  Returns (B, gen_len) generated ids.
    Prefill once, then gen_len decode steps against the growing cache."""
    B, P = prompts.shape
    prefill = jax.jit(steps_lib.make_prefill_step(cfg))
    decode = jax.jit(steps_lib.make_decode_step(cfg), donate_argnums=(2,))

    if cfg.modality == "audio_tokens":
        batch = {"tokens_mc": jnp.broadcast_to(
            prompts[..., None], (B, P, cfg.num_codebooks))}
    else:
        batch = {"tokens": prompts}
    last_logits, cache = prefill(params, batch)
    cache = zoo.pad_cache(cache, gen_len)

    out = []
    tok = greedy(last_logits)
    for t in range(gen_len):
        out.append(tok)
        step_batch = {"cache_len": jnp.asarray(P + t, jnp.int32)}
        if cfg.modality == "audio_tokens":
            step_batch["tokens_mc"] = jnp.broadcast_to(
                tok[:, None, None] if tok.ndim == 1 else tok[:, None],
                (B, 1, cfg.num_codebooks)).astype(jnp.int32)
        else:
            step_batch["tokens"] = tok.reshape(B, 1)[:, :1] if tok.ndim > 1 \
                else tok[:, None]
        logits, cache = decode(params, step_batch, cache)
        tok = greedy(logits)
        if tok.ndim > 1:                     # audio: (B, K) -> flatten choice
            tok = tok[:, 0]
    return jnp.stack(out, axis=1)


def serve_inl(args):
    """Fuse-what-arrived serving: J lossy uplinks race the per-request
    deadline; the fusion center renormalises over the latents that made it
    (linkfault.partial_fuse) instead of failing the request."""
    from repro.configs.paper_inl import PaperExperimentConfig
    from repro.core import linkfault, schemes
    from repro.core import topology as topology_lib
    from repro.data import multiview

    cfg = PaperExperimentConfig(
        conv_channels=(4,), d_bottleneck=8, dense_units=(32,),
        image_shape=(16, 16, 3), dataset_size=640) if args.smoke \
        else PaperExperimentConfig()
    scheme = schemes.get("inl")
    state = scheme.init(cfg, jax.random.PRNGKey(args.seed))
    round_fn = scheme.make_round(cfg)
    imgs, labels = multiview.make_base_dataset(
        cfg.dataset_size, image_shape=cfg.image_shape, seed=args.seed)
    views = multiview.make_views(imgs, cfg.noise_stds)
    rng = jax.random.PRNGKey(args.seed + 1)
    epochs = 2 if args.smoke else 5
    for ep in range(epochs):
        for v, l in multiview.multiview_batches(views, labels, 32, seed=ep):
            rng, sub = jax.random.split(rng)
            state, _ = round_fn(state, jnp.asarray(v)[None],
                                jnp.asarray(l)[None], sub)

    # a star whose uplinks straggle: exponential latency tails around the
    # deadline, plus a little outright loss
    lossy = linkfault.with_links(
        topology_lib.star(cfg.num_clients),
        linkfault.LinkModel(erasure=0.05, latency_ms=5.0, jitter_ms=10.0))
    n = args.requests
    ev, el = jnp.asarray(views[:, :n]), np.asarray(labels[:n])
    key = jax.random.PRNGKey(args.seed + 2)

    t0 = time.time()
    delivery = linkfault.sample_delivery_mask(key, lossy, cfg, n,
                                              deadline=args.deadline_ms)
    from repro.core import inl as inl_lib
    probs = inl_lib.predict(state["params"], state["state"], ev,
                            cfg=cfg, delivery=delivery)
    dt = time.time() - t0
    arrived = np.asarray(delivery).sum(axis=0)
    acc = float(np.mean(np.argmax(np.asarray(probs), -1) == el))
    clean = scheme.predict(state, ev, cfg=cfg)
    clean_acc = float(np.mean(np.argmax(np.asarray(clean), -1) == el))
    dl = "none" if args.deadline_ms is None else f"{args.deadline_ms:g}ms"
    print(f"arch=paper-inl served {n} requests over star({cfg.num_clients})"
          f" with straggling uplinks, deadline={dl} ({dt:.1f}s incl."
          f" compile)")
    print(f"views fused per request: min={int(arrived.min())} "
          f"mean={arrived.mean():.2f} max={int(arrived.max())} "
          f"of {cfg.num_clients}")
    print(f"accuracy: {acc:.4f} under the deadline vs {clean_acc:.4f} on a "
          f"clean network")
    if args.deadline_ms is not None:
        assert int(arrived.min()) < cfg.num_clients, \
            "deadline never bit — straggler path not exercised"
    assert arrived.min() >= 0 and acc >= 0.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="paper-inl: per-request fusion deadline — latents "
                         "missing it are dropped and the survivors fused")
    args = ap.parse_args()

    if args.arch == "paper-inl":
        serve_inl(args)
        return

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.modality == "vlm":
        raise SystemExit("serve demo supports text/audio archs; VLM decode "
                         "is exercised via the dry-run")

    params = zoo.init_params(cfg, jax.random.PRNGKey(args.seed))
    prompts = np.asarray(
        token_data.markov_stream(cfg.vocab_size,
                                 args.requests * args.prompt_len,
                                 seed=args.seed)
    ).reshape(args.requests, args.prompt_len).astype(np.int32)

    t0 = time.time()
    gen = serve_batch(cfg, params, jnp.asarray(prompts), args.gen_len)
    dt = time.time() - t0
    toks = args.requests * args.gen_len
    print(f"arch={cfg.name} served {args.requests} requests, "
          f"prompt={args.prompt_len}, generated {args.gen_len} each "
          f"({toks} tokens, {dt:.1f}s, {toks/dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(gen[0, :16]).tolist())


if __name__ == "__main__":
    main()
