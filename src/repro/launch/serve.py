"""Serving driver: the CLI front end over the INL serving plane
(`repro/serving/`) plus the LLM batched prefill+decode demo.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --prompt-len 64 --gen-len 32

`--arch paper-inl` serves the paper's in-network model: requests fan their
J views into per-node queues, the continuous-batching engine coalesces
whatever is in flight into bucketed fused-cutlayer launches (one compile
per bucket size), and with `--deadline-ms` / `--erasure` the fusion center
fuses WHAT ARRIVED per request — a straggling view misses only its own
fusion, never its batchmates' (per-request-id fault draws).  `--load-gen`
switches from the one-shot block to a seeded Poisson offered-load sweep
with p50/p99 latency and goodput per load point.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import signal
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import tokens as token_data
from repro.launch import steps as steps_lib
from repro.models import zoo


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def clamp_requests(n: int, available: int, *, strict: bool = False) -> int:
    """`--requests` larger than the dataset used to truncate SILENTLY to
    the available rows — the reported accuracy/latency then covered fewer
    requests than asked for.  Clamp loudly (RuntimeWarning), or raise under
    `--strict`."""
    if n <= available:
        return n
    msg = (f"--requests {n} exceeds the {available} requests available in "
           f"the dataset; serving {available}")
    if strict:
        raise ValueError(msg + " is disallowed in strict mode")
    warnings.warn(msg, RuntimeWarning, stacklevel=2)
    return available


def serve_batch(cfg, params, prompts, gen_len: int, *, trace_log=None):
    """prompts: (B, P) int32.  Returns (B, gen_len) generated ids.
    Prefill once, then greedy decode against the growing cache.

    The argmax lives INSIDE the jitted decode step
    (`make_decode_step(greedy=True)`) and the token rides the device
    between steps — the loop never issues a per-token eager argmax against
    in-flight logits, so gen_len steps dispatch back-to-back with no
    blocking host transfer (tests/test_serving.py pins one compile and a
    transfer-guard-clean loop).  `trace_log` is forwarded to the decode
    step for the one-compile assertion."""
    B, P = prompts.shape
    prefill = jax.jit(steps_lib.make_prefill_step(cfg))
    decode = jax.jit(
        steps_lib.make_decode_step(cfg, greedy=True, trace_log=trace_log),
        donate_argnums=(2,))

    if cfg.modality == "audio_tokens":
        batch = {"tokens_mc": jnp.broadcast_to(
            prompts[..., None], (B, P, cfg.num_codebooks))}
    else:
        batch = {"tokens": prompts}
    last_logits, cache = prefill(params, batch)
    cache = zoo.pad_cache(cache, gen_len)

    tok = greedy(last_logits)            # once per batch, not per token
    if tok.ndim > 1:                     # audio: (B, K) -> first codebook
        tok = tok[:, 0]
    out = [tok]
    for t in range(gen_len - 1):
        step_batch = {"cache_len": jnp.asarray(P + t, jnp.int32)}
        if cfg.modality == "audio_tokens":
            step_batch["tokens_mc"] = jnp.broadcast_to(
                tok[:, None, None],
                (B, 1, cfg.num_codebooks)).astype(jnp.int32)
        else:
            step_batch["tokens"] = tok[:, None]
        tok, cache = decode(params, step_batch, cache)
        out.append(tok)
    return jnp.stack(out, axis=1)


@contextlib.contextmanager
def drain_on_signal(engine):
    """SIGTERM/Ctrl-C become a GRACEFUL engine shutdown: admission stops,
    queued work drains, and still-pending futures fail with
    `EngineShutdown` instead of hanging their waiters.  The serve flows
    catch that and flush ServeStats + the bit ledgers before exiting, so
    an interrupted run still reports what it actually served.

    Yields a dict that gains a "sig" key if a signal fired (the caller
    uses it to pick a clean exit code over a crash)."""
    fired = {}

    def _handler(signum, frame):
        fired["sig"] = signum
        engine.shutdown()

    prev = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            prev[sig] = signal.signal(sig, _handler)
        except ValueError:      # not the main thread (embedded use)
            pass
    try:
        yield fired
    finally:
        for sig, h in prev.items():
            signal.signal(sig, h)


def flush_stats(engine, *, label: str = "shutdown") -> None:
    """The ledger flush every exit path owes the operator: whatever the
    engine completed is reported even when the run was cut short."""
    st = engine.stats
    print(f"[{label}] served={st.completed} launches={st.launches} "
          f"shed={st.shed} patched={st.patched} "
          f"pad_fraction={st.pad_fraction:.2f}")
    print(f"[{label}] ledger: offered={engine.meter.gbits * 1e3:.3f} Mbits "
          f"delivery_ratio={engine.meter.delivery_ratio:.3f}")


def _inl_setup(args):
    """Train a smoke INL model and build the requested serving topology.
    Returns (scheme, state, cfg, topology-or-None, (J, n) views, labels)."""
    from repro.configs.paper_inl import PaperExperimentConfig
    from repro.core import linkfault, schemes
    from repro.core import topology as topology_lib
    from repro.data import multiview

    cfg = PaperExperimentConfig(
        conv_channels=(4,), d_bottleneck=8, dense_units=(32,),
        image_shape=(16, 16, 3), dataset_size=640) if args.smoke \
        else PaperExperimentConfig()
    if args.topology == "tree":
        topo = topology_lib.tree(2, 2)
        cfg = dataclasses.replace(
            cfg, num_clients=topo.num_views(),
            noise_stds=cfg.noise_stds
            + (1.5,) * (topo.num_views() - len(cfg.noise_stds)))
    else:
        topo = topology_lib.star(cfg.num_clients)
    if args.wire == "packed" and cfg.link_bits > 16:
        cfg = dataclasses.replace(cfg, link_bits=8)

    scheme = schemes.get("inl")
    state = scheme.init(cfg, jax.random.PRNGKey(args.seed))
    imgs, labels = multiview.make_base_dataset(
        cfg.dataset_size, image_shape=cfg.image_shape, seed=args.seed)
    views = multiview.make_views(imgs, cfg.noise_stds)
    ckpt_dir = getattr(args, "ckpt_dir", "")
    restored = False
    if ckpt_dir:
        from repro import checkpoint
        if checkpoint.latest_step(ckpt_dir) is not None:
            state, step = checkpoint.restore(ckpt_dir, jax.device_get(state))
            print(f"serving from checkpoint step {step} ({ckpt_dir})")
            restored = True
    if not restored:
        round_fn = scheme.make_round(cfg)
        rng = jax.random.PRNGKey(args.seed + 1)
        epochs = 2 if args.smoke else 5
        for ep in range(epochs):
            for v, l in multiview.multiview_batches(views, labels, 32,
                                                    seed=ep):
                rng, sub = jax.random.split(rng)
                state, _ = round_fn(state, jnp.asarray(v)[None],
                                    jnp.asarray(l)[None], sub)
        if ckpt_dir:
            from repro import checkpoint
            checkpoint.save(ckpt_dir, epochs, jax.device_get(state),
                            extra={"arch": "paper-inl", "epochs": epochs})

    # a network whose uplinks straggle: exponential latency tails around
    # the deadline, plus a little outright loss
    link = None
    if args.deadline_ms is not None:
        link = linkfault.LinkModel(erasure=max(args.erasure, 0.05),
                                   latency_ms=5.0, jitter_ms=10.0)
    elif args.erasure > 0:
        link = linkfault.LinkModel(erasure=args.erasure)
    if link is not None:
        topo = linkfault.with_links(topo, link)
    return scheme, state, cfg, topo, np.asarray(views), np.asarray(labels)


def serve_inl(args):
    """One-shot fuse-what-arrived serving through the continuous-batching
    engine: submit a block of requests, report fused-view stats, accuracy
    under the deadline vs clean, and the per-request bit ledger."""
    from repro.serving import EngineShutdown, ServingEngine

    scheme, state, cfg, topo, views, labels = _inl_setup(args)
    n = clamp_requests(args.requests, views.shape[1], strict=args.strict)
    ev, el = views[:, :n], labels[:n]

    transport = None
    if args.transport:
        from repro.transport import DEFAULT_RETRY, NetworkTransport
        transport = NetworkTransport(topo, cfg, seed=args.seed + 3,
                                     policy=DEFAULT_RETRY,
                                     channels=args.transport)
    engine = ServingEngine(scheme, state, cfg, topology=topo,
                           wire=args.wire, deadline_ms=args.deadline_ms,
                           seed=args.seed + 2, transport=transport,
                           speculative=args.speculative)
    engine.warmup()
    t0 = time.time()
    try:
        with engine, drain_on_signal(engine) as fired:
            probs, results = engine.serve(ev)
    except EngineShutdown:
        flush_stats(engine, label="drained")
        if transport is not None:
            transport.close()
        raise SystemExit(0 if fired.get("sig") else 1)
    dt = time.time() - t0
    arrived = np.asarray([r.views_fused for r in results])
    acc = float(np.mean(np.argmax(probs, -1) == el))
    # the jitted reference: same compiled-prediction semantics as the
    # engine's bucketed launches.  Executables compiled at different batch
    # shapes may round the last ulp differently, so the clean-parity bar
    # is tight-allclose + identical decisions (the eager path is further
    # off still, ~1e-7 of XLA fusion rounding)
    ref_topo = None if args.topology == "star" else topo
    clean = np.asarray(jax.jit(
        lambda st, vv: scheme.predict(st, vv, cfg=cfg, topology=ref_topo)
    )(state, jnp.asarray(ev)))
    clean_acc = float(np.mean(np.argmax(clean, -1) == el))
    dl = "none" if args.deadline_ms is None else f"{args.deadline_ms:g}ms"
    J = engine.topo.num_views()
    print(f"arch=paper-inl served {n} requests over {engine.topo.describe()}"
          f" wire={args.wire}, deadline={dl} ({dt:.1f}s post-warmup)")
    print(f"views fused per request: min={int(arrived.min())} "
          f"mean={arrived.mean():.2f} max={int(arrived.max())} of {J}")
    print(f"launches={engine.stats.launches} "
          f"pad_fraction={engine.stats.pad_fraction:.2f} "
          f"traces={dict(engine.trace_counts)}")
    print(f"accuracy: {acc:.4f} under the deadline vs {clean_acc:.4f} on a "
          f"clean network; offered={engine.meter.gbits * 1e3:.3f} Mbits "
          f"delivery_ratio={engine.meter.delivery_ratio:.3f}")
    if transport is not None:
        snap = transport.snapshot()
        print(f"transport: channels={args.transport} "
              f"patched={engine.stats.patched} "
              f"views_recovered={engine.stats.views_recovered} "
              f"breakers={ {k: b['state'] for k, b in snap['breaker'].items()} }")
        transport.close()
    assert all(c <= 1 for c in engine.trace_counts.values()), \
        f"bucket predict retraced: {engine.trace_counts}"
    if args.deadline_ms is not None:
        # speculative fusion RECOVERS stragglers (their patched fusion
        # fuses everything that eventually arrived), so the evidence the
        # deadline bit is either a short fusion or a patched request
        assert int(arrived.min()) < J or engine.stats.patched > 0, \
            "deadline never bit — straggler path not exercised"
    if not engine.faulty:
        assert np.allclose(probs, clean, atol=2e-6, rtol=0), \
            "clean-network serving drifted from jitted scheme.predict"
        assert np.array_equal(np.argmax(probs, -1), np.argmax(clean, -1)), \
            "clean-network serving changed a decision vs scheme.predict"
    assert arrived.min() >= 0 and acc >= 0.0


def serve_inl_loadgen(args):
    """Poisson offered-load sweep: calibrate serial capacity, then offer
    multiples of it and print p50/p99 latency + goodput per point."""
    from repro.serving import (EngineShutdown, ServingEngine,
                               measure_serial_capacity, run_poisson)

    scheme, state, cfg, topo, views, labels = _inl_setup(args)
    n = clamp_requests(args.requests, views.shape[1], strict=args.strict)
    pool = views[:, :n]

    serial = ServingEngine(scheme, state, cfg, topology=topo,
                           wire=args.wire, deadline_ms=args.deadline_ms,
                           buckets=(1,), seed=args.seed + 2)
    serial.warmup()
    with serial:
        cap = measure_serial_capacity(serial, pool,
                                      num_requests=min(n, 32))
    print(f"serial capacity: {cap:.1f} req/s over {serial.topo.describe()}")

    engine = ServingEngine(scheme, state, cfg, topology=topo,
                           wire=args.wire, deadline_ms=args.deadline_ms,
                           seed=args.seed + 2, max_queue=args.max_queue)
    engine.warmup()
    print(f"{'offered_rps':>12} {'goodput_rps':>12} {'p50_ms':>9} "
          f"{'p99_ms':>9} {'fused':>6} {'shed':>5}")
    try:
        with engine, drain_on_signal(engine) as fired:
            for mult in (0.5, 2.0, 8.0):
                s = run_poisson(engine, pool, rate_rps=cap * mult,
                                num_requests=n,
                                seed=args.seed + int(mult * 10))
                print(f"{s['offered_rps']:12.1f} {s['goodput_rps']:12.1f} "
                      f"{s['p50_ms']:9.2f} {s['p99_ms']:9.2f} "
                      f"{s['mean_views_fused']:6.2f} {s['shed']:5d}")
    except EngineShutdown:
        flush_stats(engine, label="drained")
        raise SystemExit(0 if fired.get("sig") else 1)
    flush_stats(engine, label="done")
    assert all(c <= 1 for c in engine.trace_counts.values()), \
        f"bucket predict retraced: {engine.trace_counts}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="paper-inl: per-request fusion deadline — latents "
                         "missing it are dropped and the survivors fused")
    ap.add_argument("--topology", choices=("star", "tree"), default="star",
                    help="paper-inl: serving graph (tree = tree(2, 2))")
    ap.add_argument("--erasure", type=float, default=0.0,
                    help="paper-inl: per-link erasure probability")
    ap.add_argument("--wire", choices=("dense", "packed"), default="dense",
                    help="paper-inl: relay-hop wire format (graph paths)")
    ap.add_argument("--strict", action="store_true",
                    help="error (rather than clamp) when --requests "
                         "exceeds the dataset")
    ap.add_argument("--ckpt-dir", default="",
                    help="paper-inl: serve the latest checkpoint under this "
                         "directory (skipping the smoke training), or save "
                         "the smoke-trained model there when none exists — "
                         "serving restarts recover instead of retraining")
    ap.add_argument("--transport", choices=("loopback", "socket"),
                    default=None,
                    help="paper-inl: ride each view fragment over a real "
                         "retrying edge channel (repro/transport/) instead "
                         "of in-graph fault draws")
    ap.add_argument("--speculative", action="store_true",
                    help="paper-inl (needs --transport): fuse what arrived "
                         "at the deadline, patch late stragglers into the "
                         "next bucket")
    ap.add_argument("--load-gen", action="store_true",
                    help="paper-inl: Poisson offered-load sweep instead of "
                         "the one-shot block")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="paper-inl --load-gen: bound per-node queue depth; "
                         "arrivals over the bound are shed with a typed "
                         "Rejected result instead of growing latency "
                         "without limit")
    args = ap.parse_args()

    if args.arch == "paper-inl":
        (serve_inl_loadgen if args.load_gen else serve_inl)(args)
        return

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.modality == "vlm":
        raise SystemExit("serve demo supports text/audio archs; VLM decode "
                         "is exercised via the dry-run")

    params = zoo.init_params(cfg, jax.random.PRNGKey(args.seed))
    prompts = np.asarray(
        token_data.markov_stream(cfg.vocab_size,
                                 args.requests * args.prompt_len,
                                 seed=args.seed)
    ).reshape(args.requests, args.prompt_len).astype(np.int32)

    t0 = time.time()
    gen = serve_batch(cfg, params, jnp.asarray(prompts), args.gen_len)
    dt = time.time() - t0
    toks = args.requests * args.gen_len
    print(f"arch={cfg.name} served {args.requests} requests, "
          f"prompt={args.prompt_len}, generated {args.gen_len} each "
          f"({toks} tokens, {dt:.1f}s, {toks/dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(gen[0, :16]).tolist())


if __name__ == "__main__":
    main()
