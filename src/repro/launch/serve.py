"""Serving driver: batched prefill + decode loop with a continuous-batching
style request queue (reduced configs on CPU; the same step functions lower
for the production mesh in the dry-run).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 8 --prompt-len 64 --gen-len 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import tokens as token_data
from repro.launch import steps as steps_lib
from repro.models import zoo


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def serve_batch(cfg, params, prompts, gen_len: int, *, temperature=0.0):
    """prompts: (B, P) int32.  Returns (B, gen_len) generated ids.
    Prefill once, then gen_len decode steps against the growing cache."""
    B, P = prompts.shape
    prefill = jax.jit(steps_lib.make_prefill_step(cfg))
    decode = jax.jit(steps_lib.make_decode_step(cfg), donate_argnums=(2,))

    if cfg.modality == "audio_tokens":
        batch = {"tokens_mc": jnp.broadcast_to(
            prompts[..., None], (B, P, cfg.num_codebooks))}
    else:
        batch = {"tokens": prompts}
    last_logits, cache = prefill(params, batch)
    cache = zoo.pad_cache(cache, gen_len)

    out = []
    tok = greedy(last_logits)
    for t in range(gen_len):
        out.append(tok)
        step_batch = {"cache_len": jnp.asarray(P + t, jnp.int32)}
        if cfg.modality == "audio_tokens":
            step_batch["tokens_mc"] = jnp.broadcast_to(
                tok[:, None, None] if tok.ndim == 1 else tok[:, None],
                (B, 1, cfg.num_codebooks)).astype(jnp.int32)
        else:
            step_batch["tokens"] = tok.reshape(B, 1)[:, :1] if tok.ndim > 1 \
                else tok[:, None]
        logits, cache = decode(params, step_batch, cache)
        tok = greedy(logits)
        if tok.ndim > 1:                     # audio: (B, K) -> flatten choice
            tok = tok[:, 0]
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.modality == "vlm":
        raise SystemExit("serve demo supports text/audio archs; VLM decode "
                         "is exercised via the dry-run")

    params = zoo.init_params(cfg, jax.random.PRNGKey(args.seed))
    prompts = np.asarray(
        token_data.markov_stream(cfg.vocab_size,
                                 args.requests * args.prompt_len,
                                 seed=args.seed)
    ).reshape(args.requests, args.prompt_len).astype(np.int32)

    t0 = time.time()
    gen = serve_batch(cfg, params, jnp.asarray(prompts), args.gen_len)
    dt = time.time() - t0
    toks = args.requests * args.gen_len
    print(f"arch={cfg.name} served {args.requests} requests, "
          f"prompt={args.prompt_len}, generated {args.gen_len} each "
          f"({toks} tokens, {dt:.1f}s, {toks/dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(gen[0, :16]).tolist())


if __name__ == "__main__":
    main()
