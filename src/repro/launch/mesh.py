"""Production meshes.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and smoke tests
must see 1 CPU device while the dry-run sees 512 placeholders).
"""
from __future__ import annotations

import warnings

import jax


def _client_axis_size(num_clients: int, slots: int) -> int:
    """Size of the 'client' mesh axis given `slots` devices available to it.

    The largest axis that both divides the slot count (the mesh must tile
    the devices) and divides J (every shard holds the same number of WHOLE
    encoders — a lopsided split would leave ragged stacks shard_map cannot
    express): J itself when it divides the slots, a partial-parallel axis
    (several nodes per shard) otherwise, and a replicated axis (size 1,
    with a warning) when no common divisor exists."""
    if num_clients >= 1 and slots % num_clients == 0:
        return num_clients
    client = max((k for k in range(1, min(num_clients, slots) + 1)
                  if slots % k == 0 and num_clients % k == 0), default=1)
    if client == 1 and num_clients > 1:
        warnings.warn(
            f"J={num_clients} clients share no divisor with the {slots} "
            f"available device slots; falling back to a replicated client "
            f"axis (client=1) — node-parallel INL/FL execution is "
            f"disabled, batch/data parallelism still applies.",
            stacklevel=3)
    return client


def current_abstract_mesh():
    """The ambient abstract mesh, or None when no mesh is active.

    `jax.sharding.get_abstract_mesh` is only public from jax >= 0.5; on the
    pinned 0.4.x it lives in `jax._src.mesh` and returns a non-mesh sentinel
    when nothing is set.  Callers branch on None instead of `.empty` so both
    versions work."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        from jax._src import mesh as _mesh
        get = _mesh.get_abstract_mesh
    m = get()
    if not hasattr(m, "axis_names") or getattr(m, "empty", False):
        return None
    return m


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_inl_mesh(num_clients: int, *, multi_pod: bool = False):
    """Mesh for the paper-mode (INL) trainer: a 'client' axis holds the J
    edge nodes; remaining capacity goes to data/model parallelism.
    256 (or 512) chips total, same hardware as make_production_mesh.

    When J does not divide the per-model-group chip count the client axis
    falls back to replicated (size 1, with a warning) instead of erroring —
    the scheme still runs, data-parallel only."""
    model = 16
    total = 512 if multi_pod else 256
    client = _client_axis_size(num_clients, total // model)
    data = total // (client * model)
    return jax.make_mesh((client, data, model),
                         ("client", "data", "model"))


def make_inl_host_mesh(num_clients: int):
    """INL mesh over the locally visible devices (CPU smoke / forced
    multi-device runs): ('client', 'data') with the J nodes on 'client' when
    J divides the device count, else a replicated client axis (warned) and
    everything on 'data'.  This is the mesh `schemes.runner.run_scheme`
    takes for sharded host execution."""
    n = len(jax.devices())
    client = _client_axis_size(num_clients, n)
    return jax.make_mesh((client, n // client), ("client", "data"))


def make_host_mesh():
    """Whatever devices exist locally (CPU smoke runs): 1D 'data' mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes for a mesh (everything that isn't 'model' or
    'client')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
