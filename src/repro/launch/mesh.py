"""Production meshes.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and smoke tests
must see 1 CPU device while the dry-run sees 512 placeholders).
"""
from __future__ import annotations

import jax


def current_abstract_mesh():
    """The ambient abstract mesh, or None when no mesh is active.

    `jax.sharding.get_abstract_mesh` is only public from jax >= 0.5; on the
    pinned 0.4.x it lives in `jax._src.mesh` and returns a non-mesh sentinel
    when nothing is set.  Callers branch on None instead of `.empty` so both
    versions work."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        from jax._src import mesh as _mesh
        get = _mesh.get_abstract_mesh
    m = get()
    if not hasattr(m, "axis_names") or getattr(m, "empty", False):
        return None
    return m


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_inl_mesh(num_clients: int, *, multi_pod: bool = False):
    """Mesh for the paper-mode (INL) trainer: a 'client' axis holds the J
    edge nodes; remaining capacity goes to data/model parallelism.
    256 (or 512) chips total, same hardware as make_production_mesh."""
    model = 16
    total = 512 if multi_pod else 256
    data = total // (num_clients * model)
    assert data >= 1, f"J={num_clients} too large for {total} chips"
    return jax.make_mesh((num_clients, data, model),
                         ("client", "data", "model"))


def make_host_mesh():
    """Whatever devices exist locally (CPU smoke runs): 1D 'data' mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes for a mesh (everything that isn't 'model' or
    'client')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
