"""Step functions: train / prefill / decode (+ INL paper-mode train), the
units the launcher jits, shards, and the dry-run lowers.

`make_scan_train_step` wraps K optimizer steps into one jitted
lax.scan with donated (params, opt_state) buffers — the launcher's epoch
unit; per-batch Python dispatch overhead amortises over K.  The scan now
extends across the data-loading boundary: `grouped_batches` +
`stack_batches` assemble the (K, ...) scan xs host-side and
`data/prefetch.prefetch_to_device` keeps >= 2 stacked groups in flight, so
the host->device transfer of group g+1 overlaps the scan executing group g
(see launch/train.py --prefetch).
"""
from __future__ import annotations

from typing import Iterable, Iterator, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro import optim as optim_lib
from repro.core import inl_llm
from repro.models import zoo


def grouped_batches(data: Iterable, k: int) -> Iterator[List]:
    """Chunk a batch stream into lists of k (trailing partial group kept —
    the scan retraces once for it at most)."""
    group = []
    for batch in data:
        group.append(batch)
        if len(group) == k:
            yield group
            group = []
    if group:
        yield group


def stack_batches(group: List):
    """Stack a group of batch pytrees into the scan's (K, ...) xs on the
    HOST (numpy) — the device transfer belongs to the prefetcher, which
    overlaps it with compute."""
    return jax.tree.map(lambda *xs: np.stack(xs), *group)


def make_train_step(cfg, optimizer, *, microbatches: int = 1,
                    unroll: bool = False):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1 splits the global batch along axis 0 and accumulates
    fp32 gradients over a lax.scan — activation residency divides by the
    microbatch count while arithmetic is unchanged (gradient accumulation)."""
    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: zoo.loss_and_metrics(p, cfg, batch), has_aux=True)(
            params)

    if microbatches == 1:
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = grad_fn(params, batch)
            new_params, new_opt = optimizer.update(grads, opt_state, params)
            metrics["grad_norm"] = optim_lib.global_norm(grads)
            return new_params, new_opt, metrics
        return train_step

    def train_step(params, opt_state, batch):
        B = jax.tree.leaves(batch)[0].shape[0]
        assert B % microbatches == 0, (B, microbatches)

        def split(x):
            return x.reshape((microbatches, B // microbatches) + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(acc, one):
            (loss, metrics), grads = grad_fn(params, one)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, metrics
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if unroll:
            # inline accumulation loop: exact cost_analysis (a lax.scan body
            # is counted once), used by the dry-run's trade-off studies
            gsum = zeros
            mlist = []
            for i in range(microbatches):
                one = jax.tree.map(lambda x: x[i], mb)
                gsum, m = body(gsum, one)
                mlist.append(m)
            ms = jax.tree.map(lambda *t: jnp.stack(t), *mlist)
        else:
            gsum, ms = jax.lax.scan(body, zeros, mb)
        grads = jax.tree.map(lambda g: g / microbatches, gsum)
        metrics = jax.tree.map(lambda m: m.mean(axis=0), ms)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics["grad_norm"] = optim_lib.global_norm(grads)
        return new_params, new_opt, metrics
    return train_step


def make_prefill_step(cfg):
    """(params, batch) -> (last_logits, cache)."""
    def prefill_step(params, batch):
        logits, cache, _ = zoo.forward(params, cfg, batch, mode="prefill",
                                       logits_positions="last")
        return logits[:, -1], cache
    return prefill_step


def make_decode_step(cfg, *, greedy: bool = False, trace_log: list = None):
    """(params, batch, cache) -> (logits, new_cache).  batch carries the new
    token(s) + cache_len; serve_step semantics per the assignment: ONE new
    token against a cache of seq_len entries.

    greedy=True returns the argmax TOKEN ids (B,) int32 instead of logits —
    the sampling folds into the jitted step, so a serving decode loop never
    dispatches an eager per-token argmax against the in-flight logits (the
    host round trip the old `serve.py` loop paid every generated token).
    Audio (multi-codebook) logits argmax per codebook and keep the first —
    the same flattening the serve loop applied host-side.

    trace_log — optional list appended to at TRACE time (not per call);
    tests assert the serving loop compiles this step exactly once."""
    def decode_step(params, batch, cache):
        if trace_log is not None:
            trace_log.append(jax.tree.map(jnp.shape, batch))
        logits, new_cache, _ = zoo.forward(params, cfg, batch, mode="decode",
                                           cache=cache)
        logits = logits[:, -1]
        if not greedy:
            return logits, new_cache
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if tok.ndim > 1:                     # audio: (B, K) -> first codebook
            tok = tok[:, 0]
        return tok, new_cache
    return decode_step


def make_inl_train_step(cfg, optimizer):
    """The paper's scheme on this architecture (core/inl_llm)."""
    def inl_step(params, opt_state, batch, rng):
        (loss, metrics), grads = jax.value_and_grad(
            inl_llm.loss_fn, has_aux=True)(params, cfg, batch, rng)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, metrics
    return inl_step


def make_scan_train_step(cfg, optimizer, *, scheme: str = "standard",
                         microbatches: int = 1, donate: bool = None):
    """K optimizer steps in ONE jitted `jax.lax.scan`, with the (params,
    opt_state) buffers donated — per-step Python dispatch and the
    params/opt_state copy at every update both disappear.

    standard scheme: (params, opt_state, batches) -> (params, opt_state,
    stacked metrics), where `batches` is the usual batch pytree with an
    extra leading K axis.  inl scheme additionally takes `rngs` (K, 2)
    PRNG keys, one per step.

    donate=None donates only on accelerators (CPU XLA cannot alias the
    buffers and would just warn)."""
    if donate is None:
        donate = jax.default_backend() != "cpu"
    donate_args = (0, 1) if donate else ()

    if scheme == "inl":
        inner = make_inl_train_step(cfg, optimizer)

        def epoch(params, opt_state, batches, rngs):
            def body(carry, x):
                batch, rng = x
                p, o, m = inner(carry[0], carry[1], batch, rng)
                return (p, o), m
            (p, o), ms = jax.lax.scan(body, (params, opt_state),
                                      (batches, rngs))
            return p, o, ms
    else:
        inner = make_train_step(cfg, optimizer, microbatches=microbatches)

        def epoch(params, opt_state, batches):
            def body(carry, batch):
                p, o, m = inner(carry[0], carry[1], batch)
                return (p, o), m
            (p, o), ms = jax.lax.scan(body, (params, opt_state), batches)
            return p, o, ms

    return jax.jit(epoch, donate_argnums=donate_args)


def default_optimizer(cfg, total_steps: int = 10_000):
    sched = optim_lib.warmup_cosine_schedule(3e-4, min(200, total_steps // 10 + 1),
                                             total_steps)
    return optim_lib.adamw(sched, weight_decay=0.1, clip_norm=1.0)
