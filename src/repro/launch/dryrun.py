import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, SPMD-partitions, and compiles for the production meshes,
and extract the roofline terms from the compiled artifacts.

The two lines above MUST stay the first statements in this module — jax locks
the device count at first init, and only the dry-run may see 512 placeholder
devices (smoke tests and benches see the real 1-CPU environment).

Per combination this driver lowers:
  1. the FULL model with scanned layers  -> memory_analysis (fits?),
     compile-success, collective schedule;
  2. 1-period and 2-period UNROLLED variants -> scan-compensated FLOPs /
     bytes / collective-bytes (cost_analysis counts a scan body once):
         cost(k) = fixed + k*body  =>  total = fixed + n_periods*body.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single --out experiments/dryrun
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax

from repro.configs import (INPUT_SHAPES, arch_for_shape, get_config,
                           list_archs)
from repro.launch import mesh as mesh_lib
from repro.launch import sharding, steps
from repro.models import transformer, zoo
from repro.roofline import analysis as roofline


def _cost_record(compiled, chips: int) -> dict:
    """cost_analysis() on an SPMD-partitioned module reports the PER-DEVICE
    program (verified: global/256 for a 256-way mesh) — scale to fleet totals
    so the roofline formulas (X / (chips * rate)) apply as written."""
    ca = compiled.cost_analysis() or {}
    coll = roofline.collective_bytes(compiled.as_text())
    coll = {k: v * chips for k, v in coll.items()}
    return {"flops": float(ca.get("flops", 0.0)) * chips,
            "hbm_bytes": float(ca.get("bytes accessed", 0.0)) * chips,
            "coll": coll}


def _variant(cfg, periods: int, *, cost_oracle: bool = False):
    """Unrolled k-period model for scan compensation.  cost_oracle=True
    additionally un-scans attention tiles and the CE chunking (full-sequence
    blocks) so NO FLOPs hide inside inner scan bodies — such a variant is
    never executed, only lowered for cost_analysis (its 'bytes accessed'
    over-counts the never-materialised score tensors, so bytes are taken
    from the realistic variant instead)."""
    big = 1 << 30
    kw = dict(attn_block_q=big, attn_block_k=big, ce_chunk=big) \
        if cost_oracle else {}
    pat = transformer.block_pattern(cfg)
    return dataclasses.replace(
        cfg, num_layers=cfg.moe.first_dense_layers + periods * len(pat),
        scan_layers=False, **kw)


DEFAULT_MICROBATCHES = 8


def lower_step(cfg, shape, mesh, *, microbatches: int = None):
    """Build shardings and lower the step for (cfg, shape) on mesh.
    Returns the lowered computation."""
    params_shape = jax.eval_shape(functools.partial(zoo.init_params, cfg),
                                  jax.random.PRNGKey(0))
    p_sh = sharding.param_shardings(params_shape, mesh)
    specs = zoo.input_specs(cfg, shape)
    b_sh = sharding.batch_shardings(specs, mesh)
    # set_mesh (not `with mesh:`) so get_abstract_mesh() works inside traced
    # code (the shard_map MoE and the int8 wire read the axis names)
    jax.sharding.set_mesh(mesh)
    if True:
        if shape.mode == "train":
            if microbatches is None:
                microbatches = DEFAULT_MICROBATCHES \
                    if shape.global_batch % DEFAULT_MICROBATCHES == 0 else 1
            opt = steps.default_optimizer(cfg)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            o_sh = sharding.opt_state_shardings(opt_shape, p_sh, mesh)
            fn = steps.make_train_step(cfg, opt, microbatches=microbatches)
            return jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                           out_shardings=(p_sh, o_sh, None)) \
                .lower(params_shape, opt_shape, specs)
        if shape.mode == "prefill":
            fn = steps.make_prefill_step(cfg)
            return jax.jit(fn, in_shardings=(p_sh, b_sh),
                           out_shardings=None).lower(params_shape, specs)
        # decode: unrolled layers — the per-token graph is small, unrolling
        # removes the scan's ys staging copy of the KV cache (measured:
        # 17.3 -> 10.2 GB/device at 32k) and makes cost_analysis exact.
        cfg = dataclasses.replace(cfg, scan_layers=False)
        cache_shape = jax.eval_shape(
            functools.partial(zoo.make_cache, cfg, shape.global_batch,
                              shape.seq_len))
        c_sh = sharding.cache_shardings(cache_shape, mesh)
        fn = steps.make_decode_step(cfg)
        # donate the cache: in-place ring-buffer update, no second copy
        return jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh),
                       out_shardings=(None, c_sh), donate_argnums=(2,)) \
            .lower(params_shape, specs, cache_shape)


def lower_inl_step(cfg, shape, mesh, *, rng_dummy=None):
    """Lower the paper-mode (INL) train step on the client mesh: encoder
    params + per-node views sharded over 'client'; only the bottleneck
    latents u_j / error chunks delta_j cross that boundary (int8 wire when
    cfg.inl.link_bits <= 8)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import inl_llm
    from repro import optim as optim_lib

    params_shape = jax.eval_shape(functools.partial(inl_llm.init, cfg),
                                  jax.random.PRNGKey(0))
    p_sh = sharding.param_shardings(params_shape, mesh, client_axis=True)
    specs = inl_llm.input_specs(cfg, shape)
    b_sh = sharding.batch_shardings(specs, mesh)
    rng_spec = jax.ShapeDtypeStruct((2,), jax.numpy.uint32)
    opt = optim_lib.adamw(1e-4)
    opt_shape = jax.eval_shape(opt.init, params_shape)
    o_sh = sharding.opt_state_shardings(opt_shape, p_sh, mesh)
    fn = steps.make_inl_train_step(cfg, opt)
    # set_mesh (not the legacy `with mesh:`) so get_abstract_mesh() inside
    # the traced step sees the axis names — the int8 wire needs them to pin
    # its boundary shardings (core/linkmodel.wire_concat)
    jax.sharding.set_mesh(mesh)
    return jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh,
                                     NamedSharding(mesh, P())),
                   out_shardings=(p_sh, o_sh, None)) \
        .lower(params_shape, opt_shape, specs, rng_spec)


def run_inl(arch: str, shape_name: str = "train_4k", *,
            link_bits: int = 16) -> dict:
    """INL-mode dry-run record for one arch (client mesh, single pod)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_for_shape(get_config(arch), shape)
    cfg = dataclasses.replace(
        cfg, inl=dataclasses.replace(cfg.inl, link_bits=link_bits))
    mesh = mesh_lib.make_inl_mesh(cfg.inl.num_nodes)
    chips = mesh.size
    t0 = time.time()
    compiled = lower_inl_step(cfg, shape, mesh).compile()
    ma = compiled.memory_analysis()
    rec = {"arch": arch, "shape": shape_name, "mesh": "inl-single",
           "link_bits": link_bits, "chips": chips,
           "compile_s": round(time.time() - t0, 1),
           "memory": {"per_device_bytes": (ma.argument_size_in_bytes
                                           + ma.temp_size_in_bytes)},
           "cost": _cost_record(compiled, chips)}
    return rec


def run_one(arch: str, shape_name: str, mesh_name: str, *,
            with_compensation: bool = True) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_for_shape(get_config(arch), shape)
    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": chips, "status": "ok"}
    t0 = time.time()

    # ---- full scanned model: compile + memory analysis
    lowered = lower_step(cfg, shape, mesh)
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
    }
    per_device = (ma.argument_size_in_bytes + ma.temp_size_in_bytes)
    rec["memory"]["per_device_bytes"] = per_device
    rec["memory"]["fits_hbm"] = bool(per_device <= roofline.HW.hbm_bytes)
    full = _cost_record(compiled, chips)
    rec["raw_cost"] = full

    # ---- scan compensation via 1- and 2-period unrolled variants:
    # FLOPs from the cost-oracle variants (nothing hidden in scans), bytes
    # and collectives from the realistic variants; train variants run
    # microbatches=1 so the microbatch scan does not hide per-step cost.
    # Decode lowers unrolled already -> its cost_analysis is exact.
    nper = transformer.num_periods(cfg)
    if with_compensation and shape.mode != "decode":
        c1 = _cost_record(lower_step(_variant(cfg, 1), shape, mesh,
                                     microbatches=1).compile(), chips)
        c2 = _cost_record(lower_step(_variant(cfg, 2), shape, mesh,
                                     microbatches=1).compile(), chips)
        f1 = _cost_record(lower_step(_variant(cfg, 1, cost_oracle=True),
                                     shape, mesh, microbatches=1).compile(),
                          chips)
        f2 = _cost_record(lower_step(_variant(cfg, 2, cost_oracle=True),
                                     shape, mesh, microbatches=1).compile(),
                          chips)

        def comp(a, b):
            return a + (nper - 1) * max(b - a, 0.0)

        flops = comp(f1["flops"], f2["flops"])
        hbm = comp(c1["hbm_bytes"], c2["hbm_bytes"])
        coll_total = comp(c1["coll"]["total"], c2["coll"]["total"])
        coll_by_kind = {k: comp(c1["coll"][k], c2["coll"][k])
                        for k in c1["coll"] if k != "total"}
    else:
        flops, hbm = full["flops"], full["hbm_bytes"]
        coll_total = full["coll"]["total"]
        coll_by_kind = {k: v for k, v in full["coll"].items() if k != "total"}

    rec["cost"] = {"flops": flops, "hbm_bytes": hbm,
                   "coll_bytes": coll_total, "coll_by_kind": coll_by_kind}
    rec["roofline"] = roofline.analyze(
        {"flops": flops, "hbm_bytes": hbm, "coll_bytes": coll_total},
        cfg, shape, chips)
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-compensation", action="store_true")
    ap.add_argument("--inl", action="store_true",
                    help="lower the paper-mode INL train step instead "
                         "(client mesh; train_4k; link_bits 16 and 8)")
    args = ap.parse_args()

    if args.inl:
        os.makedirs(args.out, exist_ok=True)
        archs = list_archs() if args.arch == "all" else args.arch.split(",")
        failures = 0
        for arch in archs:
            for bits in (16, 8):
                tag = f"{arch}_inl_train_4k_b{bits}"
                try:
                    rec = run_inl(arch, link_bits=bits)
                    c = rec["cost"]["coll"]
                    print(f"[ok] {tag}: coll_total={c['total']:.3e} "
                          f"ag={c['all-gather']:.3e} "
                          f"mem/dev={rec['memory']['per_device_bytes']/1e9:.1f}GB",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    rec = {"arch": arch, "status": "fail",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    print(f"[FAIL] {tag}: {rec['error']}", flush=True)
                with open(os.path.join(args.out, f"{tag}.json"), "w") as f:
                    json.dump(rec, f, indent=2)
        raise SystemExit(1 if failures else 0)

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" \
        else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch}_{shape_name}_{mesh_name}"
                try:
                    rec = run_one(arch, shape_name, mesh_name,
                                  with_compensation=not args.no_compensation)
                    r = rec["roofline"]
                    mem_gb = rec["memory"]["per_device_bytes"] / 1e9
                    print(f"[ok] {tag}: compile={rec['compile_s']}s "
                          f"mem/dev={mem_gb:.2f}GB "
                          f"fits={rec['memory']['fits_hbm']} "
                          f"compute={r['compute_s']:.3e}s "
                          f"memory={r['memory_s']:.3e}s "
                          f"coll={r['collective_s']:.3e}s "
                          f"dominant={r['dominant']}", flush=True)
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures += 1
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "fail",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}",
                          flush=True)
                with open(os.path.join(args.out, f"{tag}.json"), "w") as f:
                    json.dump(rec, f, indent=2)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
