"""Sharding rules: parameter / batch / cache / optimizer-state layouts.

Megatron-style tensor parallelism on the 'model' axis + FSDP-style parameter
sharding on the 'data' axis, expressed as path-keyed PartitionSpec rules over
NEGATIVE dim indices so stacked (scan) leading axes never shift a rule.

    column weights  (…, d_in, d_out): d_out -> model, d_in -> data (FSDP)
    row weights     (…, d_in, d_out): d_in -> model, d_out -> data (FSDP)
    expert weights  (…, E, d, f):     E -> model (expert parallel), d -> data
    embeddings      (…, V, d):        V -> model, d -> data
    INL encoders    (J, …):           J -> client (paper mode)
    norms / biases / scalars:         replicated

Every rule is divisibility-guarded: a dim that does not divide by the mesh
axis size stays replicated on that axis (e.g. qwen's 20 heads on a 16-way
model axis -> attention stays model-replicated; the §Perf pass revisits this
with sequence parallelism).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# path-name classification ---------------------------------------------------

_COLUMN = {"wq", "wk", "wv", "wi", "wg", "up", "wx", "in_proj", "wq_a",
           "wq_b", "wkv_a", "wk_b", "wv_b", "unembed", "heads", "w_if",
           "mu", "logvar"}
_ROW = {"wo", "down", "out_proj", "adapter"}
_EMBED = {"embed"}
_MOE_STACK = {"moe"}
_REPLICATE = {"router", "conv", "r", "A_log", "D", "dt_bias", "norm",
              "q_norm", "kv_norm", "attn_norm", "ffn_norm", "final_norm",
              "scale", "bias", "b"}


def _axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _assign(spec: list, axis_idx: int, mesh_axis: str, dim: int,
            mesh) -> None:
    size = _axis_size(mesh, mesh_axis)
    if size > 1 and dim % size == 0 and spec[axis_idx] is None:
        spec[axis_idx] = mesh_axis


def _path_names(path) -> list:
    return [p.key for p in path if hasattr(p, "key")]


def param_spec(path, leaf, mesh, *, fsdp: bool = True,
               client_axis: bool = False) -> P:
    names = _path_names(path)
    nd = leaf.ndim
    spec: list = [None] * nd

    if client_axis and names and names[0] == "encoders" and nd >= 1:
        # INL: leading J axis of stacked per-node encoders
        if leaf.shape[0] % _axis_size(mesh, "client") == 0:
            spec[0] = "client"

    def done():
        return P(*spec)

    if nd == 0 or not names:
        return done()
    last = names[-1]
    parents = set(names[:-1])

    if last in _REPLICATE or (last == "b") or nd == 1:
        return done()

    is_moe = bool(parents & _MOE_STACK) and last in {"wi", "wg", "wo"} and nd >= 3
    if is_moe:
        _assign(spec, nd - 3, "model", leaf.shape[nd - 3], mesh)   # experts
        if fsdp:
            _assign(spec, nd - 2, "data", leaf.shape[nd - 2], mesh)
        return done()

    if last in _EMBED or (names and names[-2:] == ["embed", "w"]) \
            or "embed" in parents:
        _assign(spec, nd - 2, "model", leaf.shape[nd - 2], mesh)    # vocab
        if fsdp:
            _assign(spec, nd - 1, "data", leaf.shape[nd - 1], mesh)
        return done()

    owner = names[-2] if last == "w" and len(names) >= 2 else last
    if owner in _COLUMN:
        _assign(spec, nd - 1, "model", leaf.shape[nd - 1], mesh)
        if fsdp:
            _assign(spec, nd - 2, "data", leaf.shape[nd - 2], mesh)
        return done()
    if owner in _ROW:
        _assign(spec, nd - 2, "model", leaf.shape[nd - 2], mesh)
        if fsdp:
            _assign(spec, nd - 1, "data", leaf.shape[nd - 1], mesh)
        return done()
    # default: FSDP the largest dim on data
    if fsdp and nd >= 2:
        big = int(np.argmax(leaf.shape))
        _assign(spec, big, "data", leaf.shape[big], mesh)
    return done()


def param_shardings(params_shape, mesh, *, fsdp: bool = True,
                    client_axis: bool = False):
    """params_shape: pytree of ShapeDtypeStructs (or arrays)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, mesh, fsdp=fsdp,
                             client_axis=client_axis)),
        params_shape)


# ---------------------------------------------------------------------------
# Batch / cache / optimizer state
# ---------------------------------------------------------------------------

def _dp(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_spec(name: str, leaf, mesh) -> P:
    dp = _dp(mesh)
    if leaf.ndim == 0:
        return P()
    batch = leaf.shape[0]
    total = int(np.prod([_axis_size(mesh, a) for a in dp]))
    if total > 1 and batch % total == 0:
        return P(dp, *([None] * (leaf.ndim - 1)))
    # long_500k: batch=1 -> shard the sequence axis instead where possible
    if leaf.ndim >= 2 and leaf.shape[1] % total == 0 and total > 1:
        return P(None, dp, *([None] * (leaf.ndim - 2)))
    return P(*([None] * leaf.ndim))


def batch_shardings(batch_specs, mesh):
    return {k: NamedSharding(mesh, batch_spec(k, v, mesh))
            for k, v in batch_specs.items()}


def wire_specs(mesh):
    """PartitionSpecs pinning the INL cut-layer wire tensors in GSPMD (jit)
    paths — (J, B, S, d_b) latents or their (J, B, S, W) packed codeword
    lanes; the same specs serve both since the last axis is unsharded:

        client_spec    'client' on the leading J axis — the tensor BEFORE
                       the link (each node holds its own chunk);
        gathered_spec  client axis replicated — constraining the quantized/
                       packed tensor to this spec IS the link gather, and
                       pinning it there keeps GSPMD from gathering the wide
                       float tensor instead (linkmodel.wire_concat /
                       packed_wire_concat).

    Returns (gathered_spec, client_spec), both None when the mesh has no
    'client' axis (single-host runs)."""
    if mesh is None or "client" not in mesh.axis_names:
        return None, None
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    gathered = P(None, dp or None, None, None)
    client = P("client", dp or None, None, None)
    return gathered, client


def scheme_batch_shardings(mesh, num_clients: int, batch_size: int):
    """Shardings for the whole-epoch scan xs of a scheme round
    (core/schemes/runner.py): views (K, R, J, B, ...), labels (K, R, B),
    rngs (K, 2) — J on 'client', B on 'data', scan/round axes and keys
    replicated.  Divisibility-guarded like every other rule here: an axis
    that does not divide stays replicated."""
    c = "client" if (_axis_size(mesh, "client") > 1
                     and num_clients % _axis_size(mesh, "client") == 0) \
        else None
    d = "data" if (_axis_size(mesh, "data") > 1
                   and batch_size % _axis_size(mesh, "data") == 0) else None
    return (NamedSharding(mesh, P(None, None, c, d)),
            NamedSharding(mesh, P(None, None, d)),
            NamedSharding(mesh, P()))


_CACHE_BATCH_AXIS = {"k": -4, "v": -4, "c_kv": -3, "k_rope": -3,
                     "conv": -3, "ssm": -4, "C": -4, "n": -3, "m": -2,
                     "c": -3, "h": -3}
_CACHE_TIME_AXIS = {"k": -3, "v": -3, "c_kv": -2, "k_rope": -2}
_CACHE_HEAD_AXIS = {"k": -2, "v": -2, "ssm": -3, "C": -3, "n": -2, "m": -1,
                    "c": -2, "h": -2}


def cache_spec(path, leaf, mesh) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    dp = _dp(mesh)
    total = int(np.prod([_axis_size(mesh, a) for a in dp]))
    nd = leaf.ndim
    spec: list = [None] * nd
    ba = _CACHE_BATCH_AXIS.get(name)
    if ba is not None and -ba <= nd:
        bdim = leaf.shape[ba]
        if total > 1 and bdim % total == 0:
            spec[ba % nd] = dp
        elif name in _CACHE_TIME_AXIS:
            # batch=1 long-context: shard the cache TIME axis over data
            ta = _CACHE_TIME_AXIS[name] % nd
            if leaf.shape[ta] % total == 0 and total > 1:
                spec[ta] = dp
    ha = _CACHE_HEAD_AXIS.get(name)
    msize = _axis_size(mesh, "model")
    if ha is not None and -ha <= nd:
        hdim = leaf.shape[ha]
        if msize > 1 and hdim % msize == 0 and spec[ha % nd] is None:
            spec[ha % nd] = "model"
            return P(*spec)
    # kv heads don't divide the model axis (MHA archs like qwen's 20 heads):
    # shard the cache TIME axis over 'model' instead — flash-decode style
    # partial-softmax with a cross-shard reduction, instead of replicating a
    # 100+ GB/device cache (measured; EXPERIMENTS.md §Perf).
    if name in _CACHE_TIME_AXIS and msize > 1:
        ta = _CACHE_TIME_AXIS[name] % nd
        if spec[ta] is None and leaf.shape[ta] % msize == 0:
            spec[ta] = "model"
    return P(*spec)


def cache_shardings(cache_specs, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, cache_spec(path, leaf, mesh)),
        cache_specs)


def opt_state_shardings(opt_shape, param_shardings_tree, mesh, *,
                        zero1: bool = True):
    """m/v/master mirror the param layout; scalars replicated.  With zero1,
    any still-replicated large dim is additionally sharded over 'data'
    (ZeRO-1: optimizer states fully partitioned)."""

    def one(path, leaf):
        names = _path_names(path)
        if leaf.ndim == 0 or (names and names[0] == "step"):
            return NamedSharding(mesh, P())
        # the state mirrors the param at path[1:] (strip the m/v/master key)
        spec = list(param_spec(path[1:], leaf, mesh))
        if zero1:
            dp = _dp(mesh)
            used = {a for s in spec if s is not None
                    for a in (s if isinstance(s, tuple) else (s,))}
            free = tuple(a for a in dp if a not in used)
            total = int(np.prod([_axis_size(mesh, a) for a in free])) \
                if free else 1
            if total > 1:
                for ax in range(leaf.ndim):
                    if spec[ax] is None and leaf.shape[ax] % total == 0:
                        spec[ax] = free if len(free) > 1 else free[0]
                        break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, opt_shape)
