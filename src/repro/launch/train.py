"""Training driver.

On real hardware this runs under the production mesh; on this container it
runs reduced (smoke) configs on the host devices.  Supports three schemes:

    standard  plain data/tensor-parallel LM training of the selected arch
    inl       the paper's in-network learning split of the same arch
              (J encoder nodes + fusion decoder, eq.-6 loss)

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50 --batch 8 --seq 128 [--scheme inl] [--ckpt-dir ckpts]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint, optim
from repro.configs import get_config, get_smoke_config
from repro.core import inl_llm
from repro.data import prefetch
from repro.data import tokens as token_data
from repro.launch import steps as steps_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--scheme", default="standard",
                    choices=["standard", "inl"])
    ap.add_argument("--learned-prior", action="store_true",
                    help="inl scheme: learned per-node Gaussian priors "
                         "Q_psi_j in the eq.-(6) rate (fused kernel path)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--scan-steps", type=int, default=10,
                    help="optimizer steps per jitted lax.scan call (donated "
                         "params/opt_state buffers; 1 = step-per-dispatch)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="stacked scan groups kept in flight host->device "
                         "(data/prefetch.py); 1 disables the overlap")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest --ckpt-dir checkpoint (params "
                         "AND optimizer state) and fast-forward the "
                         "data/rng streams, finishing the schedule "
                         "bit-identically to an uninterrupted run "
                         "(repro/chaos.py SIGKILLs + asserts it)")
    ap.add_argument("--log-every", type=int, default=10,
                    help="(superseded: metrics are logged once per scan "
                         "group, i.e. every --scan-steps steps)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32") if args.smoke else cfg

    key = jax.random.PRNGKey(args.seed)
    optimizer = optim.adamw(
        optim.warmup_cosine_schedule(args.lr, max(args.steps // 10, 1),
                                     args.steps), weight_decay=0.1,
        clip_norm=1.0)

    if args.scheme == "inl":
        from repro.models import transformer
        # the INL split needs >= encoder_layers + 1 periods; smoke configs
        # have exactly one — grow the reduced model by one period
        pat = transformer.block_pattern(cfg)
        need = (cfg.inl.encoder_layers + 1) * len(pat) \
            + cfg.moe.first_dense_layers
        if cfg.num_layers < need:
            cfg = dataclasses.replace(cfg, num_layers=need)
        if args.learned_prior:
            cfg = dataclasses.replace(
                cfg, inl=dataclasses.replace(cfg.inl, learned_prior=True))
        params = inl_llm.init(cfg, key)
        opt_state = optimizer.init(params)
    else:
        from repro.models import zoo
        params = zoo.init_params(cfg, key)
        opt_state = optimizer.init(params)
    epoch_fn = steps_lib.make_scan_train_step(
        cfg, optimizer, scheme=args.scheme, microbatches=args.microbatches)

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} scheme={args.scheme} params={n_params:,} "
          f"devices={jax.device_count()}")

    data = token_data.lm_batches(cfg, args.batch, args.seq, steps=args.steps,
                                 seed=args.seed)
    rng = jax.random.PRNGKey(args.seed + 1)
    K = max(args.scan_steps, 1)
    step = 0
    if args.resume and args.ckpt_dir:
        latest = checkpoint.latest_step(args.ckpt_dir)
        if latest is not None:
            restored, _ = checkpoint.restore(
                args.ckpt_dir, {"params": params, "opt": opt_state},
                step=latest)
            params, opt_state = restored["params"], restored["opt"]
            step = latest
            # fast-forward the streams through the completed work: the data
            # generator is deterministic per (cfg, seed), and the inl rng
            # splits once per scan group — replaying both makes the resumed
            # subkeys (and so the trajectory) the uninterrupted run's
            for _ in range(step):
                next(data)
            if args.scheme == "inl":
                for _ in range((step + K - 1) // K):
                    rng, _ = jax.random.split(rng)
            print(f"resumed from step {step} ({args.ckpt_dir})")
    t0 = time.time()
    history = []

    def run_group(params, opt_state, rng, batches, k):
        # one jitted scan over the group: K optimizer steps, zero
        # per-step dispatch, donated params/opt_state; `batches` arrives
        # stacked AND device-resident from the prefetcher
        nonlocal step
        if args.scheme == "inl":
            rng, sub = jax.random.split(rng)
            rngs = jax.random.split(sub, k)
            params, opt_state, ms = epoch_fn(params, opt_state, batches,
                                             rngs)
        else:
            params, opt_state, ms = epoch_fn(params, opt_state, batches)
        prev_step, step = step, step + k
        last = jax.tree.map(lambda x: x[-1], ms)
        m = {k: float(v) for k, v in last.items() if jnp.ndim(v) == 0}
        m["step"] = step - 1
        m["wall_s"] = round(time.time() - t0, 1)
        history.append(m)
        print(json.dumps({k: (round(v, 4) if isinstance(v, float) else v)
                          for k, v in m.items()}), flush=True)
        # checkpoint when the group crossed a --ckpt-every boundary (step
        # advances by the group size, so an exact-multiple test would skip)
        if args.ckpt_dir and args.ckpt_every and \
                step // args.ckpt_every > prev_step // args.ckpt_every:
            checkpoint.save(args.ckpt_dir, step,
                            {"params": params, "opt": opt_state},
                            extra={"arch": cfg.name, "scheme": args.scheme})
        return params, opt_state, rng

    # the scan now crosses the data-loading boundary: groups are stacked
    # host-side and device_put by the double-buffered prefetcher, so the
    # transfer of group g+1 overlaps the scan executing group g
    stacked = (steps_lib.stack_batches(g)
               for g in steps_lib.grouped_batches(data, K))
    for batches in prefetch.prefetch_to_device(stacked,
                                               size=max(args.prefetch, 1)):
        k = jax.tree.leaves(batches)[0].shape[0]
        params, opt_state, rng = run_group(params, opt_state, rng, batches,
                                           k)
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps,
                        {"params": params, "opt": opt_state},
                        extra={"arch": cfg.name, "scheme": args.scheme})
    if history:
        first, last = history[0], history[-1]
        key_metric = "loss" if "loss" in last else "ce"
        print(f"loss {first[key_metric]:.4f} -> {last[key_metric]:.4f} "
              f"({args.steps} steps, {time.time()-t0:.1f}s)")
    return history


if __name__ == "__main__":
    main()
