"""Pure-jnp oracles for every Pallas kernel.  Deliberately naive and
obviously-correct; used by tests/test_kernels.py for allclose sweeps and by
ops.py as the CPU fallback for tiny shapes.

For the fused cut layer this module carries three things:

  * `cutlayer_ref` / `cutlayer_prior_ref` — the UNFUSED 3-pass formulation
    (sample, quantize, rate) written with `stop_gradient` straight-through
    semantics so plain `jax.grad` yields the ground-truth gradients the
    hand-written VJP in `inl_bottleneck.py` must match.  The `_prior_`
    variant evaluates the eq.-(6) rate against a learned diagonal-Gaussian
    prior Q_psi = N(prior_mu, exp(prior_logvar)) instead of N(0, I).
  * `cutlayer_fwd_ref` / `cutlayer_bwd_ref` — single-expression jnp
    implementations of the fused forward and the hand-derived backward.
    `inl_bottleneck.cutlayer_fused(impl="reference")` plugs these into the
    SAME `jax.custom_vjp` wrapper the Pallas path uses, so CPU CI exercises
    the exact code path that runs on TPU.
  * `cutlayer_prior_fwd_ref` / `cutlayer_prior_bwd_ref` — same pair for the
    learned-prior path.  Shapes are normalised by the caller to (J, T, d)
    latents against (J, d) per-node prior vectors; the backward also emits
    the prior gradients (dpmu, dplv), reduced over each node's rows.

All cut-layer entry points share a `mode` in {"sample", "analytic", "none"}:
the paper's per-sample eq.-(6) estimator, the closed-form Gaussian KL, or a
deterministic no-rate pass (rate == 0) used for split learning's
non-stochastic cut (eps == 0 -> u == quantize(mu)).

The link quantizer's value map (`quantize_value`, `QUANT_RANGE`) lives here
as the single source of truth shared by `core/linkmodel.py` and the kernels.

The PACKED WIRE FORMAT also lives here as jnp oracles: a quantized latent is
an integer codeword index in [0, 2^bits), and `pack_indices` /
`unpack_indices` move those `bits`-bit codewords in and out of uint32 lanes
(little-endian within each lane, `32 // bits` codewords per word, zero-padded
tail for odd d).  `dequantize_index(quantize_index(u))` equals
`quantize_value(u)` bit-for-bit — the packed wire is a pure re-encoding of
the dense quantized latent, so routing a collective over the packed buffer
cannot change a trajectory.  `cutlayer_pack_fwd_ref` is the oracle of the
pack-emitting fused forward kernel (u + packed codewords + rate in one
expression); `unpack_dequant_ref` is the fusion-center side.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

QUANT_RANGE = 4.0   # Gaussian bottlenecks: 4 sigma covers the latents


def quantize_value(u, bits: int, *, u_range: float = QUANT_RANGE):
    """Value map of the uniform link quantizer (no gradient semantics).

    bits >= 32 is the identity (full-precision link)."""
    if bits >= 32:
        return u
    levels = (1 << bits) - 1
    scale = levels / (2.0 * u_range)
    clipped = jnp.clip(u, -u_range, u_range)
    return jnp.round((clipped + u_range) * scale) / scale - u_range


# ---------------------------------------------------------------------------
# Packed wire format: bits-bit codeword indices in uint32 lanes
# ---------------------------------------------------------------------------

def vals_per_word(bits: int) -> int:
    """Codewords per uint32 lane (e.g. 16 at 2 bits, 4 at 8 bits; 10 at the
    odd 3-bit width — 2 lane bits are then padding)."""
    if not 1 <= bits <= 16:
        raise ValueError(f"packable link_bits must be in [1, 16], got {bits}")
    return 32 // bits


def packed_width(d: int, bits: int) -> int:
    """uint32 lanes per d-vector: ceil(d / vals_per_word)."""
    return -(-d // vals_per_word(bits))


def quantize_index(u, bits: int, *, u_range: float = QUANT_RANGE):
    """Codeword index of the uniform link quantizer: uint32 in [0, 2^bits).

    `dequantize_index(quantize_index(u, bits), bits)` reproduces
    `quantize_value(u, bits)` bit-for-bit (same fp32 expression order)."""
    levels = (1 << bits) - 1
    scale = levels / (2.0 * u_range)
    clipped = jnp.clip(u.astype(jnp.float32), -u_range, u_range)
    return jnp.round((clipped + u_range) * scale).astype(jnp.uint32)


def dequantize_index(idx, bits: int, *, dtype=jnp.float32,
                     u_range: float = QUANT_RANGE):
    """Value of a codeword index — the fusion-center side of the link."""
    levels = (1 << bits) - 1
    scale = levels / (2.0 * u_range)
    return (idx.astype(jnp.float32) / scale - u_range).astype(dtype)


def pack_indices(idx, bits: int):
    """(..., d) uint32 codewords -> (..., W) uint32 lanes.

    Little-endian within the lane (codeword k at bit offset k*bits); a tail
    that does not fill the last lane is zero-padded."""
    vpw = vals_per_word(bits)
    d = idx.shape[-1]
    W = packed_width(d, bits)
    pad = W * vpw - d
    if pad:
        idx = jnp.pad(idx, [(0, 0)] * (idx.ndim - 1) + [(0, pad)])
    grouped = idx.astype(jnp.uint32).reshape(idx.shape[:-1] + (W, vpw))
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * jnp.uint32(bits))
    return jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)


def unpack_indices(packed, d: int, bits: int):
    """Inverse of pack_indices: (..., W) uint32 lanes -> (..., d) codewords."""
    vpw = vals_per_word(bits)
    mask = jnp.uint32((1 << bits) - 1)
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * jnp.uint32(bits))
    ext = (packed[..., None] >> shifts) & mask        # (..., W, vpw)
    return ext.reshape(packed.shape[:-1] + (-1,))[..., :d]


def pack_values_ref(u, bits: int):
    """Quantized values -> packed codeword lanes (one fused expression).

    For u already on the `bits`-bit quantizer grid (any cut-layer output
    with link_bits == bits) this is a lossless re-encoding."""
    return pack_indices(quantize_index(u, bits), bits)


def unpack_dequant_ref(packed, d: int, bits: int, *, dtype=jnp.float32):
    """Packed codeword lanes -> dense quantized values (fusion-center side)."""
    return dequantize_index(unpack_indices(packed, d, bits), bits,
                            dtype=dtype)


def cutlayer_pack_fwd_ref(mu, logvar, eps, bits: int, mode: str):
    """Pack-emitting fused forward: one expression yielding the dense
    quantized latent u, its bit-packed codewords, AND the per-row rate.

    Bit-identical to `cutlayer_fwd_ref` on (u, rate): the codeword index is
    the shared intermediate (u == dequantize_index(idx)), so the packed
    lanes are a free extra output of the same pass, not a second quantizer.
    Requires bits <= 16 (a packable width)."""
    muf = mu.astype(jnp.float32)
    lv = logvar.astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    pre = muf + sigma * eps.astype(jnp.float32)
    idx = quantize_index(pre, bits)
    u = dequantize_index(idx, bits)
    packed = pack_indices(idx, bits)
    if mode == "sample":
        rate = 0.5 * jnp.sum(u * u - (u - muf) ** 2 * jnp.exp(-lv) - lv,
                             axis=-1)
    elif mode == "analytic":
        rate = 0.5 * jnp.sum(jnp.exp(lv) + muf * muf - 1.0 - lv, axis=-1)
    else:
        rate = jnp.zeros(u.shape[:-1], jnp.float32)
    return u.astype(mu.dtype), packed, rate


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    """Naive masked softmax attention.  q: (B,Sq,H,Dh); k/v: (B,Sk,KV,Dh)."""
    B, Sq, H, Dh = q.shape
    _, Sk, KV, _ = k.shape
    g = H // KV
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scale = 1.0 / np.sqrt(Dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None] + q_offset
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def bottleneck_ref(mu, logvar, eps):
    """u = mu + sigma*eps; kl = KL(N(mu,sigma^2) || N(0,I)) per row."""
    lv = logvar.astype(jnp.float32)
    muf = mu.astype(jnp.float32)
    u = muf + jnp.exp(0.5 * lv) * eps.astype(jnp.float32)
    kl = 0.5 * jnp.sum(jnp.exp(lv) + muf * muf - 1.0 - lv, axis=-1)
    return u.astype(mu.dtype), kl


def cutlayer_ref(mu, logvar, eps, *, link_bits: int = 32,
                 rate_estimator: str = "sample"):
    """Unfused 3-pass cut layer, ground truth for the fused kernel.

    u    = quantize_st(mu + exp(logvar/2) * eps)      (straight-through)
    rate = log P(u|x) - log Q(u)   ("sample", eq. 6, standard-normal prior;
           the log(2 pi) terms cancel) or the analytic Gaussian KL.

    Differentiable by plain AD: the quantizer uses `stop_gradient`, so
    `jax.grad` through this function defines the gradients — including the
    eq.-(10) error-vector + rate split — that the hand-written VJP in
    `inl_bottleneck.py` must reproduce."""
    muf = mu.astype(jnp.float32)
    lv = logvar.astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    pre = muf + sigma * eps.astype(jnp.float32)
    q = quantize_value(pre, link_bits)
    u = pre + jax.lax.stop_gradient(q - pre)
    if rate_estimator == "sample":
        rate = 0.5 * jnp.sum(u * u - (u - muf) ** 2 * jnp.exp(-lv) - lv,
                             axis=-1)
    elif rate_estimator == "analytic":
        rate = 0.5 * jnp.sum(jnp.exp(lv) + muf * muf - 1.0 - lv, axis=-1)
    else:                                   # "none": deterministic cut
        rate = jnp.zeros(u.shape[:-1], jnp.float32)
    return u.astype(mu.dtype), rate


def cutlayer_prior_ref(mu, logvar, eps, prior_mu, prior_logvar, *,
                       link_bits: int = 32, rate_estimator: str = "sample"):
    """Unfused cut layer against a LEARNED Gaussian prior — AD ground truth
    for the fused learned-prior kernel, including the prior gradients.

    mu/logvar/eps: (..., d); prior_mu/prior_logvar: (d,) broadcast over the
    rows (per-node priors: call per node, or shape (J, 1, ..., d)-compatible).
    The log(2 pi) terms of log P - log Q cancel exactly as in the
    standard-normal case."""
    muf = mu.astype(jnp.float32)
    lv = logvar.astype(jnp.float32)
    pmu = prior_mu.astype(jnp.float32)
    plv = prior_logvar.astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    pre = muf + sigma * eps.astype(jnp.float32)
    q = quantize_value(pre, link_bits)
    u = pre + jax.lax.stop_gradient(q - pre)
    if rate_estimator == "sample":
        rate = 0.5 * jnp.sum((u - pmu) ** 2 * jnp.exp(-plv) + plv
                             - (u - muf) ** 2 * jnp.exp(-lv) - lv, axis=-1)
    elif rate_estimator == "analytic":
        rate = 0.5 * jnp.sum(plv - lv + (jnp.exp(lv) + (muf - pmu) ** 2)
                             * jnp.exp(-plv) - 1.0, axis=-1)
    else:
        rate = jnp.zeros(u.shape[:-1], jnp.float32)
    return u.astype(mu.dtype), rate


def cutlayer_fwd_ref(mu, logvar, eps, bits: int, mode: str):
    """Fused forward as one jnp expression (XLA compiles it to a single
    pass on CPU).  Must match `inl_bottleneck._cut_fwd_kernel` bit-for-bit
    in fp32 arithmetic order."""
    muf = mu.astype(jnp.float32)
    lv = logvar.astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    pre = muf + sigma * eps.astype(jnp.float32)
    u = quantize_value(pre, bits)
    if mode == "sample":
        rate = 0.5 * jnp.sum(u * u - (u - muf) ** 2 * jnp.exp(-lv) - lv,
                             axis=-1)
    elif mode == "analytic":
        rate = 0.5 * jnp.sum(jnp.exp(lv) + muf * muf - 1.0 - lv, axis=-1)
    else:
        rate = jnp.zeros(u.shape[:-1], jnp.float32)
    return u.astype(mu.dtype), rate


def cutlayer_bwd_ref(mu, logvar, eps, gu, grate, bits: int, mode: str):
    """Hand-derived fused backward (the paper's eq.-10 split).

    Inputs: residuals (mu, logvar, eps) and cotangents gu (rows, d) — the
    decoder error-vector chunk delta[j], straight-through through the
    quantizer — and grate (rows,) on the rate output.  With
    w = (u - mu) * exp(-logvar) (the whitened residual) and straight-through
    du/dpre = 1:

      sample:   dmu  = gu + grate * u
                dlv  = (gu + grate*(u - w)) * eps*sigma/2
                       + grate * ((u-mu)^2 exp(-lv) - 1) / 2
                deps = (gu + grate*(u - w)) * sigma
      analytic: dmu  = gu + grate * mu
                dlv  = gu * eps*sigma/2 + grate * (exp(lv) - 1) / 2
                deps = gu * sigma
      none:     dmu  = gu;  dlv = gu * eps*sigma/2;  deps = gu * sigma
                (the rate output is identically zero, so grate is unused)
    """
    muf = mu.astype(jnp.float32)
    lv = logvar.astype(jnp.float32)
    ef = eps.astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    gu = gu.astype(jnp.float32)
    gr = grate.astype(jnp.float32)[..., None]
    if mode == "sample":
        u = quantize_value(muf + sigma * ef, bits)
        w = (u - muf) * jnp.exp(-lv)
        g_pre = gu + gr * (u - w)
        dmu = gu + gr * u
        dlv = g_pre * (0.5 * sigma * ef) + gr * 0.5 * (w * (u - muf) - 1.0)
        deps = g_pre * sigma
    elif mode == "analytic":
        dmu = gu + gr * muf
        dlv = gu * (0.5 * sigma * ef) + gr * 0.5 * (jnp.exp(lv) - 1.0)
        deps = gu * sigma
    else:
        dmu = gu
        dlv = gu * (0.5 * sigma * ef)
        deps = gu * sigma
    return (dmu.astype(mu.dtype), dlv.astype(logvar.dtype),
            deps.astype(eps.dtype))


def cutlayer_prior_fwd_ref(mu, logvar, eps, pmu, plv, bits: int, mode: str):
    """Learned-prior fused forward.  mu/logvar/eps: (J, T, d); pmu/plv:
    (J, d) per-node prior mean / log-variance.  Returns (u (J,T,d),
    rate (J,T) fp32).

    The optimization barrier pins u to ONE materialised buffer (matching
    the Pallas path, where u is a real kernel output): the rate reduction
    here, the backward's error-vector pass, and its prior-gradient
    reductions all read that buffer.  Without it XLA duplicates the
    exp/quantize chain into every reduction fusion — a measured ~1.4x on
    the learned-prior backward on CPU."""
    muf = mu.astype(jnp.float32)
    lv = logvar.astype(jnp.float32)
    pm = pmu.astype(jnp.float32)[:, None, :]
    pv = plv.astype(jnp.float32)[:, None, :]
    sigma = jnp.exp(0.5 * lv)
    pre = muf + sigma * eps.astype(jnp.float32)
    u = jax.lax.optimization_barrier(quantize_value(pre, bits))
    if mode == "sample":
        rate = 0.5 * jnp.sum((u - pm) ** 2 * jnp.exp(-pv) + pv
                             - (u - muf) ** 2 * jnp.exp(-lv) - lv, axis=-1)
    else:                                   # "analytic"
        rate = 0.5 * jnp.sum(pv - lv + (jnp.exp(lv) + (muf - pm) ** 2)
                             * jnp.exp(-pv) - 1.0, axis=-1)
    return u.astype(mu.dtype), rate


def cutlayer_prior_bwd_ref(mu, logvar, eps, pmu, plv, u, gu, grate,
                           bits: int, mode: str):
    """Hand-derived learned-prior backward: the eq.-(10) split generalised
    to Q_psi = N(pmu, exp(plv)).  With wq = (u - pmu) * exp(-plv) (the
    prior-whitened residual) and w = (u - mu) * exp(-lv):

      sample:   g_pre = gu + grate * (wq - w)
                dmu   = g_pre + grate * w            (== gu + grate * wq)
                dlv   = g_pre * eps*sigma/2 + grate * (w*(u-mu) - 1)/2
                deps  = g_pre * sigma
                dpmu  = -sum_rows grate * wq
                dplv  =  sum_rows grate * (1 - wq*(u-pmu))/2
      analytic: with dm = (mu - pmu) * exp(-plv):
                dmu   = gu + grate * dm
                dlv   = gu * eps*sigma/2 + grate * (exp(lv-plv) - 1)/2
                deps  = gu * sigma
                dpmu  = -sum_rows grate * dm
                dplv  =  sum_rows grate
                         * (1 - (exp(lv)+(mu-pmu)^2) exp(-plv))/2

    The prior gradients reduce over each node's rows (axis 1).  `u` is the
    QUANTIZED forward output, saved as a residual: it is a live buffer
    anyway (the forward returns it), and reading it keeps the prior
    reductions' dependency cone to {u, grate} — recomputing u here instead
    makes XLA re-derive the whole exp/quantize chain inside each reduction
    fusion, a measured ~1.4x backward regression on CPU."""
    muf = mu.astype(jnp.float32)
    lv = logvar.astype(jnp.float32)
    ef = eps.astype(jnp.float32)
    pm = pmu.astype(jnp.float32)[:, None, :]
    pv = plv.astype(jnp.float32)[:, None, :]
    u = u.astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    gu = gu.astype(jnp.float32)
    gr = grate.astype(jnp.float32)[..., None]
    if mode == "sample":
        w = (u - muf) * jnp.exp(-lv)
        wq = (u - pm) * jnp.exp(-pv)
        g_pre = gu + gr * (wq - w)
        dmu = g_pre + gr * w
        dlv = g_pre * (0.5 * sigma * ef) + gr * 0.5 * (w * (u - muf) - 1.0)
        deps = g_pre * sigma
        c = gr * wq
        dpmu = -jnp.sum(c, axis=1)
        dplv = 0.5 * (jnp.sum(gr, axis=1) - jnp.sum(c * (u - pm), axis=1))
    else:                                   # "analytic"
        dm = (muf - pm) * jnp.exp(-pv)
        dmu = gu + gr * dm
        e_lp = jnp.exp(lv - pv)
        dlv = gu * (0.5 * sigma * ef) + gr * 0.5 * (e_lp - 1.0)
        deps = gu * sigma
        c = gr * dm
        dpmu = -jnp.sum(c, axis=1)
        # (exp(lv) + (mu-pm)^2) e^{-pv} == e_lp + dm*(mu-pm)
        dplv = 0.5 * (jnp.sum(gr, axis=1) - jnp.sum(gr * e_lp, axis=1)
                      - jnp.sum(c * (muf - pm), axis=1))
    return (dmu.astype(mu.dtype), dlv.astype(logvar.dtype),
            deps.astype(eps.dtype), dpmu.astype(pmu.dtype),
            dplv.astype(plv.dtype))


def ssd_scan_ref(x, dt, a, bm, cm, dskip):
    """Exact sequential SSM recurrence (the definition, not the chunked form).

    h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t^T;  y_t = C_t h_t + D x_t.
    x: (B,S,H,P); dt: (B,S,H); a: (H,); bm/cm: (B,S,N); dskip: (H,)."""
    B, S, H, P = x.shape
    N = bm.shape[-1]

    def step(h, t):
        xt, dtt, bt, ct = t                              # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * a)                         # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dtt, bt, xt)
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cm, 1, 0).astype(jnp.float32))
    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                            # (B,S,H,P)
    y = y + dskip[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype)
