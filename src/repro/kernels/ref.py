"""Pure-jnp oracles for every Pallas kernel.  Deliberately naive and
obviously-correct; used by tests/test_kernels.py for allclose sweeps and by
ops.py as the CPU fallback for tiny shapes.

For the fused cut layer this module carries two things:

  * `cutlayer_ref` — the UNFUSED 3-pass formulation (sample, quantize,
    rate) written with `stop_gradient` straight-through semantics so plain
    `jax.grad` yields the ground-truth gradients the hand-written VJP in
    `inl_bottleneck.py` must match.
  * `cutlayer_fwd_ref` / `cutlayer_bwd_ref` — single-expression jnp
    implementations of the fused forward and the hand-derived backward.
    `inl_bottleneck.cutlayer_fused(impl="reference")` plugs these into the
    SAME `jax.custom_vjp` wrapper the Pallas path uses, so CPU CI exercises
    the exact code path that runs on TPU.

The link quantizer's value map (`quantize_value`, `QUANT_RANGE`) lives here
as the single source of truth shared by `core/linkmodel.py` and the kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

QUANT_RANGE = 4.0   # Gaussian bottlenecks: 4 sigma covers the latents


def quantize_value(u, bits: int, *, u_range: float = QUANT_RANGE):
    """Value map of the uniform link quantizer (no gradient semantics).

    bits >= 32 is the identity (full-precision link)."""
    if bits >= 32:
        return u
    levels = (1 << bits) - 1
    scale = levels / (2.0 * u_range)
    clipped = jnp.clip(u, -u_range, u_range)
    return jnp.round((clipped + u_range) * scale) / scale - u_range


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    """Naive masked softmax attention.  q: (B,Sq,H,Dh); k/v: (B,Sk,KV,Dh)."""
    B, Sq, H, Dh = q.shape
    _, Sk, KV, _ = k.shape
    g = H // KV
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scale = 1.0 / np.sqrt(Dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None] + q_offset
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def bottleneck_ref(mu, logvar, eps):
    """u = mu + sigma*eps; kl = KL(N(mu,sigma^2) || N(0,I)) per row."""
    lv = logvar.astype(jnp.float32)
    muf = mu.astype(jnp.float32)
    u = muf + jnp.exp(0.5 * lv) * eps.astype(jnp.float32)
    kl = 0.5 * jnp.sum(jnp.exp(lv) + muf * muf - 1.0 - lv, axis=-1)
    return u.astype(mu.dtype), kl


def cutlayer_ref(mu, logvar, eps, *, link_bits: int = 32,
                 rate_estimator: str = "sample"):
    """Unfused 3-pass cut layer, ground truth for the fused kernel.

    u    = quantize_st(mu + exp(logvar/2) * eps)      (straight-through)
    rate = log P(u|x) - log Q(u)   ("sample", eq. 6, standard-normal prior;
           the log(2 pi) terms cancel) or the analytic Gaussian KL.

    Differentiable by plain AD: the quantizer uses `stop_gradient`, so
    `jax.grad` through this function defines the gradients — including the
    eq.-(10) error-vector + rate split — that the hand-written VJP in
    `inl_bottleneck.py` must reproduce."""
    muf = mu.astype(jnp.float32)
    lv = logvar.astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    pre = muf + sigma * eps.astype(jnp.float32)
    q = quantize_value(pre, link_bits)
    u = pre + jax.lax.stop_gradient(q - pre)
    if rate_estimator == "sample":
        rate = 0.5 * jnp.sum(u * u - (u - muf) ** 2 * jnp.exp(-lv) - lv,
                             axis=-1)
    else:
        rate = 0.5 * jnp.sum(jnp.exp(lv) + muf * muf - 1.0 - lv, axis=-1)
    return u.astype(mu.dtype), rate


def cutlayer_fwd_ref(mu, logvar, eps, bits: int, sampled: bool):
    """Fused forward as one jnp expression (XLA compiles it to a single
    pass on CPU).  Must match `inl_bottleneck._cut_fwd_kernel` bit-for-bit
    in fp32 arithmetic order."""
    muf = mu.astype(jnp.float32)
    lv = logvar.astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    pre = muf + sigma * eps.astype(jnp.float32)
    u = quantize_value(pre, bits)
    if sampled:
        rate = 0.5 * jnp.sum(u * u - (u - muf) ** 2 * jnp.exp(-lv) - lv,
                             axis=-1)
    else:
        rate = 0.5 * jnp.sum(jnp.exp(lv) + muf * muf - 1.0 - lv, axis=-1)
    return u.astype(mu.dtype), rate


def cutlayer_bwd_ref(mu, logvar, eps, gu, grate, bits: int, sampled: bool):
    """Hand-derived fused backward (the paper's eq.-10 split).

    Inputs: residuals (mu, logvar, eps) and cotangents gu (rows, d) — the
    decoder error-vector chunk delta[j], straight-through through the
    quantizer — and grate (rows,) on the rate output.  With
    w = (u - mu) * exp(-logvar) (the whitened residual) and straight-through
    du/dpre = 1:

      sample:   dmu  = gu + grate * u
                dlv  = (gu + grate*(u - w)) * eps*sigma/2
                       + grate * ((u-mu)^2 exp(-lv) - 1) / 2
                deps = (gu + grate*(u - w)) * sigma
      analytic: dmu  = gu + grate * mu
                dlv  = gu * eps*sigma/2 + grate * (exp(lv) - 1) / 2
                deps = gu * sigma
    """
    muf = mu.astype(jnp.float32)
    lv = logvar.astype(jnp.float32)
    ef = eps.astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    gu = gu.astype(jnp.float32)
    gr = grate.astype(jnp.float32)[..., None]
    if sampled:
        u = quantize_value(muf + sigma * ef, bits)
        w = (u - muf) * jnp.exp(-lv)
        g_pre = gu + gr * (u - w)
        dmu = gu + gr * u
        dlv = g_pre * (0.5 * sigma * ef) + gr * 0.5 * (w * (u - muf) - 1.0)
        deps = g_pre * sigma
    else:
        dmu = gu + gr * muf
        dlv = gu * (0.5 * sigma * ef) + gr * 0.5 * (jnp.exp(lv) - 1.0)
        deps = gu * sigma
    return (dmu.astype(mu.dtype), dlv.astype(logvar.dtype),
            deps.astype(eps.dtype))


def ssd_scan_ref(x, dt, a, bm, cm, dskip):
    """Exact sequential SSM recurrence (the definition, not the chunked form).

    h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t^T;  y_t = C_t h_t + D x_t.
    x: (B,S,H,P); dt: (B,S,H); a: (H,); bm/cm: (B,S,N); dskip: (H,)."""
    B, S, H, P = x.shape
    N = bm.shape[-1]

    def step(h, t):
        xt, dtt, bt, ct = t                              # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * a)                         # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dtt, bt, xt)
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cm, 1, 0).astype(jnp.float32))
    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                            # (B,S,H,P)
    y = y + dskip[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype)
