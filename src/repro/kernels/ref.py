"""Pure-jnp oracles for every Pallas kernel.  Deliberately naive and
obviously-correct; used by tests/test_kernels.py for allclose sweeps and by
ops.py as the CPU fallback for tiny shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    """Naive masked softmax attention.  q: (B,Sq,H,Dh); k/v: (B,Sk,KV,Dh)."""
    B, Sq, H, Dh = q.shape
    _, Sk, KV, _ = k.shape
    g = H // KV
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scale = 1.0 / np.sqrt(Dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None] + q_offset
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def bottleneck_ref(mu, logvar, eps):
    """u = mu + sigma*eps; kl = KL(N(mu,sigma^2) || N(0,I)) per row."""
    lv = logvar.astype(jnp.float32)
    muf = mu.astype(jnp.float32)
    u = muf + jnp.exp(0.5 * lv) * eps.astype(jnp.float32)
    kl = 0.5 * jnp.sum(jnp.exp(lv) + muf * muf - 1.0 - lv, axis=-1)
    return u.astype(mu.dtype), kl


def ssd_scan_ref(x, dt, a, bm, cm, dskip):
    """Exact sequential SSM recurrence (the definition, not the chunked form).

    h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t^T;  y_t = C_t h_t + D x_t.
    x: (B,S,H,P); dt: (B,S,H); a: (H,); bm/cm: (B,S,N); dskip: (H,)."""
    B, S, H, P = x.shape
    N = bm.shape[-1]

    def step(h, t):
        xt, dtt, bt, ct = t                              # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * a)                         # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dtt, bt, xt)
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cm, 1, 0).astype(jnp.float32))
    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                            # (B,S,H,P)
    y = y + dskip[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype)
