"""Fused cut-layer megakernel for in-network learning (the paper's hot loop).

Per node j the cut layer is  (mu, logvar) -> u = Q(mu + exp(logvar/2)*eps)
-> per-row rate  forward, and the eq.-(8c)/(10) error-vector split backward.
Unfused that is three HBM-bound passes (reparametrised sample, link
quantizer, rate term) plus vanilla AD; here it is ONE Pallas pass per
direction:

  forward   `_cut_fwd_kernel`: each (block_t, d) tile of mu/logvar/eps is
            read into VMEM once and produces BOTH the quantized transmission
            u and the per-row rate (sampled estimator of eq. 6 evaluated at
            the quantized latent, the analytic Gaussian KL, or zero for the
            deterministic "none" mode split learning's non-stochastic cut
            uses).
  backward  `_cut_bwd_kernel`: given the decoder cotangent chunk delta[j]
            (straight-through through the quantizer) and the rate cotangent,
            recomputes sigma/u from the saved inputs and emits
            (dmu, dlogvar, deps) in a single fused pass — the paper's
            error-vector + local-rate-gradient split, eq. (10).

A second kernel pair (`_cut_prior_fwd_kernel` / `_cut_prior_bwd_kernel`)
evaluates the eq.-(6) rate against LEARNED diagonal-Gaussian priors
Q_psi_j = N(prior_mu_j, exp(prior_logvar_j)): the grid becomes
(J, row-blocks) so each node's (d,)-vector prior is read once per block, and
the backward additionally emits (dpmu, dplv), accumulated across each node's
row blocks inside the kernel (the grid is sequential, so `+=` into the
per-node output block is exact).  Learned priors therefore run the SAME
one-pass-per-direction fused path as the standard-normal case — no fallback
to the unfused 3-pass estimator.

All directions hang off `jax.custom_vjp` wrappers (`cutlayer_fused`), so
training never differentiates through `pallas_call` (interpret-mode AD was
the seed's CPU bottleneck).  The J client nodes are BATCHED into one kernel
launch: callers pass (J, ..., d) and the leading axes are folded into the
row grid — no `jax.vmap` over per-node calls.

Contract:
  * arbitrary leading dims; rows padded to a block_t multiple (no assert),
    outputs sliced back.  With learned priors, priors are (d,) shared or
    (J, d) per-node with mu shaped (J, ..., d).
  * `impl="reference"` routes the same custom VJP through the jnp oracle
    (kernels/ref.py), which XLA compiles to one fused pass on CPU — CI and
    TPU run identical code paths.
  * `interpret=None` auto-detects via the kernels/ops.py backend resolver
    (compiled on TPU, interpret elsewhere); never silently interprets on
    TPU.
  * quantizer semantics (clip to +-QUANT_RANGE, uniform midtread,
    straight-through) are shared with core/linkmodel.py via
    ref.quantize_value.

`bottleneck_fused` (sample + analytic KL, no quantizer) is kept as the
seed-compatible entry point on top of the same kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

DEFAULT_BLOCK_T = 256

MODES = ("sample", "analytic", "none")


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------

def _quantize(pre, bits: int):
    """In-kernel uniform quantizer value map; identical math to
    ref.quantize_value (bits is a compile-time constant)."""
    if bits >= 32:
        return pre
    r = ref.QUANT_RANGE
    scale = ((1 << bits) - 1) / (2.0 * r)
    return jnp.round((jnp.clip(pre, -r, r) + r) * scale) / scale - r


def _cut_fwd_kernel(mu_ref, lv_ref, eps_ref, u_ref, rate_ref, *,
                    bits: int, mode: str):
    mu = mu_ref[...].astype(jnp.float32)
    lv = lv_ref[...].astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    u = _quantize(mu + sigma * eps, bits)
    u_ref[...] = u.astype(u_ref.dtype)
    if mode == "sample":
        rate = 0.5 * jnp.sum(u * u - (u - mu) ** 2 * jnp.exp(-lv) - lv,
                             axis=-1)
    elif mode == "analytic":
        rate = 0.5 * jnp.sum(jnp.exp(lv) + mu * mu - 1.0 - lv, axis=-1)
    else:
        rate = jnp.zeros(u.shape[:-1], jnp.float32)
    rate_ref[...] = rate.astype(rate_ref.dtype)


def _pack_lanes(idx, W: int, bits: int):
    """(rows, d) uint32 codewords -> (rows, W) uint32 lanes, in-kernel.

    Same little-endian lane layout as ref.pack_indices; the iota is
    broadcasted (TPU disallows 1-D iota inside kernels)."""
    vpw = 32 // bits
    rows, d = idx.shape
    pad = W * vpw - d
    if pad:
        idx = jnp.pad(idx, ((0, 0), (0, pad)))
    grouped = idx.reshape(rows, W, vpw)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, vpw), 2) \
        * jnp.uint32(bits)
    return jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)


def _cut_fwd_pack_kernel(mu_ref, lv_ref, eps_ref, u_ref, pk_ref, rate_ref, *,
                         bits: int, mode: str):
    """Pack-emitting fused forward: the codeword index is the shared
    intermediate, so u, the packed lanes and the rate all come out of ONE
    read of (mu, logvar, eps) — the wire buffer costs no extra pass."""
    mu = mu_ref[...].astype(jnp.float32)
    lv = lv_ref[...].astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    pre = mu + sigma * eps
    r = ref.QUANT_RANGE
    scale = ((1 << bits) - 1) / (2.0 * r)
    idx = jnp.round((jnp.clip(pre, -r, r) + r) * scale).astype(jnp.uint32)
    u = idx.astype(jnp.float32) / scale - r
    u_ref[...] = u.astype(u_ref.dtype)
    pk_ref[...] = _pack_lanes(idx, pk_ref.shape[-1], bits)
    if mode == "sample":
        rate = 0.5 * jnp.sum(u * u - (u - mu) ** 2 * jnp.exp(-lv) - lv,
                             axis=-1)
    elif mode == "analytic":
        rate = 0.5 * jnp.sum(jnp.exp(lv) + mu * mu - 1.0 - lv, axis=-1)
    else:
        rate = jnp.zeros(u.shape[:-1], jnp.float32)
    rate_ref[...] = rate.astype(rate_ref.dtype)


def _pack_kernel(u_ref, pk_ref, *, bits: int):
    """Standalone pack: quantized values -> codeword lanes (used for paths
    whose forward kernel does not emit packed output, e.g. learned priors)."""
    u = u_ref[...].astype(jnp.float32)
    r = ref.QUANT_RANGE
    scale = ((1 << bits) - 1) / (2.0 * r)
    idx = jnp.round((jnp.clip(u, -r, r) + r) * scale).astype(jnp.uint32)
    pk_ref[...] = _pack_lanes(idx, pk_ref.shape[-1], bits)


def _unpack_dequant_kernel(pk_ref, u_ref, *, bits: int):
    """Fusion-center side: packed lanes -> dense quantized values."""
    packed = pk_ref[...]
    rows, W = packed.shape
    d = u_ref.shape[-1]
    vpw = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, vpw), 2) \
        * jnp.uint32(bits)
    ext = (packed[..., None] >> shifts) & mask
    idx = ext.reshape(rows, W * vpw)[:, :d]
    r = ref.QUANT_RANGE
    scale = ((1 << bits) - 1) / (2.0 * r)
    u_ref[...] = (idx.astype(jnp.float32) / scale - r).astype(u_ref.dtype)


def _cut_bwd_kernel(mu_ref, lv_ref, eps_ref, gu_ref, gr_ref,
                    dmu_ref, dlv_ref, deps_ref, *, bits: int, mode: str):
    mu = mu_ref[...].astype(jnp.float32)
    lv = lv_ref[...].astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    gu = gu_ref[...].astype(jnp.float32)
    gr = gr_ref[...].astype(jnp.float32)[:, None]
    sigma = jnp.exp(0.5 * lv)
    if mode == "sample":
        u = _quantize(mu + sigma * eps, bits)
        w = (u - mu) * jnp.exp(-lv)
        g_pre = gu + gr * (u - w)
        dmu = gu + gr * u
        dlv = g_pre * (0.5 * sigma * eps) + gr * 0.5 * (w * (u - mu) - 1.0)
        deps = g_pre * sigma
    elif mode == "analytic":
        dmu = gu + gr * mu
        dlv = gu * (0.5 * sigma * eps) + gr * 0.5 * (jnp.exp(lv) - 1.0)
        deps = gu * sigma
    else:
        dmu = gu
        dlv = gu * (0.5 * sigma * eps)
        deps = gu * sigma
    dmu_ref[...] = dmu.astype(dmu_ref.dtype)
    dlv_ref[...] = dlv.astype(dlv_ref.dtype)
    deps_ref[...] = deps.astype(deps_ref.dtype)


def _fwd_pallas(mu, logvar, eps, bits, mode, block_t, interpret):
    R, d = mu.shape
    grid = (R // block_t,)
    spec = pl.BlockSpec((block_t, d), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_cut_fwd_kernel, bits=bits, mode=mode),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, pl.BlockSpec((block_t,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((R, d), mu.dtype),
                   jax.ShapeDtypeStruct((R,), jnp.float32)],
        interpret=interpret,
    )(mu, logvar, eps)


def _bwd_pallas(mu, logvar, eps, gu, grate, bits, mode, block_t,
                interpret):
    R, d = mu.shape
    grid = (R // block_t,)
    spec = pl.BlockSpec((block_t, d), lambda i: (i, 0))
    spec1 = pl.BlockSpec((block_t,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_cut_bwd_kernel, bits=bits, mode=mode),
        grid=grid,
        in_specs=[spec, spec, spec, spec, spec1],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((R, d), mu.dtype),
                   jax.ShapeDtypeStruct((R, d), logvar.dtype),
                   jax.ShapeDtypeStruct((R, d), eps.dtype)],
        interpret=interpret,
    )(mu, logvar, eps, gu, grate)


def _fwd_pack_pallas(mu, logvar, eps, bits, mode, block_t, interpret):
    R, d = mu.shape
    W = ref.packed_width(d, bits)
    grid = (R // block_t,)
    spec = pl.BlockSpec((block_t, d), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_cut_fwd_pack_kernel, bits=bits, mode=mode),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, pl.BlockSpec((block_t, W), lambda i: (i, 0)),
                   pl.BlockSpec((block_t,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((R, d), mu.dtype),
                   jax.ShapeDtypeStruct((R, W), jnp.uint32),
                   jax.ShapeDtypeStruct((R,), jnp.float32)],
        interpret=interpret,
    )(mu, logvar, eps)


def _pack_pallas(u, bits, block_t, interpret):
    R, d = u.shape
    W = ref.packed_width(d, bits)
    grid = (R // block_t,)
    return pl.pallas_call(
        functools.partial(_pack_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_t, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, W), jnp.uint32),
        interpret=interpret,
    )(u)


def _unpack_pallas(packed, d, bits, dtype, block_t, interpret):
    R, W = packed.shape
    grid = (R // block_t,)
    return pl.pallas_call(
        functools.partial(_unpack_dequant_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, W), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), dtype),
        interpret=interpret,
    )(packed)


# ---------------------------------------------------------------------------
# Learned-prior kernels: grid (J, row-blocks), per-node (d,) prior vectors
# ---------------------------------------------------------------------------

def _cut_prior_fwd_kernel(mu_ref, lv_ref, eps_ref, pmu_ref, plv_ref,
                          u_ref, rate_ref, *, bits: int, mode: str):
    mu = mu_ref[0].astype(jnp.float32)           # (block_t, d)
    lv = lv_ref[0].astype(jnp.float32)
    eps = eps_ref[0].astype(jnp.float32)
    pmu = pmu_ref[...].astype(jnp.float32)       # (1, d) broadcasts over rows
    plv = plv_ref[...].astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    u = _quantize(mu + sigma * eps, bits)
    u_ref[0] = u.astype(u_ref.dtype)
    if mode == "sample":
        rate = 0.5 * jnp.sum((u - pmu) ** 2 * jnp.exp(-plv) + plv
                             - (u - mu) ** 2 * jnp.exp(-lv) - lv, axis=-1)
    else:                                        # "analytic"
        rate = 0.5 * jnp.sum(plv - lv + (jnp.exp(lv) + (mu - pmu) ** 2)
                             * jnp.exp(-plv) - 1.0, axis=-1)
    rate_ref[0] = rate.astype(rate_ref.dtype)


def _cut_prior_bwd_kernel(mu_ref, lv_ref, eps_ref, pmu_ref, plv_ref,
                          u_ref, gu_ref, gr_ref, dmu_ref, dlv_ref,
                          deps_ref, dpmu_ref, dplv_ref, *, bits: int,
                          mode: str):
    mu = mu_ref[0].astype(jnp.float32)
    lv = lv_ref[0].astype(jnp.float32)
    eps = eps_ref[0].astype(jnp.float32)
    pmu = pmu_ref[...].astype(jnp.float32)       # (1, d)
    plv = plv_ref[...].astype(jnp.float32)
    gu = gu_ref[0].astype(jnp.float32)
    gr = gr_ref[0].astype(jnp.float32)[:, None]
    sigma = jnp.exp(0.5 * lv)
    if mode == "sample":
        u = u_ref[0].astype(jnp.float32)         # saved forward output
        w = (u - mu) * jnp.exp(-lv)
        wq = (u - pmu) * jnp.exp(-plv)
        g_pre = gu + gr * (wq - w)
        dmu = g_pre + gr * w
        dlv = g_pre * (0.5 * sigma * eps) + gr * 0.5 * (w * (u - mu) - 1.0)
        deps = g_pre * sigma
        dpmu = jnp.sum(-gr * wq, axis=0, keepdims=True)
        dplv = jnp.sum(gr * 0.5 * (1.0 - wq * (u - pmu)), axis=0,
                       keepdims=True)
    else:                                        # "analytic"
        dm = (mu - pmu) * jnp.exp(-plv)
        dmu = gu + gr * dm
        dlv = gu * (0.5 * sigma * eps) + gr * 0.5 * (jnp.exp(lv - plv) - 1.0)
        deps = gu * sigma
        dpmu = jnp.sum(-gr * dm, axis=0, keepdims=True)
        dplv = jnp.sum(gr * 0.5 * (1.0 - (jnp.exp(lv) + (mu - pmu) ** 2)
                                   * jnp.exp(-plv)), axis=0, keepdims=True)
    dmu_ref[0] = dmu.astype(dmu_ref.dtype)
    dlv_ref[0] = dlv.astype(dlv_ref.dtype)
    deps_ref[0] = deps.astype(deps_ref.dtype)
    # per-node prior grads: accumulate across this node's row blocks (the
    # grid is sequential with the row dimension innermost, so the first
    # block initialises and the rest add)
    @pl.when(pl.program_id(1) == 0)
    def _init():
        dpmu_ref[...] = jnp.zeros(dpmu_ref.shape, dpmu_ref.dtype)
        dplv_ref[...] = jnp.zeros(dplv_ref.shape, dplv_ref.dtype)
    dpmu_ref[...] += dpmu.astype(dpmu_ref.dtype)
    dplv_ref[...] += dplv.astype(dplv_ref.dtype)


def _prior_fwd_pallas(mu, logvar, eps, pmu, plv, bits, mode, block_t,
                      interpret):
    J, T, d = mu.shape
    grid = (J, T // block_t)
    row = pl.BlockSpec((1, block_t, d), lambda j, i: (j, i, 0))
    prior = pl.BlockSpec((1, d), lambda j, i: (j, 0))
    return pl.pallas_call(
        functools.partial(_cut_prior_fwd_kernel, bits=bits, mode=mode),
        grid=grid,
        in_specs=[row, row, row, prior, prior],
        out_specs=[row, pl.BlockSpec((1, block_t), lambda j, i: (j, i))],
        out_shape=[jax.ShapeDtypeStruct((J, T, d), mu.dtype),
                   jax.ShapeDtypeStruct((J, T), jnp.float32)],
        interpret=interpret,
    )(mu, logvar, eps, pmu, plv)


def _prior_bwd_pallas(mu, logvar, eps, pmu, plv, u, gu, grate, bits, mode,
                      block_t, interpret):
    J, T, d = mu.shape
    grid = (J, T // block_t)
    row = pl.BlockSpec((1, block_t, d), lambda j, i: (j, i, 0))
    prior = pl.BlockSpec((1, d), lambda j, i: (j, 0))
    rate = pl.BlockSpec((1, block_t), lambda j, i: (j, i))
    return pl.pallas_call(
        functools.partial(_cut_prior_bwd_kernel, bits=bits, mode=mode),
        grid=grid,
        in_specs=[row, row, row, prior, prior, row, row, rate],
        out_specs=[row, row, row, prior, prior],
        out_shape=[jax.ShapeDtypeStruct((J, T, d), mu.dtype),
                   jax.ShapeDtypeStruct((J, T, d), logvar.dtype),
                   jax.ShapeDtypeStruct((J, T, d), eps.dtype),
                   jax.ShapeDtypeStruct((J, d), pmu.dtype),
                   jax.ShapeDtypeStruct((J, d), plv.dtype)],
        interpret=interpret,
    )(mu, logvar, eps, pmu, plv, u, gu, grate)


# ---------------------------------------------------------------------------
# Shared custom VJPs (pallas and reference impls run the same wrappers)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _cutlayer(mu, logvar, eps, bits, mode, impl, block_t, interpret):
    if impl == "pallas":
        return _fwd_pallas(mu, logvar, eps, bits, mode, block_t, interpret)
    return ref.cutlayer_fwd_ref(mu, logvar, eps, bits, mode)


def _cutlayer_fwd(mu, logvar, eps, bits, mode, impl, block_t, interpret):
    out = _cutlayer(mu, logvar, eps, bits, mode, impl, block_t, interpret)
    return out, (mu, logvar, eps)


def _cutlayer_bwd(bits, mode, impl, block_t, interpret, res, cts):
    mu, logvar, eps = res
    gu, grate = cts
    if impl == "pallas":
        return _bwd_pallas(mu, logvar, eps, gu, grate, bits, mode,
                           block_t, interpret)
    return ref.cutlayer_bwd_ref(mu, logvar, eps, gu, grate, bits, mode)


_cutlayer.defvjp(_cutlayer_fwd, _cutlayer_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _cutlayer_prior(mu, logvar, eps, pmu, plv, bits, mode, impl, block_t,
                    interpret):
    if impl == "pallas":
        return _prior_fwd_pallas(mu, logvar, eps, pmu, plv, bits, mode,
                                 block_t, interpret)
    return ref.cutlayer_prior_fwd_ref(mu, logvar, eps, pmu, plv, bits, mode)


def _cutlayer_prior_fwd(mu, logvar, eps, pmu, plv, bits, mode, impl,
                        block_t, interpret):
    out = _cutlayer_prior(mu, logvar, eps, pmu, plv, bits, mode, impl,
                          block_t, interpret)
    # u (out[0]) rides along as a residual: it is a live output buffer
    # anyway, and the backward reading it (instead of recomputing the
    # exp/quantize chain) keeps the prior-grad reductions' dependency cone
    # minimal — without this, XLA's reduction fusions re-derive u and the
    # learned-prior backward regresses ~1.4x vs standard-normal on CPU.
    return out, (mu, logvar, eps, pmu, plv, out[0])


def _cutlayer_prior_bwd(bits, mode, impl, block_t, interpret, res, cts):
    mu, logvar, eps, pmu, plv, u = res
    gu, grate = cts
    if impl == "pallas":
        return _prior_bwd_pallas(mu, logvar, eps, pmu, plv, u, gu, grate,
                                 bits, mode, block_t, interpret)
    return ref.cutlayer_prior_bwd_ref(mu, logvar, eps, pmu, plv, u, gu,
                                      grate, bits, mode)


_cutlayer_prior.defvjp(_cutlayer_prior_fwd, _cutlayer_prior_bwd)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def _resolve_interpret(interpret):
    if interpret is not None:
        return interpret
    from repro.kernels import ops          # lazy: ops imports this module
    return not ops.on_tpu()


@functools.partial(jax.jit, static_argnames=("link_bits", "rate_estimator",
                                             "impl", "block_t", "interpret"))
def _cutlayer_call(mu, logvar, eps, link_bits, rate_estimator, impl,
                   block_t, interpret):
    shape = mu.shape
    d = shape[-1]
    R = 1
    for s in shape[:-1]:
        R *= s
    mu2 = mu.reshape(R, d)
    lv2 = logvar.reshape(R, d)
    eps2 = eps.reshape(R, d)
    bt = min(block_t or DEFAULT_BLOCK_T, R)
    pad = (-R) % bt
    if pad:
        mu2 = jnp.pad(mu2, ((0, pad), (0, 0)))
        lv2 = jnp.pad(lv2, ((0, pad), (0, 0)))
        eps2 = jnp.pad(eps2, ((0, pad), (0, 0)))
    u, rate = _cutlayer(mu2, lv2, eps2, link_bits, rate_estimator, impl,
                        bt, interpret)
    if pad:
        u, rate = u[:R], rate[:R]
    return u.reshape(shape), rate.reshape(shape[:-1])


@functools.partial(jax.jit, static_argnames=("link_bits", "rate_estimator",
                                             "impl", "block_t", "interpret"))
def _cutlayer_prior_call(mu, logvar, eps, pmu, plv, link_bits,
                         rate_estimator, impl, block_t, interpret):
    shape = mu.shape
    d = shape[-1]
    if pmu.ndim == 1:                       # shared prior: one node group
        J, lead = 1, shape[:-1]
        pmu2, plv2 = pmu[None], plv[None]
    else:                                   # per-node (J, d) priors
        J, lead = pmu.shape[0], shape[1:-1]
        if shape[0] != J:
            raise ValueError(f"per-node prior J={J} vs mu leading axis "
                             f"{shape[0]}")
        pmu2, plv2 = pmu, plv
    T = 1
    for s in lead:
        T *= s
    mu3 = mu.reshape(J, T, d)
    lv3 = logvar.reshape(J, T, d)
    eps3 = eps.reshape(J, T, d)
    bt = min(block_t or DEFAULT_BLOCK_T, T)
    pad = (-T) % bt
    if pad:
        mu3 = jnp.pad(mu3, ((0, 0), (0, pad), (0, 0)))
        lv3 = jnp.pad(lv3, ((0, 0), (0, pad), (0, 0)))
        eps3 = jnp.pad(eps3, ((0, 0), (0, pad), (0, 0)))
    u, rate = _cutlayer_prior(mu3, lv3, eps3, pmu2, plv2, link_bits,
                              rate_estimator, impl, bt, interpret)
    if pad:
        u, rate = u[:, :T], rate[:, :T]
    return u.reshape(shape), rate.reshape(shape[:-1])


def cutlayer_fused(mu, logvar, eps, *, link_bits: int = 32,
                   rate_estimator: str = "analytic", impl: str = "pallas",
                   prior_mu=None, prior_logvar=None,
                   block_t: int = None, interpret: bool = None):
    """One fused pass over the cut layer, all J nodes in one launch.

    mu/logvar/eps: (..., d) — fold any leading axes (J clients, batch,
    sequence) in; they become the row grid.  Returns
    (u (..., d) in mu.dtype, rate (...,) fp32).

    link_bits >= 32 disables the quantizer; rate_estimator selects the
    paper's sampled eq.-(6) estimator (evaluated at the quantized latent),
    the analytic Gaussian KL, or "none" (rate == 0, the deterministic cut
    split learning uses with eps == 0).  prior_mu/prior_logvar — (d,)
    shared or (J, d) per-node with mu shaped (J, ..., d) — switch the rate
    to a learned Gaussian prior Q_psi; the fused backward then also yields
    the prior gradients.  Gradients always flow through the hand-written
    fused backward (eq. 10), never through AD of the kernel body."""
    if rate_estimator not in MODES:
        raise ValueError(f"unknown rate_estimator {rate_estimator!r}")
    interpret = _resolve_interpret(interpret)
    if prior_mu is None:
        return _cutlayer_call(mu, logvar, eps, link_bits, rate_estimator,
                              impl, block_t, interpret)
    if rate_estimator == "none":            # prior irrelevant when rate == 0
        return _cutlayer_call(mu, logvar, eps, link_bits, rate_estimator,
                              impl, block_t, interpret)
    return _cutlayer_prior_call(mu, logvar, eps, prior_mu, prior_logvar,
                                link_bits, rate_estimator, impl, block_t,
                                interpret)


# ---------------------------------------------------------------------------
# Packed wire format: non-VJP building blocks (core/wirefmt.py owns the
# straight-through custom_vjp that spans pack -> collective -> unpack)
# ---------------------------------------------------------------------------

def _rows(x):
    R = 1
    for s in x.shape[:-1]:
        R *= s
    return R


@functools.partial(jax.jit, static_argnames=("link_bits", "rate_estimator",
                                             "impl", "block_t", "interpret"))
def _pack_fwd_call(mu, logvar, eps, link_bits, rate_estimator, impl, block_t,
                   interpret):
    shape = mu.shape
    d = shape[-1]
    R = _rows(mu)
    W = ref.packed_width(d, link_bits)
    mu2, lv2, eps2 = (x.reshape(R, d) for x in (mu, logvar, eps))
    bt = min(block_t or DEFAULT_BLOCK_T, R)
    pad = (-R) % bt
    if pad:
        mu2, lv2, eps2 = (jnp.pad(x, ((0, pad), (0, 0)))
                          for x in (mu2, lv2, eps2))
    if impl == "pallas":
        u, packed, rate = _fwd_pack_pallas(mu2, lv2, eps2, link_bits,
                                           rate_estimator, bt, interpret)
    else:
        u, packed, rate = ref.cutlayer_pack_fwd_ref(mu2, lv2, eps2,
                                                    link_bits, rate_estimator)
    if pad:
        u, packed, rate = u[:R], packed[:R], rate[:R]
    return (u.reshape(shape), packed.reshape(shape[:-1] + (W,)),
            rate.reshape(shape[:-1]))


def cutlayer_pack_forward(mu, logvar, eps, *, link_bits: int,
                          rate_estimator: str = "sample",
                          impl: str = "pallas", block_t: int = None,
                          interpret: bool = None):
    """Pack-emitting fused forward: (u (..., d), packed (..., W) uint32,
    rate (...,) fp32) in one kernel pass.  NO gradient rule — callers wrap
    it in their own custom_vjp (core/wirefmt.py) whose backward is
    `cutlayer_backward`.  Bit-identical to `cutlayer_fused` on (u, rate)."""
    if rate_estimator not in MODES:
        raise ValueError(f"unknown rate_estimator {rate_estimator!r}")
    return _pack_fwd_call(mu, logvar, eps, link_bits, rate_estimator, impl,
                          block_t, _resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("link_bits", "rate_estimator",
                                             "impl", "block_t", "interpret"))
def _bwd_call(mu, logvar, eps, gu, grate, link_bits, rate_estimator, impl,
              block_t, interpret):
    shape = mu.shape
    d = shape[-1]
    R = _rows(mu)
    mu2, lv2, eps2, gu2 = (x.reshape(R, d) for x in (mu, logvar, eps, gu))
    gr2 = grate.reshape(R)
    bt = min(block_t or DEFAULT_BLOCK_T, R)
    pad = (-R) % bt
    if pad:
        mu2, lv2, eps2, gu2 = (jnp.pad(x, ((0, pad), (0, 0)))
                               for x in (mu2, lv2, eps2, gu2))
        gr2 = jnp.pad(gr2, (0, pad))
    if impl == "pallas":
        dmu, dlv, deps = _bwd_pallas(mu2, lv2, eps2, gu2, gr2, link_bits,
                                     rate_estimator, bt, interpret)
    else:
        dmu, dlv, deps = ref.cutlayer_bwd_ref(mu2, lv2, eps2, gu2, gr2,
                                              link_bits, rate_estimator)
    if pad:
        dmu, dlv, deps = dmu[:R], dlv[:R], deps[:R]
    return tuple(x.reshape(shape) for x in (dmu, dlv, deps))


def cutlayer_backward(mu, logvar, eps, gu, grate, *, link_bits: int,
                      rate_estimator: str = "sample", impl: str = "pallas",
                      block_t: int = None, interpret: bool = None):
    """The fused eq.-(10) backward as a plain dispatch (same kernels the
    `cutlayer_fused` custom VJP runs), for wrappers that own their VJP."""
    return _bwd_call(mu, logvar, eps, gu, grate, link_bits, rate_estimator,
                     impl, block_t, _resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("link_bits", "impl", "block_t",
                                             "interpret"))
def _pack_call(u, link_bits, impl, block_t, interpret):
    shape = u.shape
    d = shape[-1]
    R = _rows(u)
    W = ref.packed_width(d, link_bits)
    u2 = u.reshape(R, d)
    bt = min(block_t or DEFAULT_BLOCK_T, R)
    pad = (-R) % bt
    if pad:
        u2 = jnp.pad(u2, ((0, pad), (0, 0)))
    if impl == "pallas":
        packed = _pack_pallas(u2, link_bits, bt, interpret)
    else:
        packed = ref.pack_values_ref(u2, link_bits)
    if pad:
        packed = packed[:R]
    return packed.reshape(shape[:-1] + (W,))


def pack_values(u, *, link_bits: int, impl: str = "pallas",
                block_t: int = None, interpret: bool = None):
    """Quantized values -> packed codeword lanes ((..., d) -> (..., W)
    uint32).  Lossless on values already on the link_bits quantizer grid.

    bf16 storage can only address grids up to 8 bits exactly (coarser than
    the bf16 mantissa); wider codes would decode to different values, so
    they are rejected rather than silently corrupted."""
    if jnp.dtype(u.dtype).itemsize < 4 and link_bits > 8:
        raise ValueError(f"cannot re-encode {u.dtype} values at "
                         f"{link_bits}-bit codes (> 8 bits exceeds the "
                         "half-precision mantissa); pack from the kernel's "
                         "fp32 internals via cutlayer_pack_forward instead")
    return _pack_call(u, link_bits, impl, block_t,
                      _resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("d", "link_bits", "dtype",
                                             "impl", "block_t", "interpret"))
def _unpack_call(packed, d, link_bits, dtype, impl, block_t, interpret):
    shape = packed.shape
    W = shape[-1]
    R = _rows(packed)
    pk2 = packed.reshape(R, W)
    bt = min(block_t or DEFAULT_BLOCK_T, R)
    pad = (-R) % bt
    if pad:
        pk2 = jnp.pad(pk2, ((0, pad), (0, 0)))
    if impl == "pallas":
        u = _unpack_pallas(pk2, d, link_bits, dtype, bt, interpret)
    else:
        u = ref.unpack_dequant_ref(pk2, d, link_bits, dtype=dtype)
    if pad:
        u = u[:R]
    return u.reshape(shape[:-1] + (d,))


def unpack_dequant(packed, d: int, *, link_bits: int, dtype=jnp.float32,
                   impl: str = "pallas", block_t: int = None,
                   interpret: bool = None):
    """Fusion-center unpack: (..., W) uint32 lanes -> (..., d) quantized
    values, one fused extract+dequantize pass."""
    if packed.shape[-1] != ref.packed_width(d, link_bits):
        raise ValueError(f"packed width {packed.shape[-1]} does not match "
                         f"d={d} at {link_bits} bits "
                         f"(want {ref.packed_width(d, link_bits)})")
    return _unpack_call(packed, d, link_bits, jnp.dtype(dtype), impl,
                        block_t, _resolve_interpret(interpret))


def bottleneck_fused(mu, logvar, eps, *, block_t: int = DEFAULT_BLOCK_T,
                     interpret: bool = None):
    """Seed-compatible entry: u = mu + exp(logvar/2)*eps (no quantizer) and
    the per-row analytic KL.  mu/logvar/eps: (T, d); returns (u, kl).

    T need not divide block_t (rows are padded internally); interpret=None
    auto-detects the backend."""
    return cutlayer_fused(mu, logvar, eps, link_bits=32,
                          rate_estimator="analytic", impl="pallas",
                          block_t=block_t, interpret=interpret)
