"""Fused cut-layer megakernel for in-network learning (the paper's hot loop).

Per node j the cut layer is  (mu, logvar) -> u = Q(mu + exp(logvar/2)*eps)
-> per-row rate  forward, and the eq.-(8c)/(10) error-vector split backward.
Unfused that is three HBM-bound passes (reparametrised sample, link
quantizer, rate term) plus vanilla AD; here it is ONE Pallas pass per
direction:

  forward   `_cut_fwd_kernel`: each (block_t, d) tile of mu/logvar/eps is
            read into VMEM once and produces BOTH the quantized transmission
            u and the per-row rate (sampled estimator of eq. 6 evaluated at
            the quantized latent, or the analytic Gaussian KL).
  backward  `_cut_bwd_kernel`: given the decoder cotangent chunk delta[j]
            (straight-through through the quantizer) and the rate cotangent,
            recomputes sigma/u from the saved inputs and emits
            (dmu, dlogvar, deps) in a single fused pass — the paper's
            error-vector + local-rate-gradient split, eq. (10).

Both directions hang off one `jax.custom_vjp` (`cutlayer_fused`), so
training never differentiates through `pallas_call` (interpret-mode AD was
the seed's CPU bottleneck).  The J client nodes are BATCHED into one kernel
launch: callers pass (J, ..., d) and the leading axes are folded into the
row grid — no `jax.vmap` over per-node calls.

Contract:
  * arbitrary leading dims; rows padded to a block_t multiple (no assert),
    outputs sliced back.
  * `impl="reference"` routes the same custom VJP through the jnp oracle
    (kernels/ref.py), which XLA compiles to one fused pass on CPU — CI and
    TPU run identical code paths.
  * `interpret=None` auto-detects via the kernels/ops.py backend resolver
    (compiled on TPU, interpret elsewhere); never silently interprets on
    TPU.
  * quantizer semantics (clip to +-QUANT_RANGE, uniform midtread,
    straight-through) are shared with core/linkmodel.py via
    ref.quantize_value.

`bottleneck_fused` (sample + analytic KL, no quantizer) is kept as the
seed-compatible entry point on top of the same kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

DEFAULT_BLOCK_T = 256


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------

def _quantize(pre, bits: int):
    """In-kernel uniform quantizer value map; identical math to
    ref.quantize_value (bits is a compile-time constant)."""
    if bits >= 32:
        return pre
    r = ref.QUANT_RANGE
    scale = ((1 << bits) - 1) / (2.0 * r)
    return jnp.round((jnp.clip(pre, -r, r) + r) * scale) / scale - r


def _cut_fwd_kernel(mu_ref, lv_ref, eps_ref, u_ref, rate_ref, *,
                    bits: int, sampled: bool):
    mu = mu_ref[...].astype(jnp.float32)
    lv = lv_ref[...].astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    u = _quantize(mu + sigma * eps, bits)
    u_ref[...] = u.astype(u_ref.dtype)
    if sampled:
        rate = 0.5 * jnp.sum(u * u - (u - mu) ** 2 * jnp.exp(-lv) - lv,
                             axis=-1)
    else:
        rate = 0.5 * jnp.sum(jnp.exp(lv) + mu * mu - 1.0 - lv, axis=-1)
    rate_ref[...] = rate.astype(rate_ref.dtype)


def _cut_bwd_kernel(mu_ref, lv_ref, eps_ref, gu_ref, gr_ref,
                    dmu_ref, dlv_ref, deps_ref, *, bits: int, sampled: bool):
    mu = mu_ref[...].astype(jnp.float32)
    lv = lv_ref[...].astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    gu = gu_ref[...].astype(jnp.float32)
    gr = gr_ref[...].astype(jnp.float32)[:, None]
    sigma = jnp.exp(0.5 * lv)
    if sampled:
        u = _quantize(mu + sigma * eps, bits)
        w = (u - mu) * jnp.exp(-lv)
        g_pre = gu + gr * (u - w)
        dmu = gu + gr * u
        dlv = g_pre * (0.5 * sigma * eps) + gr * 0.5 * (w * (u - mu) - 1.0)
        deps = g_pre * sigma
    else:
        dmu = gu + gr * mu
        dlv = gu * (0.5 * sigma * eps) + gr * 0.5 * (jnp.exp(lv) - 1.0)
        deps = gu * sigma
    dmu_ref[...] = dmu.astype(dmu_ref.dtype)
    dlv_ref[...] = dlv.astype(dlv_ref.dtype)
    deps_ref[...] = deps.astype(deps_ref.dtype)


def _fwd_pallas(mu, logvar, eps, bits, sampled, block_t, interpret):
    R, d = mu.shape
    grid = (R // block_t,)
    spec = pl.BlockSpec((block_t, d), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_cut_fwd_kernel, bits=bits, sampled=sampled),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, pl.BlockSpec((block_t,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((R, d), mu.dtype),
                   jax.ShapeDtypeStruct((R,), jnp.float32)],
        interpret=interpret,
    )(mu, logvar, eps)


def _bwd_pallas(mu, logvar, eps, gu, grate, bits, sampled, block_t,
                interpret):
    R, d = mu.shape
    grid = (R // block_t,)
    spec = pl.BlockSpec((block_t, d), lambda i: (i, 0))
    spec1 = pl.BlockSpec((block_t,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_cut_bwd_kernel, bits=bits, sampled=sampled),
        grid=grid,
        in_specs=[spec, spec, spec, spec, spec1],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((R, d), mu.dtype),
                   jax.ShapeDtypeStruct((R, d), logvar.dtype),
                   jax.ShapeDtypeStruct((R, d), eps.dtype)],
        interpret=interpret,
    )(mu, logvar, eps, gu, grate)


# ---------------------------------------------------------------------------
# Shared custom VJP (pallas and reference impls run the same wrapper)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _cutlayer(mu, logvar, eps, bits, sampled, impl, block_t, interpret):
    if impl == "pallas":
        return _fwd_pallas(mu, logvar, eps, bits, sampled, block_t, interpret)
    return ref.cutlayer_fwd_ref(mu, logvar, eps, bits, sampled)


def _cutlayer_fwd(mu, logvar, eps, bits, sampled, impl, block_t, interpret):
    out = _cutlayer(mu, logvar, eps, bits, sampled, impl, block_t, interpret)
    return out, (mu, logvar, eps)


def _cutlayer_bwd(bits, sampled, impl, block_t, interpret, res, cts):
    mu, logvar, eps = res
    gu, grate = cts
    if impl == "pallas":
        return _bwd_pallas(mu, logvar, eps, gu, grate, bits, sampled,
                           block_t, interpret)
    return ref.cutlayer_bwd_ref(mu, logvar, eps, gu, grate, bits, sampled)


_cutlayer.defvjp(_cutlayer_fwd, _cutlayer_bwd)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def _resolve_interpret(interpret):
    if interpret is not None:
        return interpret
    from repro.kernels import ops          # lazy: ops imports this module
    return not ops.on_tpu()


@functools.partial(jax.jit, static_argnames=("link_bits", "rate_estimator",
                                             "impl", "block_t", "interpret"))
def _cutlayer_call(mu, logvar, eps, link_bits, rate_estimator, impl,
                   block_t, interpret):
    shape = mu.shape
    d = shape[-1]
    R = 1
    for s in shape[:-1]:
        R *= s
    mu2 = mu.reshape(R, d)
    lv2 = logvar.reshape(R, d)
    eps2 = eps.reshape(R, d)
    bt = min(block_t or DEFAULT_BLOCK_T, R)
    pad = (-R) % bt
    if pad:
        mu2 = jnp.pad(mu2, ((0, pad), (0, 0)))
        lv2 = jnp.pad(lv2, ((0, pad), (0, 0)))
        eps2 = jnp.pad(eps2, ((0, pad), (0, 0)))
    u, rate = _cutlayer(mu2, lv2, eps2, link_bits,
                        rate_estimator == "sample", impl, bt, interpret)
    if pad:
        u, rate = u[:R], rate[:R]
    return u.reshape(shape), rate.reshape(shape[:-1])


def cutlayer_fused(mu, logvar, eps, *, link_bits: int = 32,
                   rate_estimator: str = "analytic", impl: str = "pallas",
                   block_t: int = None, interpret: bool = None):
    """One fused pass over the cut layer, all J nodes in one launch.

    mu/logvar/eps: (..., d) — fold any leading axes (J clients, batch,
    sequence) in; they become the row grid.  Returns
    (u (..., d) in mu.dtype, rate (...,) fp32).

    link_bits >= 32 disables the quantizer; rate_estimator selects the
    paper's sampled eq.-(6) estimator (evaluated at the quantized latent)
    or the analytic Gaussian KL.  Gradients flow through the hand-written
    fused backward (eq. 10), never through AD of the kernel body."""
    return _cutlayer_call(mu, logvar, eps, link_bits, rate_estimator, impl,
                          block_t, _resolve_interpret(interpret))


def bottleneck_fused(mu, logvar, eps, *, block_t: int = DEFAULT_BLOCK_T,
                     interpret: bool = None):
    """Seed-compatible entry: u = mu + exp(logvar/2)*eps (no quantizer) and
    the per-row analytic KL.  mu/logvar/eps: (T, d); returns (u, kl).

    T need not divide block_t (rows are padded internally); interpret=None
    auto-detects the backend."""
    return cutlayer_fused(mu, logvar, eps, link_bits=32,
                          rate_estimator="analytic", impl="pallas",
                          block_t=block_t, interpret=interpret)
