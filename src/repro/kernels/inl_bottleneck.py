"""Pallas TPU kernel for the INL bottleneck hot-spot: fused
[mu, logvar -> reparametrised sample -> per-sample KL rate].

This is the paper's per-node/per-sample inner loop (eq. 6's rate term + the
reparametrization trick).  Unfused, XLA issues 4 HBM round-trips over the
(T, d) latent tensors (exp, mul-add, square-sum, log-sum); fused, each tile
is read once into VMEM and both outputs (u, kl) are produced in one pass —
the op is bandwidth-bound, so fusion is worth ~4x on the cut layer.

Tiling: rows (tokens*nodes) x d_bottleneck tiles of (BLOCK_T, d); d_b is
small (<= 1024) so a full row fits VMEM comfortably.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 256


def _bottleneck_kernel(mu_ref, logvar_ref, eps_ref, u_ref, kl_ref):
    mu = mu_ref[...].astype(jnp.float32)
    lv = logvar_ref[...].astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    sigma = jnp.exp(0.5 * lv)
    u = mu + sigma * eps
    u_ref[...] = u.astype(u_ref.dtype)
    # KL(N(mu, sigma^2) || N(0, I)) per row
    kl = 0.5 * jnp.sum(jnp.exp(lv) + mu * mu - 1.0 - lv, axis=-1)
    kl_ref[...] = kl.astype(kl_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "interpret"))
def bottleneck_fused(mu, logvar, eps, *, block_t: int = DEFAULT_BLOCK_T,
                     interpret: bool = True):
    """mu/logvar/eps: (T, d).  Returns (u (T,d) in mu.dtype, kl (T,) fp32).

    T % block_t == 0 required (pad upstream)."""
    T, d = mu.shape
    block_t = min(block_t, T)
    assert T % block_t == 0

    grid = (T // block_t,)
    spec = pl.BlockSpec((block_t, d), lambda i: (i, 0))
    u, kl = pl.pallas_call(
        _bottleneck_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, pl.BlockSpec((block_t,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((T, d), mu.dtype),
                   jax.ShapeDtypeStruct((T,), jnp.float32)],
        interpret=interpret,
    )(mu, logvar, eps)
    return u, kl
