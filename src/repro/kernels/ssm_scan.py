"""Pallas TPU kernel for the chunked Mamba2/SSD selective scan.

TPU adaptation (vs the CUDA selective-scan): instead of a warp-parallel
linear recurrence, the sequence is chunked so that *within* a chunk all work
is dense matmuls on the MXU (decay-weighted (C B^T) attention-like matrix and
state outer products), and the only sequential dependency is the (N x P)
state carried BETWEEN chunks — held in a VMEM scratch across the innermost
(sequential) grid dimension.  This is the SSD block-decomposition of Mamba2,
mapped onto Pallas's sequential-grid + scratch-carry idiom.

Grid: (B*H, num_chunks) — the chunk axis is the sequential innermost axis.
The per-head state (N, P) persists in scratch; chunk 0 zeroes it.

Validated in interpret mode against kernels/ref.py's sequential scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_ref,
                *, chunk: int):
    """Refs (per grid step): x (chunk, P), dt (chunk, 1), a (1, 1),
    b/c (chunk, N), d (1, 1), y (chunk, P); scratch state (N, P) fp32."""
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)                    # (cs, P)
    dt = dt_ref[...].astype(jnp.float32)                  # (cs, 1)
    a = a_ref[0, 0].astype(jnp.float32)                   # scalar (negative)
    bm = b_ref[...].astype(jnp.float32)                   # (cs, N)
    cm = c_ref[...].astype(jnp.float32)                   # (cs, N)
    dskip = d_ref[0, 0].astype(jnp.float32)

    dA = dt * a                                           # (cs, 1), <= 0
    cum = jnp.cumsum(dA, axis=0)                          # (cs, 1)

    # intra-chunk: att[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j, j <= i
    seg = cum - cum.reshape(1, chunk)                     # (cs, cs) = cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(jj <= ii, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    att = cb * decay * dt.reshape(1, chunk)
    y = jax.lax.dot(att, x, preferred_element_type=jnp.float32)

    # inter-chunk: y += (C * exp(cum)) @ state
    state = state_ref[...]
    y = y + jax.lax.dot(cm * jnp.exp(cum), state,
                        preferred_element_type=jnp.float32)

    # state update: state' = exp(cum[-1]) * state + B^T diag(exp(cum[-1]-cum)*dt) X
    gamma = jnp.exp(cum[chunk - 1, 0])
    w = jnp.exp(cum[chunk - 1, 0] - cum) * dt             # (cs, 1)
    upd = jax.lax.dot_general(bm * w, x, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    state_ref[...] = gamma * state + upd

    y_ref[...] = (y + dskip * x).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, bm, cm, dskip, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = True):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) post-softplus; a: (H,) negative;
    bm, cm: (B, S, N) shared across heads (ngroups=1); dskip: (H,).
    Returns y: (B, S, H, P).  S % chunk == 0 required.
    """
    B, S, H, P = x.shape
    N = bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    xt = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtt = dt.transpose(0, 2, 1).reshape(B * H, S, 1)
    at = a.reshape(H, 1, 1)
    dt_skip = dskip.reshape(H, 1, 1)

    grid = (B * H, nc)

    def bh_map(bh, ci):
        return (bh, ci, 0)

    def b_shared_map(bh, ci):
        return (bh // H, ci, 0)

    def head_map(bh, ci):
        return (bh % H, 0, 0)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, P), bh_map),       # x
            pl.BlockSpec((None, chunk, 1), bh_map),       # dt
            pl.BlockSpec((None, 1, 1), head_map),         # a
            pl.BlockSpec((None, chunk, N), b_shared_map),  # B
            pl.BlockSpec((None, chunk, N), b_shared_map),  # C
            pl.BlockSpec((None, 1, 1), head_map),         # D
        ],
        out_specs=pl.BlockSpec((None, chunk, P), bh_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, at, bm, cm, dt_skip)
    return out.reshape(B, H, S, P).transpose(0, 2, 1, 3)
