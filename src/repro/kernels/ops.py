"""Dispatching wrappers for the Pallas kernels.

On TPU the compiled Pallas kernels run natively (interpret=False); on CPU
(this container) they execute in interpret mode for correctness, and the
model code defaults to its jnp formulations (models/attention.py's blockwise
scan, models/ssm.py's chunked SSD) which XLA compiles efficiently.  The
`backend` argument makes the choice explicit and testable:

    backend="pallas"     pallas_call, interpret on CPU / compiled on TPU
    backend="reference"  kernels/ref.py jnp oracle
    backend="auto"       pallas on TPU, reference elsewhere
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import inl_bottleneck as _bn
from repro.kernels import ref
from repro.kernels import ssm_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "reference"
    return backend


def attention(q, k, v, *, causal=True, window=0, q_offset=0,
              backend: str = "auto", **block_kw):
    if _resolve(backend) == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset,
                                   interpret=not _on_tpu(), **block_kw)
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)


def bottleneck(mu, logvar, eps, *, backend: str = "auto", **block_kw):
    if _resolve(backend) == "pallas":
        return _bn.bottleneck_fused(mu, logvar, eps,
                                    interpret=not _on_tpu(), **block_kw)
    return ref.bottleneck_ref(mu, logvar, eps)


def ssd_scan(x, dt, a, bm, cm, dskip, *, backend: str = "auto", **block_kw):
    if _resolve(backend) == "pallas":
        return _ssd.ssd_scan(x, dt, a, bm, cm, dskip,
                             interpret=not _on_tpu(), **block_kw)
    return ref.ssd_scan_ref(x, dt, a, bm, cm, dskip)
