"""Dispatching wrappers for the Pallas kernels.

On TPU the compiled Pallas kernels run natively (interpret=False); on CPU
(this container) they execute in interpret mode for correctness, and the
model code defaults to its jnp formulations (models/attention.py's blockwise
scan, models/ssm.py's chunked SSD) which XLA compiles efficiently.  The
`backend` argument makes the choice explicit and testable:

    backend="pallas"     pallas_call, interpret on CPU / compiled on TPU
    backend="reference"  kernels/ref.py jnp oracle
    backend="auto"       pallas on TPU, reference elsewhere

`cutlayer` is the fused cut-layer megakernel (inl_bottleneck.py): sample +
link-quantize + rate in one forward pass, the paper's eq.-(10) error-vector
split in one backward pass, under a single shared `jax.custom_vjp`.  Both
backends run that same VJP wrapper — "reference" swaps the kernel bodies
for the jnp oracle so CPU CI exercises the training code path exactly.

`resolve_backend` / `on_tpu` are the canonical resolvers; kernel modules
use them for their `interpret=None` auto-detection (a kernel must never
silently interpret on TPU, nor compile Mosaic on CPU).
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import inl_bottleneck as _bn
from repro.kernels import ref
from repro.kernels import ssm_scan as _ssd


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


_on_tpu = on_tpu                      # back-compat alias


def resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if on_tpu() else "reference"
    if backend not in ("pallas", "reference"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


_resolve = resolve_backend            # back-compat alias


def attention(q, k, v, *, causal=True, window=0, q_offset=0,
              backend: str = "auto", **block_kw):
    if resolve_backend(backend) == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset,
                                   interpret=not on_tpu(), **block_kw)
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)


def bottleneck(mu, logvar, eps, *, backend: str = "auto", **block_kw):
    """Seed-compatible fused sample + analytic KL (no quantizer)."""
    if resolve_backend(backend) == "pallas":
        return _bn.bottleneck_fused(mu, logvar, eps,
                                    interpret=not on_tpu(), **block_kw)
    return ref.bottleneck_ref(mu, logvar, eps)


def cutlayer(mu, logvar, eps, *, link_bits: int = 32,
             rate_estimator: str = "sample", prior_mu=None,
             prior_logvar=None, backend: str = "auto",
             block_t: int = None):
    """Fused cut layer: (u_quantized, per-row rate) in one kernel pass,
    custom-VJP backward.  mu/logvar/eps: (..., d) with all leading axes
    (clients, batch, sequence) folded into the row grid — one launch for
    all J nodes.  rate_estimator "none" zeroes the rate (split learning's
    deterministic cut); prior_mu/prior_logvar — (d,) shared or (J, d)
    per-node — evaluate the rate against a learned Gaussian prior, still
    in one fused pass per direction (prior grads included).

    Dtype contract (the mixed-precision policy depends on it): u comes back
    in mu.dtype — a bf16 latent stays bf16 end to end, with only the
    kernels' INTERNAL arithmetic and the rate accumulation in fp32.  The
    dispatch enforces it here so a kernel regression cannot silently widen
    the hot path back to fp32."""
    u, rate = _bn.cutlayer_fused(mu, logvar, eps, link_bits=link_bits,
                                 rate_estimator=rate_estimator,
                                 prior_mu=prior_mu, prior_logvar=prior_logvar,
                                 impl=resolve_backend(backend),
                                 block_t=block_t, interpret=None)
    if u.dtype != mu.dtype:
        raise TypeError(f"cutlayer kernel changed the latent dtype: "
                        f"{mu.dtype} in, {u.dtype} out")
    if rate.dtype != jax.numpy.float32:
        raise TypeError(f"cutlayer rate must accumulate in fp32, got "
                        f"{rate.dtype}")
    return u, rate


def ssd_scan(x, dt, a, bm, cm, dskip, *, backend: str = "auto", **block_kw):
    if resolve_backend(backend) == "pallas":
        return _ssd.ssd_scan(x, dt, a, bm, cm, dskip,
                             interpret=not on_tpu(), **block_kw)
    return ref.ssd_scan_ref(x, dt, a, bm, cm, dskip)
