"""Pallas TPU flash attention (blockwise online-softmax), causal + GQA +
sliding window.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * Tiling is BlockSpec-driven: q tiles (BLOCK_Q x Dh) live in VMEM; the kv
    loop walks (BLOCK_K x Dh) tiles.  BLOCK_Q/BLOCK_K default to 128 — the
    MXU systolic dim — so every partial matmul is 128-aligned.
  * GQA is handled with a ZERO-COPY index map: the kv BlockSpec maps query
    head h to kv head h // group, so grouped keys are never materialised.
  * The causal early-exit (skipping kv tiles fully above the diagonal) is a
    grid-size reduction per q tile via the kv upper bound, not warp-level
    control flow.

Target: TPU (MXU 128x128, VMEM ~16 MB).  Validated with interpret=True on CPU
against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_k: int,
                 causal: bool, window: int, scale: float, q_offset: int):
    """Grid: (batch*heads, num_q_blocks).  Refs:
    q_ref (block_q, Dh), k_ref/v_ref (seq_k, Dh) full-row VMEM views,
    o_ref (block_q, Dh)."""
    block_q, dh = q_ref.shape
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0) \
        + q_offset

    nk = seq_k // block_k

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(ki * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(ki * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window:
            mask = mask & (q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1, keepdims=True)
        acc_new = acc * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    a0 = jnp.zeros((block_q, dh), jnp.float32)
    if causal:
        # causal early exit: kv tiles strictly above the diagonal are skipped
        hi = jnp.minimum(
            (qi + 1) * block_q + q_offset + block_k - 1, seq_k) // block_k
    else:
        hi = nk
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K, interpret: bool = True):
    """q: (B, Sq, H, Dh); k, v: (B, Sk, KV, Dh).  Returns (B, Sq, H, Dh).

    Sq % block_q == 0 and Sk % block_k == 0 are required (pad upstream).
    """
    B, Sq, H, Dh = q.shape
    _, Sk, KV, _ = k.shape
    g = H // KV
    scale = 1.0 / np.sqrt(Dh)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dh)
    kt = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, Dh)
    vt = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, Dh)

    grid = (B * H, Sq // block_q)

    def q_map(bh, qi):
        return (bh, qi, 0)

    def kv_map(bh, qi):
        # zero-copy GQA: query head -> its kv head
        b = bh // H
        h = bh % H
        return (b * KV + h // g, 0, 0)

    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_k=block_k, seq_k=Sk,
                          causal=causal, window=window, scale=scale,
                          q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, Dh), q_map),
            pl.BlockSpec((None, Sk, Dh), kv_map),
            pl.BlockSpec((None, Sk, Dh), kv_map),
        ],
        out_specs=pl.BlockSpec((None, block_q, Dh), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, Dh), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, Dh).transpose(0, 2, 1, 3)
