"""DeepSeek-V2 236B [arXiv:2405.04434] — MLA + fine-grained MoE.

60L d5120 128H, MLA kv_lora_rank=512 (qk 128 nope + 64 rope, v 128),
MoE: 2 shared + 160 routed experts top-6, d_ff_expert 1536, vocab 102400.
Layer 0 is dense (d_ff = 8 * 1536 = 12288, the standard DSv2 ratio of the
dense FFN to the expert FFN).

MLA is the arch most aligned with the paper's idea: the KV cache stores the
*compressed* latent c_kv (rank 512) + shared rope key — a learned bottleneck
representation, exactly the kind of compressed feature INL ships over links.
"""
from repro.configs.base import (ModelConfig, MoEConfig, MLAConfig, INLConfig,
                                register)

CONFIG = register(
    ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        d_ff=12_288,                      # dense layer-0 FFN width
        vocab_size=102_400,
        head_dim=192,                     # qk head dim (128 nope + 64 rope)
        use_mla=True,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      qk_rope_head_dim=64, qk_nope_head_dim=128,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=160, experts_per_token=6,
                      num_shared_experts=2, d_ff_expert=1536,
                      first_dense_layers=1),
        inl=INLConfig(num_nodes=8, encoder_layers=2, d_bottleneck=640),
        source="[arXiv:2405.04434]",
    ),
    smoke=ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        head_dim=48,
        use_mla=True,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                      qk_rope_head_dim=16, qk_nope_head_dim=32,
                      v_head_dim=32),
        moe=MoEConfig(num_experts=4, experts_per_token=2,
                      num_shared_experts=1, d_ff_expert=64,
                      first_dense_layers=1),
        inl=INLConfig(num_nodes=2, encoder_layers=1, d_bottleneck=32),
        source="[arXiv:2405.04434]",
    ),
)
