"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] — dense-MoE hybrid.

35L d7168 56H (GQA kv=8) dense-residual d_ff 4864 alongside a 128-expert
top-2 MoE on every layer (Arctic's signature dense+MoE parallel residual).
"""
from repro.configs.base import ModelConfig, MoEConfig, INLConfig, register

CONFIG = register(
    ModelConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,
        vocab_size=32_000,
        rope_theta=1e6,
        moe=MoEConfig(num_experts=128, experts_per_token=2,
                      d_ff_expert=4864, dense_residual=True),
        inl=INLConfig(num_nodes=8, encoder_layers=2, d_bottleneck=896),
        source="[hf:Snowflake/snowflake-arctic-base]",
    ),
    smoke=ModelConfig(
        name="arctic-480b",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, experts_per_token=2,
                      d_ff_expert=128, dense_residual=True),
        inl=INLConfig(num_nodes=2, encoder_layers=1, d_bottleneck=32),
        source="[hf:Snowflake/snowflake-arctic-base]",
    ),
)
