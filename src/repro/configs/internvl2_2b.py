"""InternVL2-2B [arXiv:2404.16821] — InternViT + InternLM2 (backbone only).

24L d2048 16H (GQA kv=8) d_ff 8192, vocab 92553. The InternViT vision encoder
and MLP projector are a STUB: input_specs() provides 256 precomputed patch
embeddings (B, 256, d_model) that are prepended to the text-token embeddings.
"""
from repro.configs.base import ModelConfig, INLConfig, register

CONFIG = register(
    ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=92_553,
        rope_theta=1e6,
        modality="vlm",
        num_prefix_tokens=256,
        inl=INLConfig(num_nodes=4, encoder_layers=2, d_bottleneck=512),
        source="[arXiv:2404.16821]",
    ),
    smoke=ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        modality="vlm",
        num_prefix_tokens=16,
        inl=INLConfig(num_nodes=2, encoder_layers=1, d_bottleneck=32),
        source="[arXiv:2404.16821]",
    ),
)
