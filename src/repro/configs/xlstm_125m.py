"""xLSTM-125M [arXiv:2405.04517] — sLSTM + mLSTM blocks, 12L d768 4H.

Block pattern follows the xLSTM[7:1]-style interleave: one sLSTM block per
four-block period, the rest mLSTM (matrix-memory, linear-attention-like).
d_ff=0 in the assignment: xLSTM blocks carry their own up/down projections
instead of a separate FFN sublayer.
"""
from repro.configs.base import ModelConfig, SSMConfig, INLConfig, register

_PATTERN = ("mlstm", "mlstm", "mlstm", "slstm")

CONFIG = register(
    ModelConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50_304,
        head_dim=192,
        block_pattern=_PATTERN,
        ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, head_dim=384,
                      chunk_size=256),
        inl=INLConfig(num_nodes=4, encoder_layers=2, d_bottleneck=192),
        source="[arXiv:2405.04517]",
    ),
    smoke=ModelConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=2,
        d_model=128,
        num_heads=2,
        num_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        head_dim=64,
        block_pattern=("mlstm", "slstm"),
        ssm=SSMConfig(state_dim=16, conv_width=4, expand=2, head_dim=64,
                      chunk_size=64),
        inl=INLConfig(num_nodes=2, encoder_layers=1, d_bottleneck=32),
        source="[arXiv:2405.04517]",
    ),
)
