"""MusicGen-medium [arXiv:2306.05284] — decoder-only LM over EnCodec tokens.

48L d1536 24H (MHA) d_ff 6144, vocab 2048 per codebook, 4 codebooks.
The EnCodec conv codec frontend is a STUB: input_specs() provides precomputed
frame embeddings (B, S, d_model); the decoder predicts the 4 codebook token
streams with 4 parallel LM heads (delay-pattern interleave handled by the
data pipeline, not the backbone).
"""
from repro.configs.base import ModelConfig, INLConfig, register

CONFIG = register(
    ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        modality="audio_tokens",
        num_codebooks=4,
        act="gelu",
        inl=INLConfig(num_nodes=4, encoder_layers=2, d_bottleneck=384),
        source="[arXiv:2306.05284]",
    ),
    smoke=ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=256,
        modality="audio_tokens",
        num_codebooks=4,
        act="gelu",
        inl=INLConfig(num_nodes=2, encoder_layers=1, d_bottleneck=32),
        source="[arXiv:2306.05284]",
    ),
)
