"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B] — small llama3 dense.

16L d2048 32H (GQA kv=8) d_ff 8192, vocab 128256, tied embeddings.
"""
from repro.configs.base import ModelConfig, INLConfig, register

CONFIG = register(
    ModelConfig(
        name="llama3.2-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=128_256,
        rope_theta=500_000.0,
        tie_embeddings=True,
        inl=INLConfig(num_nodes=4, encoder_layers=2, d_bottleneck=512),
        source="[hf:meta-llama/Llama-3.2-1B]",
    ),
    smoke=ModelConfig(
        name="llama3.2-1b",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        tie_embeddings=True,
        inl=INLConfig(num_nodes=2, encoder_layers=1, d_bottleneck=32),
        source="[hf:meta-llama/Llama-3.2-1B]",
    ),
)
