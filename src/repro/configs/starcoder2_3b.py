"""StarCoder2-3B [arXiv:2402.19173] — dense, near-MQA GQA, RoPE.

30L d3072 24H (GQA kv=2) d_ff 12288, vocab 49152. StarCoder2 natively uses a
4k sliding window; we keep full attention for the standard shapes (faithful
to the assignment header) and the sliding-window variant for long_500k.
"""
from repro.configs.base import ModelConfig, INLConfig, register

CONFIG = register(
    ModelConfig(
        name="starcoder2-3b",
        family="dense",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        d_ff=12_288,
        vocab_size=49_152,
        qkv_bias=True,
        rope_theta=1e5,
        act="gelu",
        inl=INLConfig(num_nodes=4, encoder_layers=2, d_bottleneck=768),
        source="[arXiv:2402.19173]",
    ),
    smoke=ModelConfig(
        name="starcoder2-3b",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        qkv_bias=True,
        act="gelu",
        inl=INLConfig(num_nodes=2, encoder_layers=1, d_bottleneck=32),
        source="[arXiv:2402.19173]",
    ),
)
