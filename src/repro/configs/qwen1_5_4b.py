"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B arch family] — dense, QKV bias.

40L d2560 20H (GQA kv=20 == MHA) d_ff 6912, vocab 151936.
"""
from repro.configs.base import ModelConfig, INLConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        num_layers=40,
        d_model=2560,
        num_heads=20,
        num_kv_heads=20,
        d_ff=6912,
        vocab_size=151_936,
        qkv_bias=True,
        rope_theta=1e6,
        inl=INLConfig(num_nodes=4, encoder_layers=2, d_bottleneck=640),
        source="[hf:Qwen/Qwen1.5-0.5B]",
    ),
    smoke=ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        qkv_bias=True,
        inl=INLConfig(num_nodes=2, encoder_layers=1, d_bottleneck=32),
        source="[hf:Qwen/Qwen1.5-0.5B]",
    ),
)
