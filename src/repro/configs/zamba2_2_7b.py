"""Zamba2-2.7B [arXiv:2411.15242] — Mamba2 backbone + shared attention blocks.

54 Mamba2 layers d2560 (ssm_state=64) with a parameter-SHARED attention+MLP
block (32H, d_ff 10240) applied every 6th layer on concat([h, h_embed])
projected back to d_model — Zamba2's global-shared-attention design.
"""
from repro.configs.base import ModelConfig, SSMConfig, INLConfig, register

# Repeating 6-layer period: 5 pure mamba2 blocks then mamba2 + shared attention.
_PATTERN = ("mamba",) * 5 + ("mamba+shared_attn",)

CONFIG = register(
    ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10_240,
        vocab_size=32_000,
        head_dim=80,
        block_pattern=_PATTERN,
        ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, head_dim=64,
                      chunk_size=256),
        inl=INLConfig(num_nodes=4, encoder_layers=2, d_bottleneck=640),
        source="[arXiv:2411.15242]",
    ),
    smoke=ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        block_pattern=("mamba", "mamba+shared_attn"),
        ssm=SSMConfig(state_dim=16, conv_width=4, expand=2, head_dim=32,
                      chunk_size=64),
        inl=INLConfig(num_nodes=2, encoder_layers=1, d_bottleneck=32),
        source="[arXiv:2411.15242]",
    ),
)
