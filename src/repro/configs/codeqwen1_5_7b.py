"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B] — qwen1.5 arch at 7B.

32L d4096 32H (MHA) d_ff 13440, vocab 92416, QKV bias.
"""
from repro.configs.base import ModelConfig, INLConfig, register

CONFIG = register(
    ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=13_440,
        vocab_size=92_416,
        qkv_bias=True,
        rope_theta=1e6,
        inl=INLConfig(num_nodes=8, encoder_layers=2, d_bottleneck=512),
        source="[hf:Qwen/CodeQwen1.5-7B]",
    ),
    smoke=ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        qkv_bias=True,
        inl=INLConfig(num_nodes=2, encoder_layers=1, d_bottleneck=32),
        source="[hf:Qwen/CodeQwen1.5-7B]",
    ),
)
