"""Config system: model configs, input-shape configs, and the arch registry.

Every assigned architecture registers a full production config (exercised only
through the dry-run, via ShapeDtypeStruct) and a reduced smoke config
(instantiated for real on CPU in tests/examples).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    experts_per_token: int = 0      # top-k
    num_shared_experts: int = 0     # always-on experts (deepseek-v2)
    d_ff_expert: int = 0            # per-expert hidden dim
    dense_residual: bool = False    # arctic: dense FFN in parallel with MoE
    first_dense_layers: int = 0     # deepseek-v2: leading dense layers
    capacity_factor: float = 1.25   # expert-parallel dispatch capacity
    router_aux_weight: float = 1e-2  # load-balance auxiliary loss weight
    router_z_weight: float = 1e-3    # router z-loss weight

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = full-rank q projection
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_rope_head_dim + self.qk_nope_head_dim


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / xLSTM state-space parameters."""
    state_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    head_dim: int = 64              # mamba2 SSD head dim
    chunk_size: int = 256           # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class INLConfig:
    """In-network-learning vertical split (the paper's technique).

    The model is split into J encoder branches (each `encoder_layers` blocks of
    the arch's own family, width `d_encoder`) terminated by a stochastic
    Gaussian bottleneck of width `d_bottleneck` per node, plus the remaining
    stack as the fusion decoder at node J+1.  Eq. (5): J * d_bottleneck must
    equal the decoder input width.
    """
    num_nodes: int = 5              # J
    encoder_layers: int = 2
    d_bottleneck: int = 64          # latent dim per node (u_j)
    s: float = 1e-2                 # Lagrange multiplier of eq. (6)
    link_bits: int = 16             # bits per activation value on the link (s in §III-C)
    learned_prior: bool = False     # Q_psi(u_j): standard normal vs learned marginal


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- attention ---
    head_dim: int = 0               # 0 = d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0         # 0 = full attention; >0 = window size
    use_mla: bool = False
    mla: MLAConfig = field(default_factory=MLAConfig)
    # --- MoE ---
    moe: MoEConfig = field(default_factory=MoEConfig)
    # --- SSM / hybrid ---
    ssm: SSMConfig = field(default_factory=SSMConfig)
    block_pattern: Tuple[str, ...] = ()   # e.g. ('mamba',)*5 + ('mamba+shared_attn',)
    # xlstm: which block types in the repeating pattern ('mlstm' / 'slstm')
    # --- modality ---
    modality: str = "text"          # text | audio_tokens | vlm
    num_prefix_tokens: int = 0      # vlm: patch tokens prepended
    num_codebooks: int = 1          # audio: parallel codebooks (output heads)
    # --- misc ---
    norm_eps: float = 1e-5
    act: str = "silu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True              # activation checkpointing on scanned blocks
    scan_layers: bool = True        # lax.scan over layer stack (False = unroll)
    # flash-attention tile sizes and CE sequence-chunk (0 = library default).
    # The dry-run's cost-oracle variants set these to the full sequence so no
    # FLOPs hide inside scan bodies (never executed, only cost-analysed).
    attn_block_q: int = 0
    attn_block_k: int = 0
    ce_chunk: int = 0
    # MoE dispatch: "ep" = shard_map expert-parallel (local dispatch + one
    # psum; §Perf iteration 5), "gspmd" = partitioner-chosen scatter (the
    # frozen baseline).  "ep" falls back to "gspmd" when no mesh is active.
    moe_impl: str = "ep"
    # --- the paper's technique ---
    inl: INLConfig = field(default_factory=INLConfig)
    source: str = ""                # citation bracket from the assignment

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: num_heads={self.num_heads} not divisible by "
            f"num_kv_heads={self.num_kv_heads}")

    # ---- derived quantities -------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe.enabled

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def with_sliding_window(self, window: int) -> "ModelConfig":
        return replace(self, sliding_window=window)

    def param_count(self) -> int:
        """Analytic parameter count N (used for FL bandwidth + roofline 6ND)."""
        from repro.models import zoo
        return zoo.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import zoo
        return zoo.param_count(self, active_only=True)


# ---------------------------------------------------------------------------
# Input-shape configs (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                       # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Sliding-window size used to make dense archs sub-quadratic for long_500k.
LONG_CONTEXT_WINDOW = 8_192


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_MODULES = [
    "xlstm_125m", "qwen1_5_4b", "arctic_480b", "llama3_2_1b",
    "musicgen_medium", "internvl2_2b", "starcoder2_3b", "deepseek_v2_236b",
    "codeqwen1_5_7b", "zamba2_2_7b", "paper_inl",
]

_REGISTRY: dict = {}
_SMOKE_REGISTRY: dict = {}


def register(config: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[config.name] = config
    _SMOKE_REGISTRY[config.name] = smoke
    return config


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    key = name.replace("_", "-")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def get_smoke_config(name: str) -> ModelConfig:
    _ensure_loaded()
    key = name.replace("_", "-")
    return _SMOKE_REGISTRY[key]


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def arch_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Adapt a config to an input shape: dense/full-attention archs switch to
    the sliding-window variant for long_500k (sub-quadratic requirement)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid") \
            and cfg.sliding_window == 0:
        return cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    return cfg
