from repro.configs.base import (INPUT_SHAPES, LONG_CONTEXT_WINDOW,  # noqa
                                INLConfig, MLAConfig, ModelConfig, MoEConfig,
                                ShapeConfig, SSMConfig, arch_for_shape,
                                get_config, get_smoke_config, list_archs)
