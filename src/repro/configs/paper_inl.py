"""The paper's own experiment setup (§IV): CIFAR-10, J=5 clients observing
Gaussian-noise-corrupted views (sigma = 0.4, 1, 2, 3, 4), VGG-style conv
encoders per client, two dense layers at node J+1.

This is not one of the 10 assigned LLM architectures — it is the faithful
reproduction target for Figures 5/7 and Table I, driven by repro.core.inl
with the conv model in repro.core.paper_model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class PaperExperimentConfig:
    num_clients: int = 5                         # J
    noise_stds: Tuple[float, ...] = (0.4, 1.0, 2.0, 3.0, 4.0)
    num_classes: int = 10
    image_shape: Tuple[int, int, int] = (32, 32, 3)
    # VGG-style encoder at each client (Fig. 4 "Conv" column, reduced widths
    # are configurable for CPU-sized runs)
    conv_channels: Tuple[int, ...] = (32, 64, 128)
    d_bottleneck: int = 64                       # u_j width -> p = J * 64
    # node (J+1): two dense layers (Fig. 4)
    dense_units: Tuple[int, ...] = (512, 256)
    s: float = 1e-2                              # eq. (6) Lagrange multiplier
    # mixed-precision policy: "fp32" (default) or "bf16" — encoder/decoder
    # convs and denses run at this dtype; master params, optimizer state,
    # BatchNorm stats and the kernels' rate/KL accumulation stay fp32
    # (core/paper_model.compute_dtype / cast_compute)
    compute_dtype: str = "fp32"
    link_bits: int = 32                          # bits per activation value
    # Q_psi_j(u_j): standard normal (False) or learned per-node Gaussian
    # marginals (True, trained jointly via the fused kernel's prior path)
    learned_prior: bool = False
    # the inference graph (a core/topology.Topology: star/chain/tree, or
    # any validated single-sink DAG with per-edge link_bits/wire/dtype).
    # None — or an all-default star — keeps every code path bit-identical
    # to the pre-topology star; explicit `topology=` arguments to the
    # Scheme API override this field per call.
    topology: object = None
    # unreliable-network training (core/linkfault.py): per-round
    # probability that each view node's transmission is dropped during
    # TRAINING on top of any per-edge LinkModel erasures — the node-dropout
    # curriculum that teaches the fusion center to degrade gracefully.
    # 0.0 (default) keeps every code path bit-identical to the pre-fault
    # graph unless an edge carries a LinkModel.
    edge_dropout: float = 0.0
    # straggler deadline: when set (milliseconds) and edges carry latency/
    # bandwidth models, the fusion center fuses whatever arrived within
    # the deadline and masks the rest (fuse-what-arrived semantics).
    fusion_deadline_ms: object = None
    # hybrid-scheme knobs (core/schemes/splitfed.py, hybrid.py).  cut_depth
    # truncates the CLIENT-side conv trunk to its first k blocks (None keeps
    # the full trunk — the classic SL boundary right before the bottleneck
    # head); hybrid_fl_clients names the clients that participate FL-style
    # (full local model + weight exchange) instead of shipping cut-layer
    # activations.  Both are ignored by the pure inl/fl/sl schemes, so the
    # defaults keep every existing trajectory bit-identical.
    cut_depth: object = None
    hybrid_fl_clients: Tuple[int, ...] = (0,)
    # experiment 1 partitions data per scheme; experiment 2 shares it
    experiment: int = 1
    dataset_size: int = 50_000
    seed: int = 0


SMOKE = PaperExperimentConfig(
    conv_channels=(8, 16), d_bottleneck=16, dense_units=(64,),
    dataset_size=512)
