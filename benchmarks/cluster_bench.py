"""Benchmark — the multi-process worker plane: supervised measure-node
processes under the SAME counter-seeded transport as the in-process runs.

Every fault draw is a pure function of (seed, domain, tick, edge, attempt)
and never sees the channel kind, and supervision advances in TICK time
(the supervisor's `tick` rides the transport's `on_tick` hook) — so a
3-process cluster is not "approximately" the in-process run, it is the
SAME run with the bytes crossing real process boundaries.  The asserts
below are stable CI contracts, not flaky statistics.

Sections, written to BENCH_cluster.json (--json):

  parity            fault-free training over 3 REAL worker processes vs
                    the in-process loopback transport, same seed.
                    ASSERTS the accuracy/bandwidth curves AND the
                    transport snapshots (ledgers + breaker counters) are
                    BIT-IDENTICAL.

  kill_resume       a scheduled mid-epoch-2 worker SIGKILL under a
                    checkpointing run: the golden uninterrupted 2-epoch
                    cluster run vs a run that checkpoints epoch 1, tears
                    the WHOLE cluster down (supervisor restart), and
                    resumes into the same kill window with fresh worker
                    processes.  ASSERTS curve, transport snapshot, and
                    adaptive-policy state are bit-identical — the crash-
                    atomic checkpoint plus uncharged tick replay rebuilds
                    the exact trajectory.

  serving_recovery  one serving request per tick through the engine over
                    a live cluster; one worker SIGKILLed for a window.
                    Goodput = delivered votes / J per request, rolling.
                    ASSERTS goodput during the kill is exactly (J-1)/J,
                    and recovers to >= 0.9x the pre-kill steady state
                    within window + 2 ticks of the scheduled restart.

  adaptive_vs_fixed the AdaptivePolicy controller vs fixed retry
                    constants under rolling edge churn (staggered flaps,
                    4 of every 6 ticks dark per edge).  ASSERTS the
                    adaptive delivered/offered ratio is STRICTLY above
                    the fixed-constant baseline, that it actually retuned,
                    and that a second identical run replays the same
                    snapshot bit for bit.

--smoke shrinks shapes/epochs for the CI bench-smoke step.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile

import numpy as np

from repro.chaos import ChaosSchedule
from repro.cluster import Cluster
from repro.configs.paper_inl import PaperExperimentConfig
from repro.core import schemes
from repro.core import topology as topology_lib
from repro.core.schemes import runner
from repro.data import multiview
from repro.serving import ServingEngine
from repro.transport import (DEFAULT_RETRY, NO_RETRY, AdaptivePolicy,
                             NetworkTransport)


def _cfg(*, smoke: bool):
    """Always 3 measure nodes — the bench's process-count contract — with
    smoke-vs-full deciding the model/dataset shapes."""
    if smoke:
        return PaperExperimentConfig(
            num_clients=3, noise_stds=(0.4, 1.0, 2.0),
            conv_channels=(4,), d_bottleneck=8, dense_units=(32,),
            image_shape=(16, 16, 3), dataset_size=128)
    return PaperExperimentConfig(
        num_clients=3, noise_stds=(0.4, 1.0, 2.0),
        conv_channels=(8, 16), d_bottleneck=16, dense_units=(64,),
        image_shape=(32, 32, 3), dataset_size=512)


def _data(cfg, seed):
    imgs, labels = multiview.make_base_dataset(
        cfg.dataset_size, image_shape=cfg.image_shape, seed=seed)
    views = multiview.make_views(imgs, cfg.noise_stds)
    return np.asarray(views), np.asarray(labels)


def _rounds_per_epoch(cfg, batch_size):
    bpr = schemes.get("inl").batches_per_round(cfg)
    return (cfg.dataset_size // batch_size) // bpr


# ---------------------------------------------------------------------------
# 3-process cluster == in-process transport, bit for bit (fault-free)
# ---------------------------------------------------------------------------

def parity_section(*, smoke: bool, epochs: int, seed: int):
    cfg = _cfg(smoke=smoke)
    views, labels = _data(cfg, seed)
    topo = topology_lib.resolve(None, cfg)

    tr = NetworkTransport(topo, cfg, seed=seed + 3, policy=DEFAULT_RETRY)
    inproc = runner.run_scheme("inl", views, labels, cfg, epochs=epochs,
                               batch_size=32, seed=seed, transport=tr)
    isnap = tr.snapshot()
    tr.close()

    with Cluster(cfg, seed=seed + 3, policy=DEFAULT_RETRY) as cl:
        procs = sorted(h.proc.pid for h in cl.supervisor.handles.values())
        clustered = runner.run_scheme("inl", views, labels, cfg,
                                      epochs=epochs, batch_size=32,
                                      seed=seed, transport=cl.transport)
        csnap = cl.transport.snapshot()

    assert len(procs) == 3, f"expected 3 worker processes, got {procs}"
    assert inproc == clustered, (
        "a fault-free 3-process cluster run must be BIT-IDENTICAL to the "
        "in-process transport run: the fault draws never see the channel "
        "kind, so crossing real process boundaries changes nothing")
    assert isnap == csnap, (
        f"transport snapshots diverged across channel kinds:\n"
        f"in-process {isnap}\ncluster    {csnap}")
    print(f"parity: {len(procs)}-process cluster == in-process, "
          f"{epochs} epochs bit for bit "
          f"(final acc {clustered[-1].accuracy:.3f})")
    return {"workers": len(procs), "epochs": epochs,
            "bitwise_identical": True,
            "final_accuracy": clustered[-1].accuracy,
            "delivery_ratio": csnap["delivery_ratio"]}


# ---------------------------------------------------------------------------
# mid-epoch SIGKILL + supervisor restart resumes bit-identically
# ---------------------------------------------------------------------------

def kill_resume_section(*, smoke: bool, seed: int):
    cfg = _cfg(smoke=smoke)
    views, labels = _data(cfg, seed)
    epochs, batch = 2, 32
    rounds = _rounds_per_epoch(cfg, batch)
    # kill a worker MID-epoch-2 (the epoch the resume re-runs live), plus
    # an epoch-1 edge outage so the adaptive controller has a non-trivial
    # trajectory to rebuild across the resume boundary
    dead = topology_lib.resolve(None, cfg).view_nodes()[1]
    kill_at, kill_len = rounds + max(rounds // 2, 1), max(rounds // 4, 1)
    keys = [e.key for e in topology_lib.resolve(None, cfg).edges]
    chaos = (ChaosSchedule()
             .kill_node(dead, at=kill_at, duration=kill_len)
             .down_edge(keys[0], 1, max(rounds // 2, 1)))

    def run(run_epochs, ckpt_dir=None, resume=False):
        with Cluster(cfg, seed=seed + 5, chaos=chaos, policy=DEFAULT_RETRY,
                     adaptive=AdaptivePolicy(base=DEFAULT_RETRY,
                                             base_threshold=3)) as cl:
            curve = runner.run_scheme(
                "inl", views, labels, cfg, epochs=run_epochs,
                batch_size=batch, seed=seed, transport=cl.transport,
                ckpt_dir=ckpt_dir, resume=resume)
            return curve, cl.transport.snapshot()

    golden, gsnap = run(epochs)

    workdir = tempfile.mkdtemp(prefix="cluster_bench_ckpt_")
    try:
        run(1, ckpt_dir=workdir)            # ... then the cluster "crashes"
        resumed, rsnap = run(epochs, ckpt_dir=workdir, resume=True)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    assert golden == resumed, (
        "resuming from the epoch-1 checkpoint with a FRESH supervisor must "
        "replay the scheduled mid-epoch-2 SIGKILL into the exact golden "
        "curve — state, rng fast-forward, and meter ledgers")
    assert gsnap == rsnap, (
        f"resumed transport snapshot (ledgers + breakers + adaptive state) "
        f"diverged from golden:\n{gsnap}\nvs\n{rsnap}")
    print(f"kill-resume: SIGKILL {dead} at tick {kill_at} for {kill_len} "
          f"rounds; 1+1 epochs across a supervisor restart == {epochs} "
          f"epochs bit for bit (final acc {golden[-1].accuracy:.3f})")
    return {"dead_node": dead, "kill_tick": kill_at,
            "kill_rounds": kill_len, "epochs": epochs,
            "bitwise_identical": True,
            "final_accuracy": golden[-1].accuracy,
            "adaptive_retunes": gsnap["adaptive"]["retunes"]}


# ---------------------------------------------------------------------------
# serving goodput recovery after a worker SIGKILL
# ---------------------------------------------------------------------------

def serving_recovery_section(*, smoke: bool, seed: int):
    cfg = _cfg(smoke=smoke)
    views, _ = _data(cfg, seed)
    J = cfg.num_clients
    kill_at, kill_len, total, window = 8, 4, 24, 4
    kill_end = kill_at + kill_len
    dead = topology_lib.resolve(None, cfg).view_nodes()[1]
    chaos = ChaosSchedule().kill_node(dead, at=kill_at, duration=kill_len)

    scheme = schemes.get("inl")
    import jax
    state = scheme.init(cfg, jax.random.PRNGKey(seed))

    # NO_RETRY + no breaker: delivered votes track the kill window exactly,
    # so "recovery" measures the SUPERVISOR's scheduled restart, not a
    # breaker cooldown tail
    with Cluster(cfg, seed=seed + 7, chaos=chaos, policy=NO_RETRY,
                 breaker=None) as cl:
        engine = ServingEngine(scheme, state, cfg, seed=seed + 2,
                               transport=cl.transport)
        engine.warmup()
        fused = []
        for i in range(total):           # one request per tick, rid == tick
            _, fut = engine.submit(views[:, i % views.shape[1]])
            while not fut.done():
                if engine.step() == 0:
                    break
            fused.append(fut.result().views_fused)

    goodput = [f / J for f in fused]
    pre = float(np.mean(goodput[:kill_at]))
    rolling = [float(np.mean(goodput[max(0, t - window + 1):t + 1]))
               for t in range(total)]
    recovered_at = next((t for t in range(kill_end, total)
                         if rolling[t] >= 0.9 * pre), None)

    assert all(g == 1.0 for g in goodput[:kill_at]), \
        f"pre-kill requests must fuse all {J} views: {goodput[:kill_at]}"
    assert all(abs(g - (J - 1) / J) < 1e-9
               for g in goodput[kill_at:kill_end]), (
        f"a SIGKILLed worker costs each request exactly the votes it "
        f"owned: {goodput[kill_at:kill_end]}")
    assert recovered_at is not None and recovered_at - kill_end <= window + 2, (
        f"rolling goodput must recover to >= 0.9x pre-kill steady state "
        f"({0.9 * pre:.2f}) within {window + 2} ticks of the scheduled "
        f"restart at {kill_end}; rolling={rolling}")
    print(f"serving recovery: goodput {pre:.2f} -> "
          f"{min(goodput[kill_at:kill_end]):.2f} during the kill -> "
          f"recovered at tick {recovered_at} "
          f"({recovered_at - kill_end} ticks after restart)")
    return {"dead_node": dead, "kill_tick": kill_at,
            "kill_rounds": kill_len, "requests": total,
            "pre_kill_goodput": pre,
            "kill_goodput": float(min(goodput[kill_at:kill_end])),
            "recovered_at_tick": recovered_at,
            "recovery_ticks_after_restart": recovered_at - kill_end,
            "shed": engine.stats.shed}


# ---------------------------------------------------------------------------
# adaptive retry/threshold policies vs fixed constants under churn
# ---------------------------------------------------------------------------

def adaptive_vs_fixed_section(*, smoke: bool, seed: int):
    cfg = _cfg(smoke=smoke)
    topo = topology_lib.resolve(None, cfg)
    keys = [e.key for e in topo.edges]
    ticks = 64 if smoke else 128
    # rolling churn: every edge dark 4 of every 6 ticks, phases staggered
    chaos = ChaosSchedule()
    for i, key in enumerate(keys):
        chaos = chaos.flap_edge(key, start=2 * i, stop=ticks, period=6,
                                duty=4)

    def run(adaptive):
        tr = NetworkTransport(topo, cfg, seed=seed + 17,
                              policy=DEFAULT_RETRY, breaker=None,
                              chaos=chaos, adaptive=adaptive)
        for t in range(ticks):
            tr.round_outcome(t, 32)
        snap = tr.snapshot()
        tr.close()
        return snap

    fixed = run(None)
    adaptive = run(AdaptivePolicy(base=DEFAULT_RETRY, base_threshold=3))
    replay = run(AdaptivePolicy(base=DEFAULT_RETRY, base_threshold=3))

    assert adaptive == replay, (
        "the adaptive controller must be DETERMINISTIC: two identical "
        "runs diverged\n"
        f"{adaptive}\nvs\n{replay}")
    assert adaptive["adaptive"]["retunes"] > 0, \
        "the controller never retuned under 4/6-duty churn"
    assert adaptive["delivery_ratio"] > fixed["delivery_ratio"], (
        f"adaptive delivered/offered {adaptive['delivery_ratio']:.3f} must "
        f"be STRICTLY above the fixed-constant {fixed['delivery_ratio']:.3f}"
        " — shrinking the retry budget on a dark edge stops re-offering "
        "full charges into it")
    print(f"adaptive vs fixed under churn: delivered/offered "
          f"{adaptive['delivery_ratio']:.3f} vs {fixed['delivery_ratio']:.3f}"
          f" (retunes={adaptive['adaptive']['retunes']})")
    return {"ticks": ticks,
            "fixed_delivery_ratio": fixed["delivery_ratio"],
            "adaptive_delivery_ratio": adaptive["delivery_ratio"],
            "retunes": adaptive["adaptive"]["retunes"],
            "deterministic_replay": True}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes/epochs (CI bench-smoke step)")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_cluster.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)

    record = {"smoke": args.smoke,
              "parity": parity_section(smoke=args.smoke, epochs=args.epochs,
                                       seed=args.seed),
              "kill_resume": kill_resume_section(smoke=args.smoke,
                                                 seed=args.seed),
              "serving_recovery": serving_recovery_section(smoke=args.smoke,
                                                           seed=args.seed),
              "adaptive_vs_fixed": adaptive_vs_fixed_section(
                  smoke=args.smoke, seed=args.seed)}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")
    return record


if __name__ == "__main__":
    main()
