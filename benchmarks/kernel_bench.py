"""Benchmark 3 — kernel micro-benchmarks.

On this CPU container the timed implementations are the compiled jnp
formulations (what actually executes here); the Pallas kernels are the TPU
target and are validated (not timed) in interpret mode.  us_per_call is
wall-clock over N repetitions after a warmup call.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.models.attention import blockwise_attention
from repro.models.ssm import _ssd_chunked


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def rows():
    key = jax.random.PRNGKey(0)
    out = []

    # flash-style attention vs naive reference, 2k context
    B, S, H, KV, Dh = 1, 2048, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, Dh), jnp.float32)
    fa = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, causal=True))
    na = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    t_f, t_n = _time(fa, q, k, v), _time(na, q, k, v)
    flops = 4 * B * H * S * S * Dh / 2
    out.append(("attention_blockwise_2k", t_f, f"{flops/t_f/1e3:.1f}GFLOPs"))
    out.append(("attention_naive_2k", t_n, f"{flops/t_n/1e3:.1f}GFLOPs"))

    # chunked SSD vs sequential scan, 4k sequence
    B, S, Hh, P, N = 1, 4096, 4, 64, 64
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Hh)))
    a = -jnp.exp(jax.random.normal(ks[2], (Hh,)) * 0.2)
    bm = jax.random.normal(ks[3], (B, S, N))
    cm = jax.random.normal(ks[4], (B, S, N))
    d = jnp.ones((Hh,))
    ch = jax.jit(lambda *t: _ssd_chunked(*t, 128)[0])
    sq = jax.jit(ref.ssd_scan_ref)
    t_c = _time(ch, x, dt, a, bm, cm, d)
    t_s = _time(sq, x, dt, a, bm, cm, d)
    out.append(("ssd_chunked_4k", t_c, f"speedup_vs_seq={t_s/t_c:.1f}x"))
    out.append(("ssd_sequential_4k", t_s, ""))

    # fused bottleneck vs unfused ops
    T, d_b = 8192, 256
    ks = jax.random.split(key, 3)
    mu = jax.random.normal(ks[0], (T, d_b))
    lv = jax.random.normal(ks[1], (T, d_b)) * 0.3
    eps = jax.random.normal(ks[2], (T, d_b))
    fused = jax.jit(ref.bottleneck_ref)           # XLA fuses the jnp form
    t_b = _time(fused, mu, lv, eps)
    bytes_ = 3 * T * d_b * 4
    out.append(("inl_bottleneck_8k", t_b, f"{bytes_/t_b/1e3:.1f}GB/s"))
    return out


def main():
    print("name,us_per_call,derived")
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
