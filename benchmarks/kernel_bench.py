"""Benchmark 3 — kernel micro-benchmarks.

On this CPU container the timed implementations are the compiled jnp
formulations (what actually executes here); the Pallas kernels are the TPU
target and are validated (not timed) in interpret mode.  Timings are the
MEDIAN of N repetitions after a warmup call.

The cut-layer section times the paper's hot inner loop both ways:

    unfused  the seed's 3-pass formulation — reparametrised sample,
             straight-through link quantizer, eq.-(6) rate — as three
             separately compiled passes (three HBM round trips over the
             (T, d) latents), gradients by plain AD.
    fused    kernels/ops.cutlayer — one compiled pass producing u AND the
             rate, hand-written eq.-(10) VJP for the backward.

Results go to stdout (CSV) and, for the cut layer, to a machine-readable
JSON file (--json, default BENCH_cutlayer.json) so the perf trajectory is
tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro.core import linkmodel
from repro.kernels import ops, ref
from repro.models.attention import blockwise_attention
from repro.models.ssm import _ssd_chunked

DEFAULT_REPS = 20


def _time(fn, *args, reps=DEFAULT_REPS):
    """Median wall-clock microseconds per call after one warmup."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


def rows():
    key = jax.random.PRNGKey(0)
    out = []

    # flash-style attention vs naive reference, 2k context
    B, S, H, KV, Dh = 1, 2048, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, Dh), jnp.float32)
    fa = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, causal=True))
    na = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    t_f, t_n = _time(fa, q, k, v, reps=5), _time(na, q, k, v, reps=5)
    flops = 4 * B * H * S * S * Dh / 2
    out.append(("attention_blockwise_2k", t_f, f"{flops/t_f/1e3:.1f}GFLOPs"))
    out.append(("attention_naive_2k", t_n, f"{flops/t_n/1e3:.1f}GFLOPs"))

    # chunked SSD vs sequential scan, 4k sequence
    B, S, Hh, P, N = 1, 4096, 4, 64, 64
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Hh)))
    a = -jnp.exp(jax.random.normal(ks[2], (Hh,)) * 0.2)
    bm = jax.random.normal(ks[3], (B, S, N))
    cm = jax.random.normal(ks[4], (B, S, N))
    d = jnp.ones((Hh,))
    ch = jax.jit(lambda *t: _ssd_chunked(*t, 128)[0])
    sq = jax.jit(ref.ssd_scan_ref)
    t_c = _time(ch, x, dt, a, bm, cm, d, reps=5)
    t_s = _time(sq, x, dt, a, bm, cm, d, reps=5)
    out.append(("ssd_chunked_4k", t_c, f"speedup_vs_seq={t_s/t_c:.1f}x"))
    out.append(("ssd_sequential_4k", t_s, ""))
    return out


def bench_cutlayer(T: int = 8192, d_b: int = 256, bits: int = 8,
                   reps: int = DEFAULT_REPS):
    """Fused megakernel vs the seed's unfused 3-pass cut layer, forward and
    value_and_grad — plus the learned-prior fused path (which must stay
    within ~1.2x of the standard-normal fused path: it reads two extra (d,)
    vectors, not a fourth pass).  Returns (csv_rows, json_record)."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 7)
    mu = jax.random.normal(ks[0], (T, d_b))
    lv = jax.random.normal(ks[1], (T, d_b)) * 0.3
    eps = jax.random.normal(ks[2], (T, d_b))
    cu = jax.random.normal(ks[3], (T, d_b))
    cr = jax.random.normal(ks[4], (T,))
    pmu = jax.random.normal(ks[5], (d_b,)) * 0.5
    plv = jax.random.normal(ks[6], (d_b,)) * 0.3

    # --- unfused: three separately compiled passes (the seed formulation:
    # bottleneck.sample -> linkmodel.quantize_st -> rate term), each a full
    # HBM round trip over the (T, d) latents
    sample_p = jax.jit(lambda mu, lv, eps: mu + jnp.exp(0.5 * lv) * eps)
    quant_p = jax.jit(lambda u: linkmodel.quantize_st(u, bits))
    rate_p = jax.jit(lambda u, mu, lv: 0.5 * jnp.sum(
        u * u - (u - mu) ** 2 * jnp.exp(-lv) - lv, axis=-1))

    def unfused(mu, lv, eps):
        u = quant_p(sample_p(mu, lv, eps))
        return u, rate_p(u, mu, lv)

    def unfused_loss(mu, lv, eps):
        u, r = unfused(mu, lv, eps)
        return (u * cu).sum() + (r * cr).sum()

    unfused_grad = jax.value_and_grad(unfused_loss, argnums=(0, 1))

    # --- fused: one compiled pass + the hand-written eq.-(10) VJP
    # (backend="auto" -> compiled jnp reference on CPU, Pallas on TPU)
    fused = jax.jit(lambda mu, lv, eps: ops.cutlayer(
        mu, lv, eps, link_bits=bits, rate_estimator="sample"))

    @jax.jit
    def fused_loss_grad(mu, lv, eps):
        def loss(mu, lv):
            u, r = ops.cutlayer(mu, lv, eps, link_bits=bits,
                                rate_estimator="sample")
            return (u * cu).sum() + (r * cr).sum()
        return jax.value_and_grad(loss, argnums=(0, 1))(mu, lv)

    # --- learned-prior fused path (same kernel family, prior grid): must
    # not regress to the old unfused-fallback cost
    prior_fwd = jax.jit(lambda mu, lv, eps: ops.cutlayer(
        mu, lv, eps, link_bits=bits, rate_estimator="sample",
        prior_mu=pmu, prior_logvar=plv))

    @jax.jit
    def prior_loss_grad(mu, lv, eps):
        def loss(mu, lv, pm, pv):
            u, r = ops.cutlayer(mu, lv, eps, link_bits=bits,
                                rate_estimator="sample", prior_mu=pm,
                                prior_logvar=pv)
            return (u * cu).sum() + (r * cr).sum()
        return jax.value_and_grad(loss, argnums=(0, 1, 2, 3))(mu, lv,
                                                              pmu, plv)

    def _interleave(fns, reps):
        """Median us per call, the contenders interleaved so cache pressure
        and scheduler noise hit them alike (sequential blocks flatter
        whichever runs with a warmer cache)."""
        for f in fns.values():
            jax.block_until_ready(f(mu, lv, eps))          # warmup/compile
        samples = {k: [] for k in fns}
        for _ in range(reps):
            for name, f in fns.items():
                t0 = time.perf_counter()
                out = f(mu, lv, eps)
                jax.block_until_ready(out)
                samples[name].append((time.perf_counter() - t0) * 1e6)
        return {k: statistics.median(v) for k, v in samples.items()}

    med = _interleave({"unfused_fwd": unfused, "fused_fwd": fused,
                       "unfused_grad": unfused_grad,
                       "fused_grad": fused_loss_grad}, reps)
    t_uf, t_ff = med["unfused_fwd"], med["fused_fwd"]
    t_ug, t_fg = med["unfused_grad"], med["fused_grad"]
    # prior-vs-standard-normal runs as STRICT two-function pairs (one pair
    # per metric): with more contenders in the loop the ~56MB grad working
    # sets thrash L3 against each other and the ratio swings +-40% run to
    # run; tight alternation keeps the cache state symmetric, and the
    # ratio (not the absolute time) is the acceptance metric here
    pmed_f = _interleave({"fused_fwd2": fused, "prior_fwd": prior_fwd},
                         reps)
    pmed_g = _interleave({"fused_grad2": fused_loss_grad,
                          "prior_grad": prior_loss_grad}, reps)
    t_pf, t_pg = pmed_f["prior_fwd"], pmed_g["prior_grad"]
    t_ff2, t_fg2 = pmed_f["fused_fwd2"], pmed_g["fused_grad2"]

    # the unfused value_and_grad cannot be outer-jitted without fusing the
    # 3 passes back together, so its timings include per-call Python
    # trace/dispatch overhead.  Measure that overhead at a compute-free
    # shape (same graph, 8 rows) and report overhead-adjusted speedups so
    # the fusion win is not overstated.
    cu8, cr8 = cu[:8], cr[:8]

    def unfused_loss_tiny(mu, lv, eps):
        u, r = unfused(mu, lv, eps)
        return (u * cu8).sum() + (r * cr8).sum()

    tiny_grad = jax.value_and_grad(unfused_loss_tiny, argnums=(0, 1))
    tiny = [x[:8] for x in (mu, lv, eps)]
    dispatch_us = _time(tiny_grad, *tiny, reps=reps)
    t_ug_adj = max(t_ug - dispatch_us, 1e-3)

    # the training hot path runs forward + backward every step
    step_speedup = (t_uf + t_ug_adj) / (t_ff + t_fg)

    bytes_fwd = 3 * T * d_b * 4
    csv = [
        ("cutlayer_unfused_fwd", t_uf, f"{bytes_fwd/t_uf/1e3:.1f}GB/s"),
        ("cutlayer_fused_fwd", t_ff,
         f"{bytes_fwd/t_ff/1e3:.1f}GB/s speedup={t_uf/t_ff:.2f}x"),
        ("cutlayer_unfused_grad", t_ug,
         f"incl_dispatch_overhead={dispatch_us:.0f}us"),
        ("cutlayer_fused_grad", t_fg, f"speedup={t_ug_adj/t_fg:.2f}x"),
        ("cutlayer_train_step", t_ff + t_fg,
         f"speedup_vs_unfused={step_speedup:.2f}x"),
        ("cutlayer_prior_fwd", t_pf,
         f"vs_std_normal={t_pf/t_ff2:.2f}x"),
        ("cutlayer_prior_grad", t_pg,
         f"vs_std_normal={t_pg/t_fg2:.2f}x"),
        ("cutlayer_prior_train_step", t_pf + t_pg,
         f"vs_std_normal={(t_pf+t_pg)/(t_ff2+t_fg2):.2f}x"),
    ]
    record = {
        "bench": "cutlayer",
        "shape": {"T": T, "d_bottleneck": d_b, "link_bits": bits},
        "reps": reps,
        "backend": jax.default_backend(),
        "impl": ops.resolve_backend("auto"),
        "us_median": {
            "unfused_fwd": round(t_uf, 2), "fused_fwd": round(t_ff, 2),
            "unfused_grad": round(t_ug, 2), "fused_grad": round(t_fg, 2),
            "prior_fwd": round(t_pf, 2), "prior_grad": round(t_pg, 2),
            # per-call Python trace/dispatch cost of the un-jittable
            # unfused value_and_grad, measured at a compute-free shape;
            # already subtracted from the adjusted speedups below
            "unfused_grad_dispatch_overhead": round(dispatch_us, 2),
        },
        "speedup": {"fwd": round(t_uf / t_ff, 3),
                    "grad": round(t_ug_adj / t_fg, 3),
                    "train_step": round(step_speedup, 3)},
        # learned-prior fused path relative to the standard-normal fused
        # path, same pairwise interleave (acceptance: <= ~1.2x — no
        # unfused fallback)
        "prior_overhead": {
            "fwd": round(t_pf / t_ff2, 3),
            "grad": round(t_pg / t_fg2, 3),
            "train_step": round((t_pf + t_pg) / (t_ff2 + t_fg2), 3)},
    }
    return csv, record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_cutlayer.json",
                    help="machine-readable cut-layer results ('' disables)")
    ap.add_argument("--T", type=int, default=8192)
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--reps", type=int, default=DEFAULT_REPS)
    ap.add_argument("--skip-generic", action="store_true",
                    help="only run the cut-layer benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + 2 reps: the CI bench-smoke step "
                         "(keeps this script importable/runnable between "
                         "nightly perf runs; numbers are meaningless)")
    args = ap.parse_args()
    if args.smoke:
        args.T, args.d, args.reps = 128, 32, 2
        args.skip_generic = True

    print("name,us_per_call,derived")
    if not args.skip_generic:
        for name, us, derived in rows():
            print(f"{name},{us:.1f},{derived}")
    csv, record = bench_cutlayer(args.T, args.d, args.bits, args.reps)
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
