# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV
# sections (see each module for details):
#   table1    bandwidth_table    paper Table I closed-form vs published, plus
#                                per-round bits of every registered scheme
#   fig5/7    accuracy_curves    accuracy-vs-epoch / accuracy-vs-bandwidth for
#                                every scheme in the unified registry
#   kernels   kernel_bench       hot-spot micro-benchmarks
#   wire      wire_bench         packed wire format: bytes-on-wire per round
#                                (asserted == closed forms) + packed-vs-dense
#                                round throughput + bf16 policy leg
#   topology  topology_bench     star vs chain vs tree: per-edge bytes
#                                (asserted == closed forms) + round
#                                wall-clock per topology
#   links     links_bench        unreliable links: accuracy-vs-erasure per
#                                scheme (asserted: INL's partial fusion
#                                beats the single-uplink schemes at 0.3)
#                                + delivered-vs-offered training bandwidth
#   serve     serve_bench        serving plane: p50/p99 latency + goodput
#                                vs Poisson offered load per topology/wire
#                                (asserted: continuous batching >= 2x the
#                                serial baseline, one compile per bucket)
#   throughput throughput_bench  end-to-end runner throughput: per-round
#                                dispatch vs whole-epoch scan+prefetch vs
#                                shard_map (forced 2-device subprocess)
#   chaos     chaos_bench        deterministic fault tolerance: serving
#                                goodput under churn, breaker vs none,
#                                node-kill degradation per scheme, and
#                                bit-identical crash-resume
#   cluster   cluster_bench      multi-process worker plane: 3-process ==
#                                in-process parity, SIGKILL+restart resume
#                                identity, serving goodput recovery, and
#                                adaptive vs fixed fault policies
#   frontier  frontier_bench     auto-placement search: accuracy-per-Gbit
#                                Pareto frontier over (scheme, cut depth,
#                                topology, width, wire) with exhaustively
#                                verified ledger pruning (asserted: the
#                                frontier beats the pure baselines at >= 1
#                                bandwidth budget, closed == measured bits
#                                on every trained point)
#   roofline  roofline_report    dry-run three-term roofline rows
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: table1,curves,kernels,wire,topology,"
                         "links,serve,throughput,chaos,cluster,frontier,"
                         "roofline")
    ap.add_argument("--epochs", type=int, default=3,
                    help="epochs for the accuracy curves (CPU-sized)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    t0 = time.time()
    if want("table1"):
        from benchmarks import bandwidth_table
        bandwidth_table.main()
        sys.stdout.flush()
    if want("kernels"):
        from benchmarks import kernel_bench
        kernel_bench.main()
        sys.stdout.flush()
    if want("wire"):
        from benchmarks import wire_bench
        wire_bench.main([])
        sys.stdout.flush()
    if want("topology"):
        from benchmarks import topology_bench
        topology_bench.main([])
        sys.stdout.flush()
    if want("links"):
        from benchmarks import links_bench
        links_bench.main([])
        sys.stdout.flush()
    if want("serve"):
        from benchmarks import serve_bench
        serve_bench.main([])
        sys.stdout.flush()
    if want("curves"):
        from benchmarks import accuracy_curves
        accuracy_curves.main(experiment=2, epochs=args.epochs)
        sys.stdout.flush()
    if want("throughput"):
        # runs in its own subprocess: the forced multi-device XLA flag must
        # be set before jax initialises, which has already happened here
        from benchmarks import throughput_bench
        throughput_bench.main([])
        sys.stdout.flush()
    if want("chaos"):
        from benchmarks import chaos_bench
        chaos_bench.main(["--smoke", "--json", ""])
        sys.stdout.flush()
    if want("cluster"):
        from benchmarks import cluster_bench
        cluster_bench.main(["--smoke", "--json", ""])
        sys.stdout.flush()
    if want("frontier"):
        # keeps its JSON: CI's BENCH_*.json artifact step uploads it
        from benchmarks import frontier_bench
        frontier_bench.main(["--smoke", "--json", "BENCH_frontier.json"])
        sys.stdout.flush()
    if want("roofline"):
        from benchmarks import roofline_report
        roofline_report.main()
    print(f"# benchmarks done in {time.time()-t0:.1f}s")


if __name__ == '__main__':
    main()
