"""Benchmark — deterministic chaos: fault tolerance as numbers per scheme.

Every fault here is SCRIPTED (repro/chaos.ChaosSchedule) and every
transport draw is counter-seeded (repro/transport/), so the whole bench
replays bit-identically — the asserts below are stable CI contracts, not
flaky statistics.

Sections, written to BENCH_chaos.json (--json):

  serving_goodput   requests served through the continuous-batching engine
                    over a transport whose edges take turns going down
                    (staggered flap: J-1 of J uplinks dark at any tick).
                    INL partial-fuses whatever arrived — a request keeps a
                    real answer as long as ONE view lands.  The FL/SL
                    serving reading (links_bench: the single client<->server
                    uplink answers or the request degrades to uniform) rides
                    the SAME chaos schedule.  ASSERTS INL goodput (correct
                    answers / offered requests) >= 2x FL and SL.

  breaker           a 40-round edge outage under retrying transport, with
                    and without circuit breakers.  Without, every round
                    re-offers max_attempts full charges into a dead link;
                    with, the breaker opens after 3 consecutive failures
                    and short-circuits the window (probes only).  ASSERTS
                    the breaker's delivered/offered ratio is STRICTLY above
                    the no-breaker baseline, that it actually opened and
                    short-circuited, and that it recloses within
                    2*cooldown+2 ticks of the outage ending (recovery
                    time).

  training_churn    a client node SIGKILLed mid-training (kill window in
                    round ticks) under transport execution.  ASSERTS the
                    degradation semantics behaviourally: across a round
                    with the node dead, SL's state is UNCHANGED (whole
                    round lost) while INL's state moved (one vote lost,
                    survivors renormalised); per partial round INL loses
                    exactly one vote.  Records accuracy of the churned INL
                    run vs its clean twin and asserts the churned run still
                    recovers (final accuracy within 0.2 of clean).

  crash_resume      elastic recovery at the runner level: a transport-mode
                    run checkpointed every epoch, restarted from the
                    midpoint, asserted BIT-IDENTICAL to the uninterrupted
                    run (curve, meter ledgers, breaker trajectory).  The
                    subprocess SIGKILL variant (torn-file crash atomicity
                    included) is `python -m repro.chaos` — the CI
                    crash-resume leg.

--smoke shrinks shapes/epochs for the CI bench-smoke step so the asserts
cannot bit-rot between nightly runs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.chaos import ChaosSchedule
from repro.configs.paper_inl import PaperExperimentConfig
from repro.core import schemes
from repro.core import topology as topology_lib
from repro.core.schemes import base as schemes_base
from repro.core.schemes import runner
from repro.data import multiview
from repro.serving import ServingEngine
from repro.transport import (DEFAULT_RETRY, NO_RETRY, CircuitBreaker,
                             NetworkTransport)


def _cfg(*, smoke: bool):
    if smoke:
        return PaperExperimentConfig(
            conv_channels=(4,), d_bottleneck=8, dense_units=(32,),
            image_shape=(16, 16, 3), dataset_size=640)
    return PaperExperimentConfig(
        conv_channels=(8, 16), d_bottleneck=16, dense_units=(64,),
        image_shape=(32, 32, 3), dataset_size=2048)


def _data(cfg, seed):
    imgs, labels = multiview.make_base_dataset(
        cfg.dataset_size, image_shape=cfg.image_shape, seed=seed)
    views = multiview.make_views(imgs, cfg.noise_stds)
    return jnp.asarray(views), jnp.asarray(labels)


def _edge_keys(cfg):
    topo = topology_lib.resolve(None, cfg)
    return [e.key for e in topo.edges], topo


# ---------------------------------------------------------------------------
# serving goodput under churn
# ---------------------------------------------------------------------------

def serving_goodput_section(*, smoke: bool, epochs: int, seed: int):
    cfg = _cfg(smoke=smoke)
    views, labels = _data(cfg, seed)
    keys, topo = _edge_keys(cfg)
    J = len(keys)
    n = min(64, labels.shape[0])

    # the churn script: staggered flaps — at (almost) every tick exactly
    # ONE of the J uplinks is up, the other J-1 dark
    chaos = ChaosSchedule()
    for i, key in enumerate(keys):
        chaos = chaos.flap_edge(key, start=i, stop=10_000, period=J,
                                duty=J - 1)

    # train each scheme CLEAN (INL with the edge-dropout curriculum so the
    # fusion center has learned to renormalise over survivors).  One-view
    # robustness needs the curriculum to have converged — 2 smoke epochs
    # leave the noisier views near chance, 4 put their single-vote
    # accuracy at ~0.57 — so the section floors the training at 4 epochs
    # (seconds at these shapes).
    epochs = max(epochs, 4)
    preds, states = {}, {}
    for name in ("inl", "fl", "sl"):
        # a HARD dropout curriculum: under the staggered flap most fusions
        # see a single surviving view, so the fusion center must have
        # trained to answer from any one vote alone
        tcfg = dataclasses.replace(cfg, edge_dropout=0.5) \
            if name == "inl" else cfg
        scheme = schemes.get(name)
        # train via the round path directly (run_scheme returns the curve,
        # not the state, and these shapes retrain in seconds)
        state = scheme.init(tcfg, jax.random.PRNGKey(seed))
        round_fn = scheme.make_round(tcfg)
        bpr = scheme.batches_per_round(tcfg)
        rng = jax.random.PRNGKey(seed + 1)
        for ep in range(epochs):
            group_v, group_l = [], []
            for v, l in multiview.multiview_batches(views, labels, 32,
                                                    seed=ep):
                group_v.append(v)
                group_l.append(l)
                if len(group_v) < bpr:
                    continue
                rng, sub = jax.random.split(rng)
                state, _ = round_fn(state, jnp.asarray(np.stack(group_v)),
                                    jnp.asarray(np.stack(group_l)), sub)
                group_v, group_l = [], []
        states[name] = state
        preds[name] = np.argmax(np.asarray(
            scheme.predict(state, views[:, :n], cfg=tcfg)), -1)
    el = np.asarray(labels[:n])

    # INL: the real engine over the chaotic transport, one request per tick
    tr = NetworkTransport(topo, cfg, seed=seed + 7, policy=NO_RETRY,
                          breaker=None, chaos=chaos)
    engine = ServingEngine(schemes.get("inl"), states["inl"], cfg,
                           seed=seed + 2, transport=tr)
    engine.warmup()
    with engine:
        probs, results = engine.serve(np.asarray(views[:, :n]))
    fused = np.asarray([r.views_fused for r in results])
    inl_correct = (np.argmax(probs, -1) == el) & (fused > 0)
    goodput = {"inl": float(inl_correct.mean())}
    tr.close()

    # FL/SL: same chaos, single-uplink reading — request rid rides its
    # owner client's edge (owner strided so it is NOT phase-locked to the
    # flap script: with period J and one edge up per tick, a 2-stride owner
    # sees its uplink up for exactly 1/J of requests — the fair baseline,
    # not an accidental 0); a dark uplink degrades the answer to uniform
    for name in ("fl", "sl"):
        t2 = NetworkTransport(topo, cfg, seed=seed + 7, policy=NO_RETRY,
                              breaker=None, chaos=chaos)
        ok = np.zeros(n, bool)
        for rid in range(n):
            rep = t2.send_request(rid)
            up = bool(rep.eventual[(2 * rid + 1) % J])
            ok[rid] = up and preds[name][rid] == el[rid]
        goodput[name] = float(ok.mean())
        t2.close()

    print("serving goodput under churn (correct answers / requests): "
          + " ".join(f"{k}={v:.3f}" for k, v in goodput.items()))
    for rival in ("fl", "sl"):
        assert goodput["inl"] >= 2.0 * goodput[rival], (
            f"INL goodput {goodput['inl']:.3f} must be >= 2x {rival} "
            f"{goodput[rival]:.3f} under churn: partial fusion keeps a "
            "vote per surviving uplink, the single-uplink schemes lose "
            "the whole request")
    return {"goodput": goodput, "requests": int(n),
            "mean_views_fused": float(fused.mean()),
            "uplinks_up_per_tick": 1}


# ---------------------------------------------------------------------------
# circuit breaker vs none over a dead window
# ---------------------------------------------------------------------------

def breaker_section(*, smoke: bool, seed: int):
    cfg = _cfg(smoke=smoke)
    keys, topo = _edge_keys(cfg)
    outage_start, outage_len, ticks = 4, 40, 64
    chaos = ChaosSchedule().down_edge(keys[0], outage_start, outage_len)
    cooldown = 4

    record = {}
    recovery_tick = None
    for label, breaker in (("no_breaker", None),
                           ("breaker",
                            lambda: CircuitBreaker(cooldown=cooldown))):
        tr = NetworkTransport(topo, cfg, seed=seed + 11, policy=DEFAULT_RETRY,
                              breaker=breaker, chaos=chaos)
        for t in range(ticks):
            tr.round_outcome(t, 32)
            if label == "breaker" and recovery_tick is None \
                    and t >= outage_start + outage_len \
                    and tr.breaker_states()[keys[0]] == "closed":
                recovery_tick = t
        snap = tr.snapshot()
        record[label] = {"offered_bits": snap["offered_bits"],
                         "delivered_bits": snap["delivered_bits"],
                         "delivery_ratio": snap["delivery_ratio"],
                         "breaker": snap["breaker"][keys[0]]}
        tr.close()

    nb, wb = record["no_breaker"], record["breaker"]
    print(f"breaker: delivered/offered {wb['delivery_ratio']:.3f} with vs "
          f"{nb['delivery_ratio']:.3f} without "
          f"(opens={wb['breaker']['opens']}, "
          f"short_circuits={wb['breaker']['short_circuits']}, "
          f"reclosed_at_tick={recovery_tick})")
    assert wb["delivery_ratio"] > nb["delivery_ratio"], (
        "the breaker must deliver a STRICTLY higher fraction of what it "
        "offers: short-circuited attempts stop re-offering full charges "
        "into a dead link")
    assert wb["breaker"]["opens"] >= 1 and \
        wb["breaker"]["short_circuits"] > 0, wb["breaker"]
    assert recovery_tick is not None and \
        recovery_tick - (outage_start + outage_len) <= 2 * cooldown + 2, (
        f"breaker must reclose within 2*cooldown+2 ticks of the outage "
        f"ending; reclosed at {recovery_tick}")
    record["recovery_ticks"] = recovery_tick - (outage_start + outage_len)
    return record


# ---------------------------------------------------------------------------
# training under a node kill: one vote vs whole round
# ---------------------------------------------------------------------------

def training_churn_section(*, smoke: bool, epochs: int, seed: int):
    cfg = _cfg(smoke=smoke)
    views, labels = _data(cfg, seed)
    keys, topo = _edge_keys(cfg)
    J = len(keys)
    dead = topo.view_nodes()[1]
    kill_at, kill_len = 2, 4
    chaos = ChaosSchedule().kill_node(dead, at=kill_at, duration=kill_len)

    def make_tr(with_chaos):
        return NetworkTransport(topo, cfg, seed=seed + 5,
                                policy=DEFAULT_RETRY,
                                chaos=chaos if with_chaos else None)

    # the behavioural semantics, one round each (deterministic): the same
    # partial delivery moves INL's state but leaves SL's untouched
    delivery = jnp.asarray(np.arange(J) != 1)          # the dead node's vote
    v1 = views[:, :32][None]
    l1 = labels[:32][None]
    rng1 = jax.random.PRNGKey(seed + 9)
    inl_scheme, sl_scheme = schemes.get("inl"), schemes.get("sl")
    st_inl = inl_scheme.init(cfg, jax.random.PRNGKey(seed))
    new_inl, _ = inl_scheme.make_transport_round(cfg)(
        st_inl, v1, l1, rng1, delivery)
    inl_moved = any(not np.array_equal(a, b) for a, b in
                    zip(jax.tree.leaves(jax.device_get(new_inl)),
                        jax.tree.leaves(jax.device_get(st_inl))))
    st_sl = sl_scheme.init(cfg, jax.random.PRNGKey(seed))
    new_sl, _ = sl_scheme.make_transport_round(cfg)(
        st_sl, v1, l1, rng1, delivery)
    sl_held = all(np.array_equal(a, b) for a, b in
                  zip(jax.tree.leaves(jax.device_get(new_sl)),
                      jax.tree.leaves(jax.device_get(st_sl))))
    assert inl_moved, "INL must partial-fuse the surviving J-1 votes"
    assert sl_held, ("SL must carry its state UNCHANGED through a round "
                     "with a failed link — the whole round is lost")

    # vote accounting over the kill window, straight off the round reports
    replay = make_tr(True)
    masks = [replay.round_outcome(t, 32, charge=False).mask
             for t in range(kill_at + kill_len + 2)]
    replay.close()
    partial = [m for m in masks if not m.all()]
    votes_lost_inl = int(sum(J - m.sum() for m in partial))
    rounds_lost_sl = len(partial)
    assert votes_lost_inl == rounds_lost_sl == kill_len, (
        "one dead node for k rounds must cost INL exactly k votes and SL "
        f"exactly k whole rounds; got votes={votes_lost_inl} "
        f"rounds={rounds_lost_sl} k={kill_len}")
    assert all(m.all() for m in masks[kill_at + kill_len:]), \
        "the node must rejoin the fusion the tick its kill window closes"

    # the churned training run still converges (elastic leave/rejoin)
    tr = make_tr(True)
    churn = runner.run_scheme("inl", views, labels, cfg, epochs=epochs,
                              batch_size=32, seed=seed, transport=tr)
    tr.close()
    clean = runner.run_scheme("inl", views, labels, cfg, epochs=epochs,
                              batch_size=32, seed=seed,
                              dispatch="per_round")
    print(f"training churn: kill {dead} for {kill_len} rounds -> "
          f"acc {churn[-1].accuracy:.3f} vs clean {clean[-1].accuracy:.3f} "
          f"(votes lost: inl={votes_lost_inl}, "
          f"whole rounds lost: sl={rounds_lost_sl})")
    assert churn[-1].accuracy >= clean[-1].accuracy - 0.2, (
        f"a {kill_len}-round client leave must not sink the run: "
        f"{churn[-1].accuracy:.3f} vs clean {clean[-1].accuracy:.3f}")
    return {"dead_node": dead, "kill_rounds": kill_len,
            "votes_lost_inl": votes_lost_inl,
            "whole_rounds_lost_sl": rounds_lost_sl,
            "accuracy_churn": churn[-1].accuracy,
            "accuracy_clean": clean[-1].accuracy}


# ---------------------------------------------------------------------------
# elastic crash-resume identity (runner level)
# ---------------------------------------------------------------------------

def crash_resume_section(*, smoke: bool, epochs: int, seed: int):
    cfg = _cfg(smoke=smoke)
    views, labels = _data(cfg, seed)
    keys, topo = _edge_keys(cfg)
    chaos = ChaosSchedule().down_edge(keys[0], 3, 2)

    def make_tr():
        return NetworkTransport(topo, cfg, seed=seed + 13,
                                policy=DEFAULT_RETRY, chaos=chaos)

    epochs = max(epochs, 2)
    half = epochs // 2
    tg = make_tr()
    golden = runner.run_scheme("inl", views, labels, cfg, epochs=epochs,
                               batch_size=32, seed=seed, transport=tg)
    gsnap = tg.snapshot()
    tg.close()

    workdir = tempfile.mkdtemp(prefix="chaos_bench_ckpt_")
    try:
        t1 = make_tr()
        runner.run_scheme("inl", views, labels, cfg, epochs=half,
                          batch_size=32, seed=seed, transport=t1,
                          ckpt_dir=workdir)
        t1.close()
        t2 = make_tr()
        resumed = runner.run_scheme("inl", views, labels, cfg, epochs=epochs,
                                    batch_size=32, seed=seed, transport=t2,
                                    ckpt_dir=workdir, resume=True)
        rsnap = t2.snapshot()
        t2.close()
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    assert golden == resumed, (
        "the resumed curve must equal the uninterrupted run's exactly "
        "(state, rng fast-forward, AND meter ledgers)")
    assert gsnap == rsnap, (
        "the resumed transport snapshot (ledgers + breaker trajectories) "
        "must equal the uninterrupted run's")
    print(f"crash-resume: {half}+{epochs - half} epochs == {epochs} epochs "
          f"bit for bit (final acc {golden[-1].accuracy:.3f})")
    return {"epochs": epochs, "resume_from_epoch": half,
            "bitwise_identical": True,
            "final_accuracy": golden[-1].accuracy}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes/epochs (CI bench-smoke step)")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_chaos.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    epochs = 2 if args.smoke else args.epochs

    record = {"smoke": args.smoke,
              "serving_goodput": serving_goodput_section(
                  smoke=args.smoke, epochs=epochs, seed=args.seed),
              "breaker": breaker_section(smoke=args.smoke, seed=args.seed),
              "training_churn": training_churn_section(
                  smoke=args.smoke, epochs=epochs, seed=args.seed),
              "crash_resume": crash_resume_section(
                  smoke=args.smoke, epochs=epochs, seed=args.seed)}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")
    return record


if __name__ == "__main__":
    main()
