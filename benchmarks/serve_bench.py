"""Benchmark — the serving plane: latency/goodput vs offered load.

The training-side benches answer "what does a ROUND cost"; this one answers
the inference-side question the serving plane (repro/serving/) exists for:
what latency does a REQUEST see, and what goodput does the fusion center
sustain, as Poisson offered load sweeps past serial capacity — per
topology (star(J), tree(2, 2)), per wire format (dense, packed hops), and
per link state (clean, erasure 0.3 with fuse-what-arrived masking).

Per leg, written to BENCH_serve.json (--json):

  serial_capacity_rps   strictly-serial service rate (buckets=(1,)): the
                        per-request baseline the batching claim is tested
                        against.
  points                >= 3 Poisson load points at 0.5x / 2x / 8x the
                        serial capacity, each with p50/p99 latency,
                        goodput, mean views fused, and the per-request
                        delivered-bits ledger off the engine's
                        BandwidthMeter (offered vs delivered Gbits).
  accuracy              served accuracy of the eval block through the
                        engine at this leg's erasure.

In-bench asserts (every run, smoke included):

  * CONTINUOUS BATCHING WINS: at the highest load point the batched
    engine's goodput is >= 2x the serial baseline's goodput at that same
    offered load (clean dense legs — the apples-to-apples claim).
  * ONE COMPILE PER BUCKET: after a full sweep, every bucket's trace count
    is <= 1 (no retracing under churn).
  * CLEAN SERVING IS predict: the erasure-0 served probabilities match the
    jitted `scheme.predict` reference (float-tolerance — different-shape
    XLA executables round the last ulp differently) with IDENTICAL argmax
    decisions, and served accuracy equals `evaluate_accuracy` exactly.
  * faulty legs deliver strictly less than they offer
    (delivery_ratio < 1), clean legs exactly what they offer (== 1).

The bench config trains at link_bits=8 so the SAME trained model serves
the dense and the packed-wire legs (packed requires link_bits <= 16).

--smoke shrinks the request counts for the CI bench-smoke step.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from benchmarks.links_bench import _cfg, _train
from repro.core import bandwidth, linkfault, schemes
from repro.core import topology as topology_lib
from repro.core.schemes import base as schemes_base
from repro.data import multiview
from repro.serving import (ServingEngine, measure_serial_capacity,
                           request_bits, run_poisson)

LOAD_MULTS = (0.5, 2.0, 8.0)
ERASURES = (0.0, 0.3)


def _legs(cfg):
    """(name, topology, cfg, wire, erasure) per sweep leg."""
    J = cfg.num_clients
    cfg6 = dataclasses.replace(
        cfg, num_clients=6, noise_stds=cfg.noise_stds + (1.5,))
    star, tr = topology_lib.star(J), topology_lib.tree(2, 2)
    legs = []
    for tname, topo, tcfg in (("star", star, cfg), ("tree(2,2)", tr, cfg6)):
        for erasure in ERASURES:
            legs.append((f"{tname}/dense/e{erasure:g}", topo, tcfg,
                         "dense", erasure))
        legs.append((f"{tname}/packed/e0", topo, tcfg, "packed", 0.0))
    return legs


def serve_section(*, smoke: bool, epochs: int, batch: int, seed: int):
    # link_bits=8 keeps the packed wire legal AND lets one trained model
    # per topology serve every wire leg
    base_cfg = dataclasses.replace(_cfg(smoke=smoke), link_bits=8)
    imgs, labels = multiview.make_base_dataset(
        base_cfg.dataset_size, image_shape=base_cfg.image_shape, seed=seed)
    # enough requests that the highest-load point reaches steady full-bucket
    # launches (a short burst measures mostly ramp-up and undersells the
    # batching win)
    n_req = 192 if smoke else 512
    n_eval = min(128, labels.shape[0])

    trained = {}   # num_clients -> (state, views)
    record = {}
    scheme = schemes.get("inl")
    print("leg,serial_rps,offered_rps,goodput_rps,p50_ms,p99_ms,"
          "delivery_ratio")
    for lname, topo, cfg, wire, erasure in _legs(base_cfg):
        J = cfg.num_clients
        if J not in trained:
            views = multiview.make_views(imgs, cfg.noise_stds)
            state = _train("inl", topo, cfg, views, labels, epochs=epochs,
                           batch=batch, seed=seed,
                           meter=bandwidth.BandwidthMeter())
            trained[J] = (state, views)
        state, views = trained[J]
        pool = np.asarray(views[:, :n_eval])
        el = np.asarray(labels[:n_eval])
        lossy = topo if erasure == 0.0 else linkfault.with_links(
            topo, linkfault.LinkModel(erasure=erasure))

        def make(buckets=None):
            return ServingEngine(scheme, state, cfg, topology=lossy,
                                 wire=wire, buckets=buckets, seed=seed + 7)

        serial = make(buckets=(1,))
        serial.warmup()
        with serial:
            cap = measure_serial_capacity(serial, pool,
                                          num_requests=min(32, n_req))
            serial_high = run_poisson(serial, pool,
                                      rate_rps=cap * LOAD_MULTS[-1],
                                      num_requests=n_req, seed=seed + 1)

        engine = make()
        engine.warmup()
        with engine:
            # the served-accuracy / bit-exactness block first
            probs, _ = engine.serve(pool)
            acc = float(np.mean(np.argmax(probs, -1) == el))
            points = [run_poisson(engine, pool, rate_rps=cap * m,
                                  num_requests=n_req,
                                  seed=seed + 10 + int(m * 10))
                      for m in LOAD_MULTS]

        if erasure == 0.0:
            import jax.numpy as jnp
            # the jitted reference carries the same compiled-prediction
            # semantics as the engine's bucketed launches; XLA executables
            # compiled at different batch shapes can differ in the last
            # ulp, so the parity bar is tight-allclose + identical argmax
            # (bit-exactness holds WITHIN a bucket executable —
            # tests/test_serving.py pins the full story)
            ref_topo = topology_lib.nontrivial(topo, cfg)
            clean = np.asarray(jax.jit(
                lambda st, vv, _s=scheme, _c=cfg, _t=ref_topo, _w=wire:
                _s.predict_batched(st, vv, topology=_t, cfg=_c, wire=_w)
            )(state, jnp.asarray(pool)))
            assert np.allclose(probs, clean, atol=2e-6, rtol=0), (
                f"{lname}: clean served probabilities drifted from the "
                "jitted predict reference")
            assert np.array_equal(np.argmax(probs, -1),
                                  np.argmax(clean, -1)), (
                f"{lname}: clean serving changed a decision vs predict")
            ref_acc = schemes_base.evaluate_accuracy(
                scheme, state, jnp.asarray(pool), jnp.asarray(el),
                topology=topo, cfg=cfg)
            assert acc == ref_acc, (lname, acc, ref_acc)
            assert abs(engine.meter.delivery_ratio - 1.0) < 1e-12, lname
        else:
            assert engine.meter.delivery_ratio < 1.0, (
                f"{lname}: erasure {erasure} never dropped anything")
        assert all(c <= 1 for c in engine.trace_counts.values()), (
            f"{lname}: bucket predict retraced: {engine.trace_counts}")

        high = points[-1]
        if erasure == 0.0 and wire == "dense":
            # the headline claim on the paper's canonical star: batching
            # >= 2x serial at saturation.  Graph topologies spend a larger
            # fraction of each launch in per-hop re-encode compute (less
            # Python/dispatch overhead to amortise), so they carry a
            # saner-but-real floor instead of the 2x bar.
            floor = 2.0 if topo.is_default_star() else 1.3
            assert high["goodput_rps"] >= floor * serial_high["goodput_rps"], (
                f"{lname}: continuous batching goodput "
                f"{high['goodput_rps']:.0f} rps < {floor}x serial baseline "
                f"{serial_high['goodput_rps']:.0f} rps at "
                f"{high['offered_rps']:.0f} rps offered")
        record[lname] = {
            "serial_capacity_rps": cap,
            "serial_goodput_at_high_load_rps": serial_high["goodput_rps"],
            "request_bits": request_bits(engine.topo, cfg),
            "accuracy": acc,
            "points": points,
            "trace_counts": {str(k): v
                             for k, v in engine.trace_counts.items()},
            "speedup_vs_serial": high["goodput_rps"]
            / serial_high["goodput_rps"],
            "pad_fraction": engine.stats.pad_fraction,
        }
        for p in points:
            print(f"{lname},{cap:.0f},{p['offered_rps']:.0f},"
                  f"{p['goodput_rps']:.0f},{p['p50_ms']:.2f},"
                  f"{p['p99_ms']:.2f},{p['delivery_ratio']:.3f}")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small request counts (CI bench-smoke step)")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    epochs = 2 if args.smoke else args.epochs

    legs = serve_section(smoke=args.smoke, epochs=epochs, batch=args.batch,
                         seed=args.seed)
    record = {"smoke": args.smoke, "load_mults": list(LOAD_MULTS),
              "erasures": list(ERASURES), "legs": legs}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
