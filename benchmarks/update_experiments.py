"""Regenerate the §Roofline table inside EXPERIMENTS.md from the dry-run
artifacts.  Idempotent: replaces the block between the ROOFLINE markers."""
from __future__ import annotations

import re
import sys

from benchmarks import roofline_report

BEGIN = "<!-- ROOFLINE-TABLE-BEGIN -->"
END = "<!-- ROOFLINE-TABLE-END -->"


def main(path: str = "EXPERIMENTS.md"):
    table = roofline_report.markdown()
    with open(path) as f:
        text = f.read()
    block = f"{BEGIN}\n{table}\n{END}"
    if BEGIN in text:
        text = re.sub(re.escape(BEGIN) + r".*?" + re.escape(END), block,
                      text, flags=re.S)
    else:
        text = text.replace(
            "## §Roofline\n",
            "## §Roofline\n\n" + block + "\n", 1)
    with open(path, "w") as f:
        f.write(text)
    print(f"updated {path} with {len(table.splitlines()) - 2} rows")


if __name__ == "__main__":
    main(*sys.argv[1:])
