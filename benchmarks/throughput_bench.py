"""Benchmark 5 — end-to-end scheme-runner throughput (ISSUE 3 tentpole).

Measures what the sharded/pipelined execution layer actually buys at the
system level, on the same registry runner every scheme uses:

    per_round     the seed-style loop: one host->device transfer + one
                  jitted dispatch per round (runner dispatch="per_round")
    scan          whole-epoch lax.scan + double-buffered device prefetcher:
                  ONE dispatch per epoch (dispatch="scan")
    scan_sharded  the scan pipeline with the shard_map round on the
                  (client, data) host mesh — J node branches in parallel

Timings are the MEDIAN of --reps runs of a --epochs training run (examples/s
and rounds/s computed from the epoch geometry), after one unmeasured warmup
run that absorbs compilation.  Run on a FORCED multi-device CPU host
(XLA_FLAGS=--xla_force_host_platform_device_count=2) so the shard_map path
executes real collectives: the speedup is measured, not asserted.  When the
current process was started without that flag the benchmark re-executes
itself in a subprocess with it set (device count is frozen at jax init).

Results: stdout CSV + BENCH_throughput.json (tracked across PRs, consumed
by the ROADMAP's measured-throughput entry).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import time

DEVICE_FLAG = "--xla_force_host_platform_device_count"
DEFAULT_DEVICES = 2


def _reexec_with_devices(argv, devices: int):
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = f"{flags} {DEVICE_FLAG}={devices}".strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [sys.executable, "-m", "benchmarks.throughput_bench"] + argv
    return subprocess.call(cmd, env=env, cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))


def _bench_config():
    """CPU-sized but dispatch-bound: a small Fig.-4 model over many rounds,
    so per-round orchestration overhead is the measurable quantity.  J=2
    divides the 2 forced devices -> a real client axis for scan_sharded."""
    from repro.configs.paper_inl import PaperExperimentConfig
    return PaperExperimentConfig(
        num_clients=2, noise_stds=(0.4, 2.0), conv_channels=(8,),
        d_bottleneck=8, dense_units=(32,), image_shape=(16, 16, 3),
        dataset_size=2048)


def run(reps: int = 5, epochs: int = 2, batch: int = 32,
        json_path: str = "BENCH_throughput.json", scheme: str = "inl"):
    import jax
    import numpy as np

    from repro.core import schemes
    from repro.core.schemes import runner
    from repro.data import multiview
    from repro.launch import mesh as mesh_lib

    cfg = _bench_config()
    n = cfg.dataset_size
    imgs, labels = multiview.make_base_dataset(
        n, image_shape=cfg.image_shape, seed=0)
    views = multiview.make_views(imgs, cfg.noise_stds)
    bpr = schemes.get(scheme).batches_per_round(cfg)
    rounds = (n // batch) // bpr              # what the runner executes
    examples = rounds * bpr * batch

    mesh = mesh_lib.make_inl_host_mesh(cfg.num_clients)
    variants = {
        "per_round": dict(dispatch="per_round"),
        "scan": dict(dispatch="scan"),
        "scan_sharded": dict(dispatch="scan", mesh=mesh),
    }

    results = {"meta": {
        "scheme": scheme, "devices": jax.device_count(),
        "mesh": dict(mesh.shape), "epochs": epochs, "batch": batch,
        "rounds_per_epoch": rounds, "examples_per_epoch": examples,
        "reps": reps, "backend": jax.default_backend(),
    }}
    print("variant,examples_per_sec,rounds_per_sec,sec_per_epoch,"
          "speedup_vs_per_round")
    base_eps = None
    for name, kw in variants.items():
        def go():
            return runner.run_scheme(scheme, views, labels, cfg,
                                     epochs=epochs, batch_size=batch,
                                     eval_n=batch, seed=0, **kw)
        go()                                   # warmup: compile + caches
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            go()
            ts.append(time.perf_counter() - t0)
        sec_per_epoch = statistics.median(ts) / epochs
        eps = examples / sec_per_epoch
        rps = rounds / sec_per_epoch
        base_eps = eps if name == "per_round" else base_eps
        speedup = eps / base_eps if base_eps else float("nan")
        results[name] = {
            "examples_per_sec": round(eps, 1),
            "rounds_per_sec": round(rps, 2),
            "sec_per_epoch": round(sec_per_epoch, 4),
            "speedup_vs_per_round": round(speedup, 3),
        }
        print(f"{name},{eps:.1f},{rps:.2f},{sec_per_epoch:.4f},"
              f"{speedup:.3f}")

    with open(json_path, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"# wrote {json_path}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--scheme", default="inl")
    ap.add_argument("--json", default="BENCH_throughput.json")
    ap.add_argument("--devices", type=int, default=DEFAULT_DEVICES,
                    help="forced host device count (re-exec if the current "
                         "process was started without the XLA flag)")
    args = ap.parse_args(argv)

    if DEVICE_FLAG not in os.environ.get("XLA_FLAGS", ""):
        argv = argv if argv is not None else sys.argv[1:]
        rc = _reexec_with_devices(list(argv), args.devices)
        if rc:
            raise SystemExit(rc)
        return None
    return run(reps=args.reps, epochs=args.epochs, batch=args.batch,
               json_path=args.json, scheme=args.scheme)


if __name__ == "__main__":
    main()
