"""Benchmark 4 — roofline table from the dry-run artifacts.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and emits
the per-(arch x shape x mesh) three-term roofline rows; also usable as a
markdown generator for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load(dirname=None):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname or DRYRUN_DIR,
                                              "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def rows(dirname=None):
    out = []
    for r in load(dirname):
        if "roofline" not in r and r.get("status") != "fail":
            continue            # INL-mode records: reported in §Perf instead
        if r.get("status") != "ok":
            out.append({"arch": r.get("arch", "?"),
                        "shape": r.get("shape", "inl"),
                        "mesh": r.get("mesh", "inl"), "status": "FAIL",
                        "error": r.get("error", "")[:80]})
            continue
        rf = r["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"], "dominant": rf["dominant"],
            "model_flops": rf["model_flops"], "hlo_flops": rf["hlo_flops"],
            "useful_ratio": rf["useful_flop_ratio"],
            "mem_gb": r["memory"]["per_device_bytes"] / 1e9,
            "fits": r["memory"]["fits_hbm"],
            "compile_s": r.get("compile_s"),
        })
    return out


def markdown(dirname=None):
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | 6ND/HLO | mem GB/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows(dirname):
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"FAIL: {r['error']} ||||||||")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['mem_gb']:.2f} "
            f"| {'yes' if r['fits'] else 'NO'} |")
    return "\n".join(lines)


def main():
    print("name,arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,mem_gb_per_dev,fits")
    for r in rows():
        if r["status"] != "ok":
            print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},,,,FAIL,,,")
            continue
        print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['compute_s']:.4e},{r['memory_s']:.4e},"
              f"{r['collective_s']:.4e},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['mem_gb']:.2f},{r['fits']}")


if __name__ == "__main__":
    main()
