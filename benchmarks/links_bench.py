"""Benchmark — unreliable links: accuracy vs erasure rate per scheme.

The robustness story (core/linkfault.py) as one number per (scheme,
topology, erasure): every scheme trains ONCE, then its trained model is
evaluated under per-request link faults at each erasure rate in the grid —
INL on the star AND the chain (the fusion center masks the latent chunks
that failed and renormalises over the survivors, so a lost link costs one
vote), FL and SL on the star (their single client<->server uplink either
answers or the request degrades to the uniform distribution).

Sections, written to BENCH_links.json (--json):

  accuracy    accuracy-vs-erasure curves: erasure in {0, 0.1, 0.3, 0.5},
              averaged over --eval-reps independent network realisations.
              INL runs the star and tree(2, 2) — shallow multi-hop routes
              where no single edge carries every view.  (A chain is the
              degenerate opposite: its last hop bundles ALL views, so at
              equal per-edge erasure its accuracy ceiling sits BELOW the
              single-uplink schemes by construction — that compounding
              story lives in tests/test_linkfault.py, not in this
              comparison.)  The section ASSERTS the degradation contract
              on every run:

                * INL (star and tree) at erasure 0.3 is STRICTLY more
                  accurate than FL and SL at 0.3 — partial fusion beats
                  answer-or-nothing;
                * every scheme's erasure-0 accuracy equals its fault-free
                  evaluate_accuracy exactly (the erasure-0 column runs the
                  plain predict path — goldens untouched).

  training    per-scheme delivered-vs-offered bandwidth of the training
              run (BandwidthMeter's two ledgers; 1.0 when the training
              network was clean).

INL trains with the cfg.edge_dropout curriculum (views dropped per round
teach the fusion center to renormalise); FL/SL have no partial-fusion
reading to train, so they train clean.  REPRO_FORCE_ERASURE=<r> (the CI
forced-erasure leg) additionally attaches LinkModel(erasure=r) to every
TRAINING edge, pushing all three schemes through the fault-aware round
paths end-to-end.

--smoke runs tiny shapes/few epochs for the CI bench-smoke step, so the
degradation asserts cannot bit-rot between nightly runs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_inl import PaperExperimentConfig
from repro.core import bandwidth, linkfault, schemes
from repro.core import topology as topology_lib
from repro.core.schemes import base as schemes_base
from repro.core.schemes import runner
from repro.data import multiview

ERASURE_GRID = (0.0, 0.1, 0.3, 0.5)
HEADLINE_ERASURE = 0.3


def _cfg(*, smoke: bool):
    if smoke:
        return PaperExperimentConfig(
            conv_channels=(4,), d_bottleneck=8, dense_units=(32,),
            image_shape=(16, 16, 3), dataset_size=640)
    return PaperExperimentConfig(
        conv_channels=(8, 16), d_bottleneck=16, dense_units=(64,),
        image_shape=(32, 32, 3), dataset_size=2048)


def _specs(cfg, dropout: float):
    """(scheme, topology name, topology, cfg, edge_dropout) per curve.
    tree(2, 2) holds 6 views, so its rows run a 6-client config (one more
    noise level) on views rendered from the same base images."""
    J = cfg.num_clients
    cfg6 = dataclasses.replace(
        cfg, num_clients=6, noise_stds=cfg.noise_stds + (1.5,))
    return (
        ("inl", "star", topology_lib.star(J), cfg, dropout),
        ("inl", "tree(2,2)", topology_lib.tree(2, 2), cfg6, dropout),
        ("fl", "star", topology_lib.star(J), cfg, 0.0),
        ("sl", "star", topology_lib.star(J), cfg, 0.0),
    )


def _train(name, topo, cfg, views, labels, *, epochs: int, batch: int,
           seed: int, meter):
    """One training run through the registry round path (the same
    make_round products the golden trajectories pin), returning the final
    state; `meter` accrues the run's offered/delivered ledgers."""
    scheme = schemes.get(name)
    state = scheme.init(cfg, jax.random.PRNGKey(seed))
    round_fn = scheme.make_round(cfg, topology=topo)
    bpr = scheme.batches_per_round(cfg)
    topo_full = topology_lib.resolve(topo, cfg)
    faulty = linkfault.active(topo_full, cfg, train=True)
    charges = runner._round_charges(scheme, cfg, state, batch,
                                    wire="dense", topology=topo)
    rng = jax.random.PRNGKey(seed + 1)
    for ep in range(epochs):
        group_v, group_l = [], []
        for v, l in multiview.multiview_batches(views, labels, batch,
                                                seed=ep):
            group_v.append(v)
            group_l.append(l)
            if len(group_v) < bpr:
                continue
            rng, sub = jax.random.split(rng)
            state, _ = round_fn(state, jnp.asarray(np.stack(group_v)),
                                jnp.asarray(np.stack(group_l)), sub)
            if faulty:
                runner._meter_fault_rounds(meter, scheme, topo_full, cfg,
                                           batch, charges, [sub])
            else:
                runner._meter_rounds(meter, charges)
            group_v, group_l = [], []
    return state


def accuracy_section(*, smoke: bool, epochs: int, batch: int,
                     eval_reps: int, seed: int):
    base_cfg = _cfg(smoke=smoke)
    imgs, labels = multiview.make_base_dataset(
        base_cfg.dataset_size, image_shape=base_cfg.image_shape, seed=seed)
    n_eval = min(256, labels.shape[0])
    el = jnp.asarray(labels[:n_eval])

    train_erasure = linkfault.forced_erasure(0.0)
    dropout = 0.2
    print("scheme,topology," + ",".join(f"acc@{r}" for r in ERASURE_GRID)
          + ",delivery_ratio")
    record, training = {}, {}
    for name, tname, topo, cfg, edge_dropout in _specs(base_cfg, dropout):
        views = multiview.make_views(imgs, cfg.noise_stds)
        ev = jnp.asarray(views[:, :n_eval])
        traincfg = dataclasses.replace(cfg, edge_dropout=edge_dropout)
        train_topo = topo if train_erasure <= 0 else linkfault.with_links(
            topo, linkfault.LinkModel(erasure=train_erasure))
        meter = bandwidth.BandwidthMeter()
        scheme = schemes.get(name)
        state = _train(name, train_topo, traincfg, views, labels,
                       epochs=epochs, batch=batch, seed=seed, meter=meter)
        curve = {}
        for r in ERASURE_GRID:
            if r == 0.0:
                # the erasure-0 column IS the fault-free path (plain
                # predict) — by construction identical to the goldens'
                # evaluation convention
                curve[r] = schemes_base.evaluate_accuracy(
                    scheme, state, ev, el, topology=topo, cfg=cfg)
                continue
            lossy = linkfault.with_links(topo,
                                         linkfault.LinkModel(erasure=r))
            accs = [schemes_base.evaluate_accuracy_under_faults(
                        scheme, state, ev, el, jax.random.PRNGKey(1000 + k),
                        topology=lossy, cfg=cfg)
                    for k in range(eval_reps)]
            curve[r] = float(np.mean(accs))
        key = f"{name}/{tname}"
        record[key] = {str(r): curve[r] for r in ERASURE_GRID}
        training[key] = {"offered_gbits": meter.gbits,
                         "delivered_gbits": meter.delivered_gbits,
                         "delivery_ratio": meter.delivery_ratio}
        print(f"{name},{tname},"
              + ",".join(f"{curve[r]:.4f}" for r in ERASURE_GRID)
              + f",{meter.delivery_ratio:.3f}")

    # the degradation contract: partial fusion beats answer-or-nothing.
    # Under REPRO_FORCE_ERASURE the TRAINING network is also degraded —
    # a 2-hop tree then trains on under half its latents while SL's rare
    # round skips leave it nearly fully trained, so the SL comparison
    # stops being an inference-time degradation statement; it is asserted
    # on clean-training runs only (the FL one holds regardless).
    h = str(HEADLINE_ERASURE)
    rivals = ("fl/star",) if train_erasure > 0 else ("fl/star", "sl/star")
    for inl_key in ("inl/star", "inl/tree(2,2)"):
        for rival in rivals:
            assert record[inl_key][h] > record[rival][h], (
                f"{inl_key} acc@{h}={record[inl_key][h]:.4f} must beat "
                f"{rival} acc@{h}={record[rival][h]:.4f}: INL fuses the "
                "surviving latents, the single-uplink schemes lose the "
                "whole request")
        # graceful degradation: more erasure can only cost accuracy
        assert record[inl_key][h] > record[inl_key]["0.5"], inl_key
        if train_erasure > 0:
            # the forced-erasure leg must actually exercise lossy training
            assert training[inl_key]["delivery_ratio"] < 1.0, inl_key
    return record, training


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes/epochs (CI bench-smoke step)")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--eval-reps", type=int, default=5,
                    help="network realisations averaged per erasure rate")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_links.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    epochs = 2 if args.smoke else args.epochs
    eval_reps = 3 if args.smoke else args.eval_reps

    acc, training = accuracy_section(
        smoke=args.smoke, epochs=epochs, batch=args.batch,
        eval_reps=eval_reps, seed=args.seed)
    record = {"smoke": args.smoke, "erasure_grid": list(ERASURE_GRID),
              "forced_erasure": linkfault.forced_erasure(0.0),
              "accuracy": acc, "training": training}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
