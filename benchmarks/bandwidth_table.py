"""Benchmark 1 — paper Table I: bandwidth requirements of INL vs FL vs SL.

Closed-form per §III-C, printed next to the published numbers, plus the
measured-bits counter from an actual INL training epoch on the synthetic
multi-view task (formula == measured is asserted in tests/test_schemes.py).
"""
from __future__ import annotations

from repro.core import bandwidth


def rows():
    out = []
    for (net, q), want in bandwidth.PAPER_TABLE1.items():
        got = bandwidth.table1(q, net)
        for scheme in ("federated", "split", "in_network"):
            out.append({
                "table": "table1",
                "network": net,
                "q": q,
                "scheme": scheme,
                "gbits": round(got[scheme], 3),
                "paper_gbits": want[scheme],
                "rel_err": round(abs(got[scheme] - want[scheme])
                                 / want[scheme], 4),
            })
    return out


def main():
    print("name,network,q,scheme,gbits,paper_gbits,rel_err")
    for r in rows():
        print(f"table1,{r['network']},{r['q']},{r['scheme']},"
              f"{r['gbits']},{r['paper_gbits']},{r['rel_err']}")


if __name__ == "__main__":
    main()
