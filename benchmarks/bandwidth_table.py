"""Benchmark 1 — paper Table I: bandwidth requirements of INL vs FL vs SL.

Closed-form per §III-C, printed next to the published numbers, plus the
per-round accounting of every REGISTERED scheme on the reduced-scale
experiment config — the same `bits_per_round` values the unified runner
meters during training (formula == measured is asserted in
tests/test_scheme_parity.py).
"""
from __future__ import annotations

from repro.core import bandwidth


def rows():
    out = []
    for (net, q), want in bandwidth.PAPER_TABLE1.items():
        got = bandwidth.table1(q, net)
        for scheme in ("federated", "split", "in_network"):
            out.append({
                "table": "table1",
                "network": net,
                "q": q,
                "scheme": scheme,
                "gbits": round(got[scheme], 3),
                "paper_gbits": want[scheme],
                "rel_err": round(abs(got[scheme] - want[scheme])
                                 / want[scheme], 4),
            })
    return out


def scheme_rows(batch_size: int = 64):
    """Per-round bits for each registered scheme on the reduced config —
    the §III-C closed forms the Scheme registry routes through."""
    import jax

    from benchmarks.accuracy_curves import CFG
    from repro.core import schemes

    out = []
    for name in schemes.available():
        scheme = schemes.get(name)
        state = scheme.init(CFG, jax.random.PRNGKey(0))
        out.append({
            "scheme": name,
            "batch": batch_size,
            "round_bits": scheme.bits_per_round(CFG, state, batch_size),
            "epoch_overhead_bits": scheme.epoch_overhead_bits(CFG, state),
            "batches_per_round": scheme.batches_per_round(CFG),
        })
    return out


def main():
    print("name,network,q,scheme,gbits,paper_gbits,rel_err")
    for r in rows():
        print(f"table1,{r['network']},{r['q']},{r['scheme']},"
              f"{r['gbits']},{r['paper_gbits']},{r['rel_err']}")
    print("name,scheme,batch,round_bits,epoch_overhead_bits,"
          "batches_per_round")
    for r in scheme_rows():
        print(f"scheme_round,{r['scheme']},{r['batch']},"
              f"{r['round_bits']:.0f},{r['epoch_overhead_bits']:.0f},"
              f"{r['batches_per_round']}")


if __name__ == "__main__":
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    main()
