"""Benchmark — bytes-on-wire and throughput of the packed wire format.

Two sections, both written to BENCH_wire.json (--json):

  bytes       per-round wire bytes of every scheme's exchange under each
              wire format at link_bits in {2, 4, 8}, from the SAME
              `Scheme.wire_bytes_per_round` / `core/wirefmt.py` accounting
              the runner meters (derived from the real wire ops via
              eval_shape).  The section ASSERTS the acceptance contract:

                * the INL client->server exchange shrinks by exactly
                  32/link_bits packed vs dense fp32;
                * measured packed bytes == core/bandwidth.py closed forms
                  / 8 (forward == half the 2 b p s charge at s=link_bits;
                  packed_duplex == the full symmetric charge).

  throughput  wall-clock of the INL train round (value_and_grad + adam)
              packed vs dense at each link_bits, single device, compiled
              jnp reference backend (the TPU Pallas path is validated in
              interpret mode by the tests; what is timed here is what runs
              on this container).  Packing is extra elementwise work with
              no collective to win back on one device, so the interesting
              number is the OVERHEAD (expect ~1x; the bytes win shows up
              on real multi-host links).  A bf16-policy leg times the
              mixed-precision round against fp32.

--smoke runs tiny shapes with 2 reps for the CI bench-smoke step: the
assertions still execute, so the wire accounting cannot bit-rot between
nightly runs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_inl import PaperExperimentConfig
from repro.core import bandwidth, schemes, wirefmt
from repro.data import multiview

LINK_BITS = (2, 4, 8)
EPS = 1e-6


def bytes_section(batch: int = 64):
    """Per-round wire bytes, asserted against the closed forms."""
    rows, record = [], {}
    for bits in LINK_BITS:
        cfg = PaperExperimentConfig(link_bits=bits)
        J, d_b = cfg.num_clients, cfg.d_bottleneck
        p = J * d_b
        closed_bits = bandwidth.inl_epoch_bits(p, batch * J, J, bits)
        rec = {}
        for wire in ("dense", "packed", "packed_duplex"):
            wb = wirefmt.round_wire_bytes(J * batch, d_b, link_bits=bits,
                                          wire=wire)
            rec[wire] = wb
            rows.append((f"inl_round_bytes[{bits}b,{wire}]", wb["total"],
                         f"fwd={wb['fwd']} bwd={wb['bwd']}"))
        # acceptance: client->server bytes shrink by >= 32/bits / (1+eps)
        reduction = rec["dense"]["fwd"] / rec["packed"]["fwd"]
        want = 32 / bits
        assert reduction >= want / (1 + EPS), (bits, reduction, want)
        # measured == closed form: fwd half of 2 b p s at s=bits; the
        # duplex round == the full symmetric charge
        assert rec["packed"]["fwd"] * 8 == closed_bits / 2, \
            (bits, rec["packed"]["fwd"] * 8, closed_bits / 2)
        assert rec["packed_duplex"]["total"] * 8 == closed_bits, \
            (bits, rec["packed_duplex"]["total"] * 8, closed_bits)
        rec["reduction_fwd_vs_dense"] = reduction
        rec["closed_form_bits"] = closed_bits
        record[str(bits)] = rec
        rows.append((f"inl_fwd_reduction[{bits}b]", reduction,
                     f"want>={want:.1f}x OK"))
    return rows, record


def _time_round(round_fn, state, v, lab, reps: int):
    rng = jax.random.PRNGKey(0)
    out = round_fn(state, v, lab, rng)                  # compile + warmup
    jax.block_until_ready(out)
    ts = []
    for i in range(reps):
        t0 = time.perf_counter()
        out = round_fn(state, v, lab, jax.random.PRNGKey(i))
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


def throughput_section(batch: int, reps: int, smoke: bool):
    """INL round wall-clock packed vs dense, plus the bf16 policy leg."""
    base = PaperExperimentConfig(
        conv_channels=(8, 16) if smoke else (16, 32),
        d_bottleneck=16 if smoke else 64,
        dense_units=(64,) if smoke else (256,),
        image_shape=(16, 16, 3) if smoke else (32, 32, 3),
        dataset_size=batch * 2)
    imgs, labels = multiview.make_base_dataset(
        batch, image_shape=base.image_shape, seed=0)
    views = jnp.asarray(multiview.make_views(imgs, base.noise_stds))
    labels = jnp.asarray(labels)
    scheme = schemes.get("inl")

    rows, record = [], {}
    for bits in LINK_BITS:
        cfg = dataclasses.replace(base, link_bits=bits)
        state = scheme.init(cfg, jax.random.PRNGKey(0))
        v = views[None, :, :batch]
        lab = labels[None, :batch]
        med = {}
        for wire in ("dense", "packed"):
            med[wire] = _time_round(scheme.make_round(cfg, wire=wire),
                                    state, v, lab, reps)
        ratio = med["packed"] / med["dense"]
        rows.append((f"inl_round_us[{bits}b,dense]", med["dense"], ""))
        rows.append((f"inl_round_us[{bits}b,packed]", med["packed"],
                     f"overhead_vs_dense={ratio:.2f}x"))
        record[str(bits)] = {"dense_us": round(med["dense"], 1),
                             "packed_us": round(med["packed"], 1),
                             "packed_overhead": round(ratio, 3)}

    # bf16 compute policy at the widest packed link
    cfg32 = dataclasses.replace(base, link_bits=8)
    cfg16 = dataclasses.replace(cfg32, compute_dtype="bf16")
    state = scheme.init(cfg32, jax.random.PRNGKey(0))
    v, lab = views[None, :, :batch], labels[None, :batch]
    t32 = _time_round(scheme.make_round(cfg32, wire="packed"), state, v,
                      lab, reps)
    t16 = _time_round(scheme.make_round(cfg16, wire="packed"), state, v,
                      lab, reps)
    rows.append(("inl_round_us[8b,packed,bf16]", t16,
                 f"vs_fp32={t16/t32:.2f}x"))
    record["bf16_policy"] = {"fp32_us": round(t32, 1),
                             "bf16_us": round(t16, 1),
                             "bf16_vs_fp32": round(t16 / t32, 3)}
    return rows, record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_wire.json",
                    help="machine-readable results ('' disables)")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + 2 reps (CI bench-smoke step); the "
                         "bytes assertions still run")
    args = ap.parse_args(argv)
    if args.smoke:
        args.batch, args.reps = 32, 2

    print("name,value,derived")
    b_rows, b_rec = bytes_section(args.batch)
    for name, val, derived in b_rows:
        print(f"{name},{val:.1f},{derived}" if isinstance(val, float)
              else f"{name},{val},{derived}")
    t_rows, t_rec = throughput_section(args.batch, args.reps, args.smoke)
    for name, val, derived in t_rows:
        print(f"{name},{val:.1f},{derived}")

    record = {
        "bench": "wire",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "batch": args.batch,
        "link_bits": list(LINK_BITS),
        "bytes": b_rec,
        "throughput": t_rec,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
