"""Benchmark — the auto-placement search (repro/search): map the
accuracy-per-Gbit Pareto frontier over (scheme, cut depth, topology,
link width, wire) and CI-assert its contracts.

Two-stage pipeline (see repro/search/): every grid point is priced from
the closed-form ledgers first (exact, no training), the provably-redundant
points are pruned (wire twins, star-dominated graphs), the survivors
train through `runner.run_scheme`, and the Pareto frontier is extracted
on the (accuracy up, accounted Gbit down) plane.

The grid pairs each link width with the wire that IMPLEMENTS its charge —
32-bit links ship dense fp32, narrow links ship packed_duplex codeword
lanes (both directions quantized, lanes exactly filled at the bench
shapes) — so closed-form and measured bandwidth agree bit for bit on
every point, not just the frontier.  The deliberately over-shipping
spellings (dense at a narrow width; packed's fp32 backward) are the
pruning rules' subject and are exercised in tests/test_search.py instead.

In-bench asserts (the CI contract, every leg):

  parity      for EVERY trained point, the stage-1 priced bandwidth ==
              the runner's metered bandwidth exactly (both ledgers), and
              closed-form bits == measured bytes * 8 exactly;
  frontier    the searched frontier beats the three PURE baselines
              (inl/fl/sl at the paper's 32-bit dense star) at >= 1
              bandwidth budget: strictly higher accuracy than any
              baseline affordable at that budget;
  pruning     (--smoke) the pruned points are ALSO trained and every one
              is weakly dominated by a surviving candidate — pruning by
              ledger never discards a frontier config; star-dominated
              graphs additionally match their star sibling's accuracy
              EXACTLY (the bit-identity the rule is built on).

--smoke runs the CI grid (tiny shapes, 14 trained points); the default
grid sweeps J=6 graphs (star/chain/tree(2,2)) over widths {2,4,8,32}.
"""
from __future__ import annotations

import argparse
import json

from repro.configs.paper_inl import PaperExperimentConfig
from repro.search import pareto
from repro.search.pricing import CANDIDATE, PRUNED_STAR
from repro.search.space import SearchSpace, merge_points
from repro.search import driver as driver_lib

SMOKE_CFG = PaperExperimentConfig(
    conv_channels=(4, 8), d_bottleneck=8, dense_units=(32,),
    image_shape=(16, 16, 3), dataset_size=512)
# paper-shaped but CPU-sized; d_bottleneck=16 fills the duplex codeword
# lanes at every narrow width in the grid (16 * q % 32 == 0)
FULL_CFG = PaperExperimentConfig(
    conv_channels=(8, 16), d_bottleneck=16, dense_units=(64,),
    dataset_size=2048)

BASELINES = ("inl", "fl", "sl")           # the paper's three fixed points


def build_grid(smoke: bool):
    """Each width rides the wire that implements its closed-form charge:
    q=32 -> dense fp32, narrow -> packed_duplex lanes."""
    if smoke:
        topos, j = ("star(5)", "chain(5)"), 5
        widths = (4,)
    else:
        topos, j = ("star(6)", "chain(6)", "tree(2,2)"), 6
        widths = (2, 4, 8)
    star = (f"star({j})",)
    spaces = [
        SearchSpace(schemes=("inl",), topologies=topos),
        SearchSpace(schemes=("inl",), topologies=topos, link_bits=widths,
                    wires=("packed_duplex",)),
        SearchSpace(schemes=("splitfed", "hybrid"), topologies=star,
                    cut_depths=(None, 1)),
        SearchSpace(schemes=("splitfed", "hybrid"), topologies=star,
                    link_bits=widths, wires=("packed_duplex",),
                    cut_depths=(None, 1)),
        SearchSpace(schemes=("fl", "sl"), topologies=star),
    ]
    return merge_points(*spaces)


def assert_parity(result):
    """Priced == metered == closed, exactly, for every trained point."""
    for m in result.measured.values():
        if not m.trained:
            continue
        if abs(m.gbits - m.priced_gbits) * 1e9 >= 1.0:
            raise AssertionError(
                f"{m.key}: priced {m.priced_gbits} Gbit != metered "
                f"{m.gbits} Gbit — pricing and runner disagree")
        if abs(m.measured_gbits - m.priced_measured_gbits) * 1e9 >= 1.0:
            raise AssertionError(
                f"{m.key}: priced wire bytes {m.priced_measured_gbits} != "
                f"metered {m.measured_gbits}")
        if abs(m.gbits - m.measured_gbits) * 1e9 >= 1.0:
            raise AssertionError(
                f"{m.key}: closed-form {m.gbits} Gbit != measured "
                f"{m.measured_gbits} Gbit — the grid pairs every width "
                f"with the wire that implements its charge")


def assert_frontier_dominates(result):
    """At >= 1 budget the frontier strictly beats every affordable pure
    baseline (an unaffordable baseline contributes nothing — accuracy 0).
    Returns the winning budgets for the record."""
    base_keys = [m.key for m in result.measured.values()
                 if m.trained and m.key.split("/")[0] in BASELINES
                 and "/q32/dense/" in m.key
                 and m.key.split("/")[1].startswith("star(")]
    baselines = [result.measured[k] for k in base_keys]
    if len(baselines) < len(BASELINES):
        raise AssertionError(f"grid lost a pure baseline: {base_keys}")
    budgets = sorted({m.gbits for m in result.measured.values()})
    wins = []
    for budget in budgets:
        f = pareto.best_under_budget(result.frontier, budget)
        b = pareto.best_under_budget(baselines, budget)
        if f is not None and f.accuracy > (b.accuracy if b else 0.0):
            wins.append({"budget_gbits": budget, "frontier": f.key,
                         "frontier_acc": f.accuracy,
                         "baseline": b.key if b else None,
                         "baseline_acc": b.accuracy if b else 0.0})
    if not wins:
        raise AssertionError(
            "the searched frontier never beats the pure baselines at any "
            "budget — the search found nothing the comparison table "
            "already had")
    return wins


def assert_pruning_sound(result):
    """Every exhaustively-trained pruned point is weakly dominated by a
    trained candidate; star-dominated points tie their sibling exactly."""
    cands = result.candidates()
    for m in result.measured.values():
        if m.status == CANDIDATE or not m.trained:
            continue
        if not any(c.accuracy >= m.accuracy - 1e-12
                   and c.gbits <= m.gbits + 1e-12 for c in cands):
            raise AssertionError(
                f"pruning discarded a frontier config: {m.key} "
                f"(acc {m.accuracy}, {m.gbits} Gbit) is undominated")
        if m.status == PRUNED_STAR:
            sib = result.measured[m.stand_in]
            if m.accuracy != sib.accuracy:
                raise AssertionError(
                    f"{m.key} trained to acc {m.accuracy} but its star "
                    f"sibling {sib.key} reached {sib.accuracy} — the "
                    f"32-bit hop-identity the prune rests on is broken")
            if m.gbits <= sib.gbits:
                raise AssertionError(
                    f"{m.key} is not costlier than its star sibling")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI grid: tiny shapes, pruned points trained too")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_frontier.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    epochs = 2 if args.smoke else args.epochs
    base_cfg = SMOKE_CFG if args.smoke else FULL_CFG

    points = build_grid(args.smoke)
    result = driver_lib.run_search(
        points, base_cfg, epochs=epochs, batch_size=args.batch,
        seed=args.seed, eval_n=256, train_pruned=args.smoke)

    assert_parity(result)
    wins = assert_frontier_dominates(result)
    if args.smoke:
        assert_pruning_sound(result)

    print("\naccuracy-per-Gbit frontier (accounted == measured bits):")
    for m in result.frontier:
        print(f"  {m.key:42s} acc {m.accuracy:.3f}  {m.gbits:.5f} Gbit  "
              f"({m.accuracy / max(m.gbits, 1e-9):8.1f} acc/Gbit)")
    w = wins[0]
    print(f"frontier beats the pure baselines at "
          f"{w['budget_gbits']:.5f} Gbit: {w['frontier']} acc "
          f"{w['frontier_acc']:.3f} vs {w['baseline_acc']:.3f}")

    record = dict(result.record(), smoke=args.smoke, epochs=epochs,
                  batch=args.batch, domination_wins=wins,
                  pruning_verified=bool(args.smoke))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
