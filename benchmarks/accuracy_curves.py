"""Benchmark 2 — paper Figures 5 & 7: accuracy vs epochs and accuracy vs
bandwidth for every registered scheme on the (synthetic) multi-view task.

All schemes run through the unified Scheme registry
(`repro.core.schemes`): one loop (`schemes.runner.run_scheme`) drives
init / rounds / predict / bandwidth for INL, FL, SL — and any scheme
registered later — on the same data and the same fused cut-layer substrate.
Reduced scale for CPU: the paper's qualitative claims to check are
  (1) INL reaches higher accuracy than FL, and converges faster;
  (2) per unit of exchanged bandwidth, INL >> SL > FL.
"""
from __future__ import annotations

import time

from repro.configs.paper_inl import PaperExperimentConfig
from repro.core import schemes
from repro.data import multiview

CFG = PaperExperimentConfig(conv_channels=(8, 16), d_bottleneck=16,
                            dense_units=(64,), dataset_size=1024)
EPOCHS = 5
BATCH = 64


def _data(experiment: int):
    """Multi-view data for the comparison runs.

    NOTE: this reduced-scale harness (like the seed's runners) trains every
    scheme under the Exp-2 protocol — all clients see all images, differing
    only by their per-client noise level.  `experiment` selects the figure
    LABEL (fig5 vs fig7) for the CSV; the Exp-1 per-scheme data partition
    (multiview.split_experiment1, paper §IV-A) is not wired into the
    unified runner yet."""
    imgs, labels = multiview.make_base_dataset(CFG.dataset_size, seed=0)
    views = multiview.make_views(imgs, CFG.noise_stds)
    return views, labels


def main(experiment: int = 2, epochs: int = EPOCHS):
    views, labels = _data(experiment)
    print("name,scheme,epoch,accuracy,gbits_exchanged")
    t0 = time.time()
    fig = 5 if experiment == 1 else 7
    for name in schemes.available():
        curve = schemes.runner.run_scheme(name, views, labels, CFG,
                                          epochs=epochs, batch_size=BATCH)
        for pt in curve:
            print(f"fig{fig},{name},{pt.epoch},{pt.accuracy:.4f},"
                  f"{pt.gbits:.6f}", flush=True)
    print(f"# wall {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
