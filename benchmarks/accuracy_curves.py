"""Benchmark 2 — paper Figures 5 & 7: accuracy vs epochs and accuracy vs
bandwidth for INL / FL / SL on the (synthetic) multi-view task.

Experiment 1 partitions the data per scheme (§IV-A); Experiment 2 trains all
schemes on the same data, differing only in per-client noise (§IV-B).
Reduced scale for CPU: the paper's qualitative claims to check are
  (1) INL reaches higher accuracy than FL, and converges faster;
  (2) per unit of exchanged bandwidth, INL >> SL > FL.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.paper_inl import PaperExperimentConfig
from repro.core import bandwidth, fl, inl, paper_model, sl
from repro.data import multiview

CFG = PaperExperimentConfig(conv_channels=(8, 16), d_bottleneck=16,
                            dense_units=(64,), dataset_size=1024)
EPOCHS = 5
BATCH = 64


def _data(experiment: int):
    imgs, labels = multiview.make_base_dataset(CFG.dataset_size, seed=0)
    views = multiview.make_views(imgs, CFG.noise_stds)
    split = (multiview.split_experiment1 if experiment == 1
             else lambda v, l, J: multiview.split_experiment2(v, l, J))(
        views, labels, CFG.num_clients)
    return views, labels, split


def run_inl(views, labels, epochs=EPOCHS):
    params, state = inl.init(CFG, jax.random.PRNGKey(0))
    opt = optim.adam(2e-3)
    opt_state = opt.init(params)
    step = inl.make_train_step(CFG, opt)
    rng = jax.random.PRNGKey(1)
    meter = bandwidth.BandwidthMeter()
    p_total = CFG.num_clients * CFG.d_bottleneck
    curve = []
    ev = jnp.asarray(views[:, :512])
    el = jnp.asarray(labels[:512])
    for ep in range(epochs):
        for v, l in multiview.multiview_batches(views, labels, BATCH,
                                                seed=ep):
            rng, sub = jax.random.split(rng)
            params, state, opt_state, m = step(
                params, state, opt_state, jnp.asarray(v), jnp.asarray(l),
                sub)
            meter.add(2 * BATCH * p_total * CFG.link_bits)
        acc = float(inl.evaluate(params, state, ev, el))
        curve.append((ep + 1, acc, meter.gbits))
    return curve


def run_sl(views, labels, epochs=EPOCHS):
    (client, server), state = sl.init(CFG, jax.random.PRNGKey(0))
    oc, os_ = optim.adam(2e-3), optim.adam(2e-3)
    oc_s, os_s = oc.init(client), os_.init(server)
    step = sl.make_train_step(oc, os_)
    rng = jax.random.PRNGKey(1)
    meter = bandwidth.BandwidthMeter()
    p_total = CFG.num_clients * CFG.d_bottleneck
    n_client = sum(x.size for x in jax.tree.leaves(client))
    curve = []
    ev = jnp.asarray(views[:, :512])
    el = jnp.asarray(labels[:512])
    for ep in range(epochs):
        # round-robin: each epoch every client takes one pass over its shard
        for v, l in multiview.multiview_batches(views, labels, BATCH,
                                                seed=ep):
            rng, sub = jax.random.split(rng)
            client, server, state, oc_s, os_s, m = step(
                client, server, state, oc_s, os_s, jnp.asarray(v),
                jnp.asarray(l), sub)
            meter.add(2 * BATCH * p_total * 32)
        meter.add(n_client * CFG.num_clients * 32)     # weight hand-offs
        probs = sl.predict(client, server, state, ev)
        acc = float((jnp.argmax(probs, -1) == el).mean())
        curve.append((ep + 1, acc, meter.gbits))
    return curve


def run_fl(views, labels, epochs=EPOCHS, local_steps=2):
    params, state = fl.init(CFG, jax.random.PRNGKey(0))
    opt = optim.adam(2e-3)
    opt_state = jax.vmap(opt.init)(params)
    round_fn = fl.make_round(CFG, opt, local_steps)
    J = CFG.num_clients
    n_params = paper_model.fl_param_count(CFG)
    meter = bandwidth.BandwidthMeter()
    curve = []
    n = labels.shape[0]
    img_avg = jnp.asarray(multiview.average_view(views[:, :512]))
    el = jnp.asarray(labels[:512])
    rng = jax.random.PRNGKey(1)
    rounds_per_epoch = max(n // (BATCH * local_steps * J), 1)
    for ep in range(epochs):
        for r in range(rounds_per_epoch):
            vs, ls = [], []
            for j in range(J):
                idx = np.random.default_rng(ep * 1000 + r * 10 + j) \
                    .integers(0, n, BATCH * local_steps)
                vj = views[j][idx].reshape(local_steps, BATCH,
                                           *views.shape[2:])
                vs.append(np.broadcast_to(
                    vj[:, None], (local_steps, J, BATCH)
                    + views.shape[2:]).copy())
                ls.append(labels[idx].reshape(local_steps, BATCH))
            rng, *subs = jax.random.split(rng, J + 1)
            params, state, opt_state, m = round_fn(
                params, state, opt_state, jnp.asarray(np.stack(vs)),
                jnp.asarray(np.stack(ls)), jnp.stack(subs))
            meter.add(fl.round_bits(CFG, n_params))
        probs = fl.predict(params, state, img_avg)
        acc = float((jnp.argmax(probs, -1) == el).mean())
        curve.append((ep + 1, acc, meter.gbits))
    return curve


def main(experiment: int = 2, epochs: int = EPOCHS):
    views, labels, split = _data(experiment)
    print("name,scheme,epoch,accuracy,gbits_exchanged")
    t0 = time.time()
    for scheme, runner in (("inl", run_inl), ("sl", run_sl), ("fl", run_fl)):
        curve = runner(views, labels, epochs)
        for ep, acc, gb in curve:
            print(f"fig{5 if experiment == 1 else 7},{scheme},{ep},"
                  f"{acc:.4f},{gb:.6f}", flush=True)
    print(f"# wall {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
