"""Benchmark — multi-hop topologies: per-edge bytes and round wall-clock.

Two sections, written to BENCH_topology.json (--json):

  bytes       the per-edge bandwidth ledger of one INL round on star(J),
              chain(J) and tree(2,2): closed-form §III-C bits and measured
              wire bytes per edge (core/topology.py over the real
              core/wirefmt.py ops), dense and packed_duplex.  The section
              ASSERTS the topology contract on every run:

                * per-edge charges sum to the scheme totals exactly;
                * star(J)'s per-edge ledger sums to the pre-topology
                  Table-I totals exactly;
                * packed_duplex measured bytes == closed forms per edge
                  (lane-filling d_bottleneck).

  throughput  wall-clock of the jitted INL train round per topology —
              star vs chain vs tree on the same fixture model.  An
              edge-homogeneous graph runs the same single fused cut-layer
              launch as the star plus J cheap re-encoding hops, so the
              interesting number is the hop OVERHEAD (expect ~1x on one
              device; the multi-hop story is bandwidth, not compute).

--smoke runs tiny shapes with 2 reps for the CI bench-smoke step, so the
per-edge accounting assertions cannot bit-rot between nightly runs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_inl import PaperExperimentConfig
from repro.core import bandwidth, schemes
from repro.core import topology as topology_lib
from repro.data import multiview

EPS = 1e-9


def _cfg(J: int, *, smoke: bool, link_bits: int = 8):
    stds = (0.4, 1.0, 2.0, 3.0, 4.0, 0.7, 1.5, 2.5)[:J]
    if smoke:
        return PaperExperimentConfig(
            num_clients=J, noise_stds=stds, conv_channels=(4,),
            d_bottleneck=8, dense_units=(32,), image_shape=(16, 16, 3),
            link_bits=link_bits, dataset_size=128)
    return PaperExperimentConfig(num_clients=J, noise_stds=stds,
                                 link_bits=link_bits)


def _topologies(J: int):
    return {"star": topology_lib.star(J),
            "chain": topology_lib.chain(J),
            "tree(2,2)": topology_lib.tree(2, 2)}


def bytes_section(*, smoke: bool, batch: int):
    print("name,edge,closed_bits,measured_bytes_dense,"
          "measured_bytes_duplex")
    record = {}
    scheme = schemes.get("inl")
    for name, topo in _topologies(5).items():
        J = topo.num_views()
        cfg = dataclasses.replace(_cfg(J, smoke=smoke), d_bottleneck=16)
        closed = topology_lib.round_edge_bits(topo, cfg, batch)
        dense = topology_lib.round_edge_wire_bytes(topo, cfg, batch,
                                                   wire="dense")
        duplex = topology_lib.round_edge_wire_bytes(topo, cfg, batch,
                                                    wire="packed_duplex")
        # contract: per-edge sums == the Scheme API totals, exactly
        assert sum(closed.values()) == scheme.bits_per_round(
            cfg, None, batch, topology=topo)
        assert sum(dense.values()) == scheme.wire_bytes_per_round(
            cfg, None, batch, wire="dense", topology=topo)
        # packed_duplex: measured == closed per edge (lanes fill at d=16)
        for k in closed:
            assert duplex[k] * 8 == closed[k], (name, k)
        if name == "star":
            p = J * cfg.d_bottleneck
            assert sum(closed.values()) == bandwidth.inl_epoch_bits(
                p, batch * J, J, cfg.link_bits)
        for k in closed:
            print(f"{name},{k},{closed[k]:.0f},{dense[k]:.0f},"
                  f"{duplex[k]:.0f}")
        record[name] = {"closed_bits": closed, "dense_bytes": dense,
                        "duplex_bytes": duplex,
                        "levels": [list(lv) for lv in topo.levels()]}
    return record


def _time_round(cfg, topo, views, labels, *, reps: int, batch: int):
    scheme = schemes.get("inl")
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    round_fn = scheme.make_round(cfg, topology=topo)
    v = views[None, :, :batch]
    lab = labels[None, :batch]
    state, m = round_fn(state, v, lab, jax.random.PRNGKey(0))  # compile
    jax.block_until_ready(m["loss"])
    times = []
    for i in range(reps):
        t0 = time.perf_counter()
        state, m = round_fn(state, v, lab, jax.random.PRNGKey(i))
        jax.block_until_ready(m["loss"])
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


def throughput_section(*, smoke: bool, batch: int, reps: int):
    print("name,us_per_round,vs_star")
    record = {}
    imgs, labels = multiview.make_base_dataset(
        max(batch, 64), image_shape=_cfg(5, smoke=smoke).image_shape,
        seed=0)
    labels = jnp.asarray(labels)
    base = None
    for name, topo in _topologies(5).items():
        cfg = _cfg(topo.num_views(), smoke=smoke)
        views = jnp.asarray(multiview.make_views(imgs, cfg.noise_stds))
        t = _time_round(cfg, topo, views, labels, reps=reps, batch=batch)
        if base is None:
            base = t
        rel = t / max(base, EPS)
        print(f"{name},{t * 1e6:.0f},{rel:.2f}x")
        record[name] = {"us_per_round": t * 1e6, "vs_star": rel}
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 2 reps (CI bench-smoke step)")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--json", default="BENCH_topology.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    reps = 2 if args.smoke else args.reps
    batch = 16 if args.smoke else args.batch

    record = {"smoke": args.smoke, "batch": batch,
              "bytes": bytes_section(smoke=args.smoke, batch=batch),
              "throughput": throughput_section(smoke=args.smoke,
                                               batch=batch, reps=reps)}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
