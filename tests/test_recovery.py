"""Elastic training recovery: resume is BIT-IDENTICAL, clients leave and
rejoin, transport rounds degrade per the scheme's semantics.

The contracts:

  * run_scheme with ckpt_dir/resume reproduces the uninterrupted curve
    EXACTLY on every dispatch path (scan, per_round, transport) — state,
    rng fast-forward, and both meter ledgers included;
  * transport execution: the (J,) delivery verdict reaches the round as an
    explicit argument — INL partial-fuses survivors (state moves on a
    partial round), SL carries its state unchanged (whole round lost), FL
    drops the missing client from the FedAvg average;
  * a transport-mode resume replays breaker trajectories without
    re-charging the ledgers;
  * a node kill mid-training = a client leave; the mask returns to full
    the tick its window closes (rejoin).
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.chaos import ChaosSchedule
from repro.core import schemes
from repro.core import topology as topology_lib
from repro.core.schemes import runner
from repro.transport import DEFAULT_RETRY, NetworkTransport
from tests._schemes_common import CFG, fixture_data

EPOCHS = 4
HALF = 2


def _run(name="inl", *, cfg=CFG, epochs=EPOCHS, **kw):
    views, labels = fixture_data()
    return runner.run_scheme(name, views, labels, cfg, epochs=epochs,
                             batch_size=32, seed=3, **kw)


@pytest.mark.parametrize("dispatch", ["scan", "per_round"])
def test_resume_bit_identical(dispatch, tmp_path):
    golden = _run(dispatch=dispatch)
    d = str(tmp_path)
    _run(dispatch=dispatch, epochs=HALF, ckpt_dir=d)
    resumed = _run(dispatch=dispatch, ckpt_dir=d, resume=True)
    assert resumed == golden        # CurvePoints compare exactly — accuracy,
    #                                 offered/measured/delivered Gbit included


def test_resume_bit_identical_under_linkfaults(tmp_path):
    # the scan path's fault metering replays per-round subkeys — the resume
    # fast-forward must reproduce them exactly
    cfg = dataclasses.replace(CFG, edge_dropout=0.3)
    golden = _run(cfg=cfg)
    d = str(tmp_path)
    _run(cfg=cfg, epochs=HALF, ckpt_dir=d)
    assert _run(cfg=cfg, ckpt_dir=d, resume=True) == golden


def _make_transport(chaos=None, seed=7):
    topo = topology_lib.resolve(None, CFG)
    return NetworkTransport(topo, CFG, seed=seed, policy=DEFAULT_RETRY,
                            chaos=chaos)


def test_transport_resume_replays_breakers_without_recharging(tmp_path):
    chaos = ChaosSchedule().down_edge("m0->fuse", 1, 2)
    tg = _make_transport(chaos)
    golden = _run(transport=tg)
    gsnap = tg.snapshot()
    tg.close()

    d = str(tmp_path)
    t1 = _make_transport(chaos)
    _run(transport=t1, epochs=HALF, ckpt_dir=d)
    t1.close()
    t2 = _make_transport(chaos)
    resumed = _run(transport=t2, ckpt_dir=d, resume=True)
    rsnap = t2.snapshot()
    t2.close()
    assert resumed == golden
    assert gsnap == rsnap           # ledgers AND breaker counters


def test_transport_round_semantics_partial_delivery():
    # one partial round, same delivery verdict for all three schemes:
    # INL's state moves, SL's does not, FL drops the client (moves too,
    # but averages only the survivors)
    views, labels = fixture_data()
    J = CFG.num_clients
    delivery = jnp.asarray(np.arange(J) != 2)
    v1, l1 = views[:, :32][None], labels[:32][None]
    rng = jax.random.PRNGKey(11)

    def moved(scheme_name, bpr_views):
        scheme = schemes.get(scheme_name)
        state = scheme.init(CFG, jax.random.PRNGKey(0))
        new, _ = scheme.make_transport_round(CFG)(
            state, bpr_views[0], bpr_views[1], rng, delivery)
        return any(not np.array_equal(a, b) for a, b in
                   zip(jax.tree.leaves(jax.device_get(new)),
                       jax.tree.leaves(jax.device_get(state))))

    assert moved("inl", (v1, l1))
    assert not moved("sl", (v1, l1))
    fl = schemes.get("fl")
    R = fl.batches_per_round(CFG)
    vR = jnp.broadcast_to(v1, (R,) + v1.shape[1:])
    lR = jnp.broadcast_to(l1, (R,) + l1.shape[1:])
    assert moved("fl", (vR, lR))


def test_transport_round_all_lost_keeps_state():
    # every vote lost: INL has nothing to fuse but still takes a step on
    # the renormalised zeros?  No — the semantics pin: SL holds; FL keeps
    # the previous global model (all clients dropped from the average)
    views, labels = fixture_data()
    J = CFG.num_clients
    none = jnp.zeros(J, bool)
    rng = jax.random.PRNGKey(11)
    fl = schemes.get("fl")
    R = fl.batches_per_round(CFG)
    v = jnp.broadcast_to(views[:, :32][None], (R, J, 32) + views.shape[2:])
    l = jnp.broadcast_to(labels[:32][None], (R, 32))
    state = fl.init(CFG, jax.random.PRNGKey(0))
    new, _ = fl.make_transport_round(CFG)(state, v, l, rng, none)
    assert all(np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(jax.device_get(new["params"])),
                   jax.tree.leaves(jax.device_get(state["params"]))))


def test_node_kill_is_leave_then_rejoin():
    chaos = ChaosSchedule().kill_node("m1", at=2, duration=3)
    tr = _make_transport(chaos)
    masks = np.stack([tr.round_outcome(t, 32, charge=False).mask
                      for t in range(8)])
    tr.close()
    assert masks[:2].all() and masks[5:].all()      # before + after: full
    assert not masks[2:5, 1].any()                  # the leave window
    assert masks[2:5, [0, 2, 3, 4]].all()           # survivors keep voting


def test_transport_excludes_mesh_and_foreign_meter():
    from repro.core import bandwidth
    views, labels = fixture_data()
    tr = _make_transport()
    with pytest.raises(ValueError, match="meter"):
        runner.run_scheme("inl", views, labels, CFG, epochs=1,
                          batch_size=32, transport=tr,
                          meter=bandwidth.BandwidthMeter())
    tr.close()


def test_transport_curve_meters_on_transport_ledger():
    tr = _make_transport(ChaosSchedule().down_edge("m0->fuse", 0, 2))
    curve = _run(transport=tr, epochs=2)
    snap = tr.snapshot()
    tr.close()
    assert curve[-1].gbits > 0
    assert curve[-1].delivered_gbits < curve[-1].gbits   # the outage cost
    assert snap["delivery_ratio"] < 1.0
