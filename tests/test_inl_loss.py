"""Properties of the eq.-(6) loss and the paper's training algorithm.

The key invariant (Remark 2 / eq. 10): JAX AD through the latent
concatenation reproduces exactly the paper's error-vector split — node j's
encoder receives only chunk delta[j] of the decoder-input cotangent plus the
local gradient of its own rate term.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.configs.paper_inl import SMOKE as CFG
from repro.core import bottleneck, inl, losses, paper_model


def _setup(seed=0, B=8):
    params, state = inl.init(CFG, jax.random.PRNGKey(seed))
    views = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (CFG.num_clients, B) + CFG.image_shape)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 2), (B,), 0,
                                CFG.num_classes)
    return params, state, views, labels


@pytest.mark.slow
def test_loss_decomposition():
    """loss == ce_joint + s * (sum branch CE + sum rates)."""
    params, state, views, labels = _setup()
    loss, (m, _) = inl.loss_fn(params, state, views, labels,
                               jax.random.PRNGKey(3), CFG)
    J = CFG.num_clients
    recon = m["ce_joint"] + CFG.s * (J * m["ce_branch_mean"]
                                     + m["rate_total"])
    np.testing.assert_allclose(float(loss), float(recon), rtol=1e-5)


@pytest.mark.slow
def test_s_zero_reduces_to_joint_ce():
    import dataclasses
    cfg0 = dataclasses.replace(CFG, s=0.0)
    params, state, views, labels = _setup()
    loss, (m, _) = inl.loss_fn(params, state, views, labels,
                               jax.random.PRNGKey(3), cfg0)
    np.testing.assert_allclose(float(loss), float(m["ce_joint"]), rtol=1e-6)


def test_gradient_split_matches_paper_eq10():
    """d loss / d u_j computed by full AD == the hand-split backprop: the
    j-th chunk of the decoder-input error vector (+ branch-head term),
    plus s * d(rate_j)/d u_j from the sampled estimator."""
    params, state, views, labels = _setup()
    rng = jax.random.PRNGKey(7)
    u, mu, logvar, _ = inl.encode(params, state, views, train=True, rng=rng,
                                  link_bits=32)
    J, B, d = u.shape
    s = CFG.s

    def total_loss(u_all):
        joint, branch = inl.decode(params, u_all, train=False)
        ce_j = losses.xent(joint, labels)
        ce_b = jnp.stack([losses.xent(bl, labels) for bl in branch]).sum()
        rate = jnp.stack([
            jnp.mean(bottleneck.rate_sampled(u_all[j], mu[j], logvar[j]))
            for j in range(J)]).sum()
        return ce_j + s * (ce_b + rate)

    g_full = jax.grad(total_loss)(u)                     # (J,B,d)

    # --- the paper's split: backprop the DECODER path only, then add the
    # local rate gradient per node (eq. 10)
    def decoder_only(u_all):
        joint, branch = inl.decode(params, u_all, train=False)
        ce_j = losses.xent(joint, labels)
        ce_b = jnp.stack([losses.xent(bl, labels) for bl in branch]).sum()
        return ce_j + s * ce_b

    delta = jax.grad(decoder_only)(u)                    # split error vectors
    for j in range(J):
        rate_j = lambda uj: s * jnp.mean(
            bottleneck.rate_sampled(uj, mu[j], logvar[j]))
        g_manual_j = delta[j] + jax.grad(rate_j)(u[j])
        np.testing.assert_allclose(np.asarray(g_full[j]),
                                   np.asarray(g_manual_j),
                                   atol=1e-6, rtol=1e-5)


def test_error_vector_is_chunked_concat():
    """The decoder-input cotangent splits horizontally into J chunks of size
    d_bottleneck — i.e. node j needs only its own sub-vector (Remark 2)."""
    params, state, views, labels = _setup()
    u, _, _, _ = inl.encode(params, state, views, train=False,
                            sample_latent=False)
    J, B, d = u.shape

    def dec_loss_cat(u_cat):
        joint = paper_model.decoder_apply(params.decoder, u_cat, train=False)
        return losses.xent(joint, labels)

    u_cat = jnp.moveaxis(u, 0, 1).reshape(B, J * d)
    g_cat = jax.grad(dec_loss_cat)(u_cat)               # (B, J*d)

    def dec_loss_stacked(u_all):
        cat = jnp.moveaxis(u_all, 0, 1).reshape(B, J * d)
        return dec_loss_cat(cat)

    g_stacked = jax.grad(dec_loss_stacked)(u)           # (J,B,d)
    for j in range(J):
        np.testing.assert_allclose(
            np.asarray(g_cat[:, j * d:(j + 1) * d]),
            np.asarray(g_stacked[j]), atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_sampled_rate_matches_analytic_in_expectation(seed):
    """E_eps[log P(u|x)/Q(u)] == KL(P || Q) — the paper's estimator is
    unbiased for the Gaussian case."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    mu = jax.random.normal(k1, (4, 8))
    lv = jnp.clip(jax.random.normal(k2, (4, 8)), -2, 1)
    n = 4000
    eps_keys = jax.random.split(k3, n)
    us = jax.vmap(lambda k: bottleneck.sample(k, mu, lv))(eps_keys)
    sampled = jax.vmap(
        lambda u: bottleneck.rate_sampled(u, mu, lv))(us).mean(axis=0)
    analytic = bottleneck.rate_analytic(mu, lv)
    se = jnp.std(jax.vmap(lambda u: bottleneck.rate_sampled(u, mu, lv))(us),
                 axis=0) / np.sqrt(n)
    assert bool((jnp.abs(sampled - analytic) < 6 * se + 5e-2).all())


def test_quantizer_straight_through():
    from repro.core import linkmodel
    u = jnp.linspace(-3, 3, 64).reshape(8, 8)
    q8 = linkmodel.quantize_st(u, 8)
    assert float(jnp.max(jnp.abs(q8 - u))) < 8.0 / 255 + 1e-6
    # straight-through: gradient of sum(quantize(u)) == ones
    g = jax.grad(lambda x: linkmodel.quantize_st(x, 4).sum())(u)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g))
    # capacity ordering: fewer bits -> larger distortion
    e4 = float(jnp.mean((linkmodel.quantize_st(u, 4) - u) ** 2))
    e8 = float(jnp.mean((q8 - u) ** 2))
    assert e4 > e8


def test_bits_accounting_matches_paper_formula():
    from repro.core import linkmodel
    b, p, s = 64, CFG.num_clients * CFG.d_bottleneck, CFG.link_bits
    assert linkmodel.training_step_bits(b, p, s) == 2 * b * p * s
    assert linkmodel.inference_step_bits(b, p, s) == b * p * s
