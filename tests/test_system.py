"""End-to-end behaviour tests for the paper's system (the old placeholder).

The INL architecture must (a) train distributively with only bottleneck
activations crossing node boundaries, (b) produce a soft prediction at node
J+1, and (c) beat chance on the multi-view task within a few epochs —
the qualitative claims of §IV.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.paper_inl import SMOKE as CFG
from repro.core import inl
from repro.data import multiview


@pytest.fixture(scope="module")
def trained():
    imgs, labels = multiview.make_base_dataset(256, seed=0)
    views = multiview.make_views(imgs, CFG.noise_stds)
    params, state = inl.init(CFG, jax.random.PRNGKey(0))
    opt = optim.adam(2e-3)
    opt_state = opt.init(params)
    step = inl.make_train_step(CFG, opt)
    rng = jax.random.PRNGKey(1)
    for ep in range(3):
        for v, l in multiview.multiview_batches(views, labels, 64, seed=ep):
            rng, sub = jax.random.split(rng)
            params, state, opt_state, m = step(
                params, state, opt_state, jnp.asarray(v), jnp.asarray(l), sub)
    return params, state, views, labels


@pytest.mark.slow
def test_soft_output_is_distribution(trained):
    params, state, views, labels = trained
    probs = inl.predict(params, state, jnp.asarray(views[:, :16]))
    np.testing.assert_allclose(np.asarray(probs.sum(axis=-1)), 1.0,
                               atol=1e-5)
    assert probs.shape == (16, CFG.num_classes)


@pytest.mark.slow
def test_inference_uses_only_bottleneck(trained):
    """Inference phase (§III-B): node J+1 sees ONLY (u_1..u_J) — predictions
    must be reproducible from the latents alone."""
    params, state, views, labels = trained
    v = jnp.asarray(views[:, :16])
    u, _, _, _ = inl.encode(params, state, v, train=False,
                            sample_latent=False)
    joint, _ = inl.decode(params, u, train=False)
    probs_direct = jax.nn.softmax(joint, axis=-1)
    probs_full = inl.predict(params, state, v)
    np.testing.assert_allclose(np.asarray(probs_direct),
                               np.asarray(probs_full), atol=1e-6)


@pytest.mark.slow
def test_trained_above_chance(trained):
    params, state, views, labels = trained
    acc = float(inl.evaluate(params, state, jnp.asarray(views),
                             jnp.asarray(labels)))
    assert acc > 0.3, acc
