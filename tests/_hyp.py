"""hypothesis compatibility layer for the test suite.

Tier-1 CI (`PYTHONPATH=src python -m pytest -x -q`) must collect and pass
without optional dependencies.  When `hypothesis` is installed (see
requirements-test.txt) the real library is re-exported; otherwise a
minimal deterministic fallback runs each property test over a fixed-seed
sample of the strategy space — weaker shrinking/coverage, but the
properties still execute.

Usage in tests:  `from _hyp import given, settings, st`
"""
import inspect
import random

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # deterministic fallback
    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:                                         # noqa: N801 (mimic API)
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda r: r.choice(seq))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rnd = random.Random(0x5EED)
                for _ in range(_FALLBACK_EXAMPLES):
                    drawn = {k: s.draw(rnd) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # pytest must not see the strategy params (it would treat them
            # as fixtures), so expose a signature without them — and no
            # __wrapped__, which pytest would follow back to the original.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # keep pytest marks applied below @given (e.g. `slow`)
            wrapper.pytestmark = list(getattr(fn, "pytestmark", []))
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return wrapper
        return deco
