"""Serving-path correctness: prefill -> decode must reproduce the full
forward, including ring-buffer sliding windows and MoE serving paths."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import zoo

S = 32


def _cfg(name, **kw):
    cfg = get_smoke_config(name)
    cfg = dataclasses.replace(cfg, dtype="float32", **kw)
    # capacity routing is length-dependent; use a no-drop factor for exact
    # train/serve agreement (see test_moe.py for the dropping property)
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


def _batches(cfg, key):
    if cfg.modality == "audio_tokens":
        toks = jax.random.randint(key, (2, S, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
        return ({"tokens_mc": toks}, {"tokens_mc": toks[:, :S - 1]},
                {"tokens_mc": toks[:, S - 1:S],
                 "cache_len": jnp.asarray(S - 1)})
    if cfg.modality == "vlm":
        P = cfg.num_prefix_tokens
        pe = jax.random.normal(key, (2, P, cfg.d_model))
        toks = jax.random.randint(key, (2, S - P), 0, cfg.vocab_size)
        return ({"patch_embeds": pe, "tokens": toks},
                {"patch_embeds": pe, "tokens": toks[:, :-1]},
                {"tokens": toks[:, -1:], "cache_len": jnp.asarray(S - 1)})
    toks = jax.random.randint(key, (2, S), 0, cfg.vocab_size)
    return ({"tokens": toks}, {"tokens": toks[:, :S - 1]},
            {"tokens": toks[:, S - 1:S], "cache_len": jnp.asarray(S - 1)})


def _check(cfg, tol=1e-3):
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    full, pre, dec = _batches(cfg, jax.random.PRNGKey(1))
    lt, _, _ = zoo.forward(params, cfg, full, mode="train")
    _, cache, _ = zoo.forward(params, cfg, pre, mode="prefill")
    if not cfg.sliding_window or cfg.sliding_window >= S:
        cache = zoo.pad_cache(cache, 1)
    ld, _, _ = zoo.forward(params, cfg, dec, mode="decode", cache=cache)
    err = float(jnp.max(jnp.abs(ld[:, 0] - lt[:, -1])))
    assert err < tol, f"{cfg.name}: decode mismatch {err}"


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(list_archs()))
def test_prefill_decode_consistency(name):
    _check(_cfg(name))


@pytest.mark.slow
@pytest.mark.parametrize("window", [8, 16, 33])
def test_sliding_window_ring_buffer(window):
    _check(_cfg("llama3.2-1b", sliding_window=window))


@pytest.mark.slow
def test_mla_sliding_window():
    _check(_cfg("deepseek-v2-236b", sliding_window=8))


@pytest.mark.slow
def test_multi_step_decode_matches_teacher_forcing():
    """Decode 4 tokens sequentially; logits must match the full forward at
    each position."""
    cfg = _cfg("llama3.2-1b")
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                              cfg.vocab_size)
    lt, _, _ = zoo.forward(params, cfg, {"tokens": toks}, mode="train")
    P = S - 4
    _, cache, _ = zoo.forward(params, cfg, {"tokens": toks[:, :P]},
                              mode="prefill")
    cache = zoo.pad_cache(cache, 4)
    for t in range(4):
        ld, cache, _ = zoo.forward(
            params, cfg, {"tokens": toks[:, P + t:P + t + 1],
                          "cache_len": jnp.asarray(P + t)},
            mode="decode", cache=cache)
        err = float(jnp.max(jnp.abs(ld[:, 0] - lt[:, P + t])))
        assert err < 1e-3, f"step {t}: {err}"


def test_ssm_decode_state_carries():
    """SSM decode state must evolve (not be recreated) across steps."""
    cfg = _cfg("zamba2-2.7b")
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    _, cache, _ = zoo.forward(params, cfg, {"tokens": toks}, mode="prefill")
    cache1 = zoo.pad_cache(cache, 1)
    _, cache2, _ = zoo.forward(
        params, cfg, {"tokens": toks[:, :1], "cache_len": jnp.asarray(8)},
        mode="decode", cache=cache1)
    ssm_before = jax.tree.leaves(cache1["pattern"][0])[0]
    ssm_after = jax.tree.leaves(cache2["pattern"][0])[0]
    assert float(jnp.max(jnp.abs(ssm_before - ssm_after))) > 0
