"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real 1-CPU environment (only launch/dryrun.py may request 512 placeholder
devices, in its own process)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
