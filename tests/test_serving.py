"""The serving plane's contracts (repro/serving + launch/serve.py).

The load-bearing claims, each pinned here:

  * one compile per bucket size, never a retrace under churn;
  * within a bucket, padding and batch composition cannot move ANY
    request's output — bit for bit (same executable, row-inert rows),
    clean or faulty;
  * across bucket sizes the same request agrees to float tolerance with
    IDENTICAL decisions (different XLA executables may round the last
    ulp differently at different batch shapes), and fault masks — booleans
    — agree EXACTLY (request-id-keyed draws);
  * clean serving matches jit(scheme.predict) to the same standard, and
    served accuracy equals evaluate_accuracy;
  * the scheduler drains FIFO and completes everything before stop();

plus the request-path fix sweep that rode along: loud clamping of
--requests past the dataset, the greedy argmax folded into the jitted
decode step (one compile, no per-token device->host transfer), the
prefetcher joining its producer thread on early drop, and
runner.efficiency([]) returning 0.0.
"""
import dataclasses
import threading
import time
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import linkfault, schemes
from repro.core import topology as topology_lib
from repro.core.schemes import runner
from repro.serving import ServingEngine, batching
from tests._schemes_common import CFG, fixture_data, trajectory


def _inl():
    scheme = schemes.get("inl")
    state = trajectory("inl")["state"]
    views, labels = fixture_data()
    return scheme, state, np.asarray(views), np.asarray(labels)


def _lossy_star(erasure=0.3):
    return linkfault.with_links(
        topology_lib.star(CFG.num_clients),
        linkfault.LinkModel(erasure=erasure))


# ---------------------------------------------------------------------------
# bucket grid
# ---------------------------------------------------------------------------

def test_bucket_helpers():
    assert batching.validate_buckets([16, 1, 4, 4]) == (1, 4, 16)
    assert batching.pick_bucket(1, (1, 4, 16)) == 1
    assert batching.pick_bucket(5, (1, 4, 16)) == 16
    with pytest.raises(ValueError):
        batching.pick_bucket(17, (1, 4, 16))
    with pytest.raises(ValueError):
        batching.validate_buckets([])
    v = np.arange(2 * 3 * 5, dtype=np.float32).reshape(2, 3, 5)
    pv, pr = batching.pad_to_bucket(v, np.arange(3, dtype=np.int32), 4)
    assert pv.shape == (2, 4, 5) and pr.tolist() == [0, 1, 2, 2]
    assert np.array_equal(pv[:, 3], v[:, 2])      # pad repeats the last row


# ---------------------------------------------------------------------------
# clean serving == jitted predict, one compile per bucket
# ---------------------------------------------------------------------------

def test_clean_serving_matches_jitted_predict():
    scheme, state, views, labels = _inl()
    engine = ServingEngine(scheme, state, CFG, seed=5)
    engine.warmup()
    assert all(c == 1 for c in engine.trace_counts.values())
    with engine:
        probs, results = engine.serve(views[:, :23])
    # warmup paid every compile; serving 23 requests (bucket 64, padded)
    # must not add a single trace
    assert all(c == 1 for c in engine.trace_counts.values()), \
        engine.trace_counts
    ref = np.asarray(jax.jit(
        lambda st, vv: scheme.predict(st, vv, cfg=CFG)
    )(state, jnp.asarray(views[:, :23])))
    assert np.allclose(probs, ref, atol=2e-6, rtol=0)
    assert np.array_equal(np.argmax(probs, -1), np.argmax(ref, -1))
    assert all(r.views_fused == CFG.num_clients for r in results)
    # clean meter: delivered == offered exactly
    assert engine.meter.total_bits > 0
    assert engine.meter.delivery_ratio == 1.0


def test_padding_and_composition_bit_exact_within_bucket():
    """Two batches that land in the SAME bucket executable must give every
    shared request a bitwise identical answer, however much padding or
    however many other requests ride along — clean AND faulty (the
    per-request-id fault draws are what make the faulty half true)."""
    scheme, state, views, labels = _inl()
    for topo in (None, _lossy_star()):
        # 7 requests padded to 16 ...
        a = ServingEngine(scheme, state, CFG, topology=topo, seed=5)
        with a:
            pa, _ = a.serve(views[:, :7])
        # ... vs the same 7 (same rids 0..6) plus 6 more, padded to 16
        b = ServingEngine(scheme, state, CFG, topology=topo, seed=5)
        with b:
            pb, _ = b.serve(views[:, :13])
        assert a.trace_counts[16] == b.trace_counts[16] == 1
        assert np.array_equal(pa, pb[:7]), \
            "batch composition moved a request's output inside one bucket"


def test_cross_bucket_agreement_and_exact_masks():
    """Across bucket sizes, outputs agree to float tolerance with identical
    decisions (different-shape XLA executables may differ in the last
    ulp), and the boolean delivery masks agree EXACTLY."""
    scheme, state, views, labels = _inl()
    for topo in (None, _lossy_star()):
        outs, fused = [], []
        for split in ((7,), (1,) * 7, (3, 4)):
            engine = ServingEngine(scheme, state, CFG, topology=topo,
                                   seed=5)
            got, nv, i = [], [], 0
            with engine:
                for k in split:
                    p, rs = engine.serve(views[:, i:i + k])
                    got.append(p)
                    nv += [r.views_fused for r in rs]
                    i += k
            outs.append(np.concatenate(got))
            fused.append(nv)
        for other, nv in zip(outs[1:], fused[1:]):
            assert np.allclose(outs[0], other, atol=2e-6, rtol=0)
            assert np.array_equal(np.argmax(outs[0], -1),
                                  np.argmax(other, -1))
            assert nv == fused[0]      # masks are exact, bucket regardless


# ---------------------------------------------------------------------------
# per-request fault semantics
# ---------------------------------------------------------------------------

def test_faulty_serving_matches_request_delivery_mask_reference():
    """Served probabilities under faults == predict_batched with the
    request-id-keyed masks, computed independently of the engine."""
    scheme, state, views, labels = _inl()
    topo = _lossy_star()
    seed = 11
    engine = ServingEngine(scheme, state, CFG, topology=topo, seed=seed)
    n = 9
    with engine:
        probs, results = engine.serve(views[:, :n])

    key = jax.random.PRNGKey(seed)
    rids = jnp.arange(n, dtype=jnp.int32)

    def ref_fn(st, vv, rr):
        delivery = linkfault.request_delivery_mask(key, topo, CFG, rr)
        return scheme.predict_batched(st, vv, delivery=delivery,
                                      topology=topo, cfg=CFG), delivery
    ref, mask = jax.jit(ref_fn)(state, jnp.asarray(views[:, :n]), rids)
    # engine ran at bucket 16, the reference at batch 9 — different
    # executables, so float tolerance; the masks themselves are exact
    assert np.allclose(probs, np.asarray(ref), atol=2e-6, rtol=0)
    assert np.array_equal(np.argmax(probs, -1), np.argmax(ref, -1))
    assert [r.views_fused for r in results] == \
        np.asarray(mask).sum(axis=0).tolist()
    # the faulty meter delivered strictly less than it offered
    assert 0.0 < engine.meter.delivery_ratio < 1.0


def test_request_mask_independent_of_batch_composition():
    key = jax.random.PRNGKey(3)
    topo = _lossy_star()
    full = np.asarray(linkfault.request_delivery_mask(
        key, topo, CFG, jnp.arange(16, dtype=jnp.int32)))
    alone = np.asarray(linkfault.request_delivery_mask(
        key, topo, CFG, jnp.asarray([11], jnp.int32)))
    assert np.array_equal(full[:, 11], alone[:, 0])
    # and requests actually draw DIFFERENT faults from one another
    assert not all(np.array_equal(full[:, i], full[:, 0])
                   for i in range(16))


def test_all_ones_mask_is_identity():
    """A modelled-but-perfect link keeps the faulty path bit-identical to
    the clean engine (partial_fuse's all-ones contract, served end-to-end)."""
    scheme, state, views, labels = _inl()
    perfect = linkfault.with_links(topology_lib.star(CFG.num_clients),
                                   linkfault.LinkModel(erasure=0.0))
    e1 = ServingEngine(scheme, state, CFG, topology=perfect, seed=5)
    assert e1.faulty
    e2 = ServingEngine(scheme, state, CFG, seed=5)
    assert not e2.faulty
    with e1:
        p1, _ = e1.serve(views[:, :6])
    with e2:
        p2, _ = e2.serve(views[:, :6])
    assert np.array_equal(p1, p2)


# ---------------------------------------------------------------------------
# scheduler behaviour
# ---------------------------------------------------------------------------

def test_queue_drain_fifo_under_seeded_arrival_stream():
    """Requests submitted in a seeded arrival stream complete in FIFO
    batches, every future resolves by stop(), and each answer is the right
    request's answer."""
    scheme, state, views, labels = _inl()
    rng = np.random.default_rng(0)
    n = 20
    engine = ServingEngine(scheme, state, CFG, seed=5)
    engine.warmup()
    futs = []
    with engine:
        for i in range(n):
            rid, fut = engine.submit(views[:, i])
            assert rid == i
            futs.append(fut)
            if rng.random() < 0.3:
                time.sleep(float(rng.exponential(0.002)))
    # context exit == stop(): drains everything already queued
    assert all(f.done() for f in futs)
    assert engine.pending() == 0 and engine.stats.completed == n
    results = [f.result(timeout=1.0) for f in futs]
    assert [r.rid for r in results] == list(range(n))
    # completion stamps never go backwards in submit order (FIFO batches)
    t = [r.t_done for r in results]
    assert all(a <= b + 1e-9 for a, b in zip(t, t[1:]))
    ref = np.asarray(jax.jit(
        lambda st, vv: scheme.predict(st, vv, cfg=CFG)
    )(state, jnp.asarray(views[:, :n])))
    got = np.stack([r.probs for r in results])
    assert np.allclose(got, ref, atol=2e-6, rtol=0)
    assert np.array_equal(np.argmax(got, -1), np.argmax(ref, -1))


def test_submit_rejects_wrong_view_count():
    scheme, state, views, labels = _inl()
    engine = ServingEngine(scheme, state, CFG, seed=0)
    with pytest.raises(ValueError, match="views"):
        engine.submit(views[:3, 0])


# ---------------------------------------------------------------------------
# satellite: scheduler-thread failure propagation
# ---------------------------------------------------------------------------

def test_scheduler_exception_fails_pending_then_poisons_engine():
    """A scheduler-thread death must (1) fail every pending Future with the
    REAL exception — no stranded blocked waiters — and (2) re-raise on the
    next submit and on stop, so the failure cannot pass silently."""
    scheme, state, views, labels = _inl()
    engine = ServingEngine(scheme, state, CFG, seed=0)
    boom = ValueError("injected scheduler failure")

    def bad_execute(batch):
        raise boom
    engine._execute_any = bad_execute

    engine.start()
    _, fut = engine.submit(views[:, 0])
    assert fut.exception(timeout=5.0) is boom
    assert engine.pending() == 0
    with pytest.raises(RuntimeError, match="scheduler failed") as ei:
        engine.submit(views[:, 1])
    assert ei.value.__cause__ is boom
    with pytest.raises(RuntimeError, match="scheduler failed"):
        engine.stop()
    # the poisoned engine keeps refusing: a later stop() still surfaces
    # the same root cause
    with pytest.raises(RuntimeError) as ei:
        engine.stop()
    assert ei.value.__cause__ is boom


def test_scheduler_exception_does_not_mask_body_exception():
    """When the `with engine:` body raises, __exit__ must let THAT
    exception through even if the scheduler also died."""
    scheme, state, views, labels = _inl()
    engine = ServingEngine(scheme, state, CFG, seed=0)
    engine._execute_any = lambda batch: (_ for _ in ()).throw(
        RuntimeError("scheduler died too"))
    with pytest.raises(KeyError, match="body wins"):
        with engine:
            _, fut = engine.submit(views[:, 0])
            fut.exception(timeout=5.0)            # scheduler is dead now
            raise KeyError("body wins")


def test_inline_step_surfaces_scheduler_error():
    scheme, state, views, labels = _inl()
    engine = ServingEngine(scheme, state, CFG, seed=0)
    engine._error = ValueError("poisoned")
    with pytest.raises(RuntimeError, match="scheduler failed"):
        engine.step()


# ---------------------------------------------------------------------------
# speculative fusion over a transport
# ---------------------------------------------------------------------------

def _late_star(latency_ms=50.0):
    # deterministic stragglers: every link delivers (erasure 0, jitter 0)
    # but 50 ms of latency blows a 10 ms fusion deadline on every view
    return linkfault.with_links(
        topology_lib.star(CFG.num_clients),
        linkfault.LinkModel(erasure=0.0, latency_ms=latency_ms,
                            jitter_ms=0.0))


def test_speculative_requires_transport():
    scheme, state, views, labels = _inl()
    with pytest.raises(ValueError, match="transport"):
        ServingEngine(scheme, state, CFG, seed=0, speculative=True)


def test_speculative_fusion_patches_stragglers():
    """All J views delivered but LATE: without speculation the fusion at
    the deadline answers from nothing; with it, the request is answered by
    the next bucket's PATCHED fusion carrying every recovered view."""
    from repro.transport import NO_RETRY, NetworkTransport
    scheme, state, views, labels = _inl()
    J, n = CFG.num_clients, 5
    topo = _late_star()

    tr = NetworkTransport(topo, CFG, seed=0, policy=NO_RETRY, breaker=None)
    plain = ServingEngine(scheme, state, CFG, topology=topo, transport=tr,
                          deadline_ms=10.0, seed=0)
    _, res = plain.serve(views[:, :n])
    tr.close()
    assert [r.views_fused for r in res] == [0] * n
    assert all(r.served_by == "first" for r in res)
    assert plain.stats.patched == 0

    tr = NetworkTransport(topo, CFG, seed=0, policy=NO_RETRY, breaker=None)
    spec = ServingEngine(scheme, state, CFG, topology=topo, transport=tr,
                         deadline_ms=10.0, seed=0, speculative=True)
    probs, res = spec.serve(views[:, :n])
    snap = tr.snapshot()
    tr.close()
    assert all(r.served_by == "patched" for r in res)
    assert [r.views_fused for r in res] == [J] * n
    assert [r.views_recovered for r in res] == [J] * n
    assert spec.stats.patched == n and spec.stats.views_recovered == n * J
    # the patched fusion consumed every view -> full delivered credit
    assert snap["delivery_ratio"] == 1.0
    # an all-views patched fusion decides like the clean engine
    clean = ServingEngine(scheme, state, CFG, seed=0)
    cp, _ = clean.serve(views[:, :n])
    assert np.allclose(probs, cp, atol=2e-6, rtol=0)
    assert np.array_equal(np.argmax(probs, -1), np.argmax(cp, -1))


def test_transport_serving_credits_only_consumed_views():
    """Non-speculative serving under a hard outage: the at-deadline fusion
    consumed nothing, so the delivered ledger stays empty while offered
    accrues per attempt."""
    from repro.chaos import ChaosSchedule
    from repro.transport import NO_RETRY, NetworkTransport
    scheme, state, views, labels = _inl()
    topo = topology_lib.resolve(None, CFG)
    chaos = ChaosSchedule()
    for e in topo.edges:
        chaos = chaos.down_edge(e.key, 0, 64)
    tr = NetworkTransport(topo, CFG, seed=0, policy=NO_RETRY, breaker=None,
                          chaos=chaos)
    engine = ServingEngine(scheme, state, CFG, transport=tr, seed=0)
    _, res = engine.serve(views[:, :3])
    assert [r.views_fused for r in res] == [0, 0, 0]
    assert tr.meter.total_bits > 0 and tr.meter.delivered_bits == 0.0
    tr.close()


# ---------------------------------------------------------------------------
# satellite: loadgen percentile / degenerate-sample guards
# ---------------------------------------------------------------------------

def test_percentile_guards_degenerate_samples():
    from repro.serving.loadgen import percentile_ms
    assert percentile_ms([], 50) == 0.0           # not a ValueError
    assert percentile_ms([], 99) == 0.0
    assert percentile_ms([7.25], 50) == 7.25      # one sample IS every pct
    assert percentile_ms([7.25], 99) == 7.25
    lats = [1.0, 2.0, 3.0, 4.0]
    assert percentile_ms(lats, 50) == pytest.approx(np.percentile(lats, 50))


def test_run_poisson_zero_and_one_request_nan_free():
    from repro.serving.loadgen import run_poisson
    scheme, state, views, labels = _inl()
    engine = ServingEngine(scheme, state, CFG, seed=0, buckets=(1,))
    with engine:
        empty = run_poisson(engine, views[:, :4], rate_rps=100.0,
                            num_requests=0)
        one = run_poisson(engine, views[:, :4], rate_rps=100.0,
                          num_requests=1)
    for summary, served in ((empty, 0), (one, 1)):
        assert summary["served"] == served
        for k, v in summary.items():
            assert np.isfinite(v), (k, v)
    assert empty["p50_ms"] == 0.0 and empty["mean_views_fused"] == 0.0
    assert one["p99_ms"] == one["p50_ms"] > 0.0
    assert one["mean_views_fused"] == CFG.num_clients


# ---------------------------------------------------------------------------
# satellite: --requests clamp
# ---------------------------------------------------------------------------

def test_clamp_requests_warns_and_clamps():
    from repro.launch.serve import clamp_requests
    assert clamp_requests(8, 100) == 8
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert clamp_requests(1000, 640) == 640
    assert any(issubclass(x.category, RuntimeWarning)
               and "exceeds" in str(x.message) for x in w)
    with pytest.raises(ValueError, match="strict"):
        clamp_requests(1000, 640, strict=True)


# ---------------------------------------------------------------------------
# satellite: greedy decode folded into the jitted step
# ---------------------------------------------------------------------------

def test_serve_batch_one_compile_no_device_to_host_transfer():
    from repro.configs import get_smoke_config
    from repro.launch.serve import serve_batch
    from repro.models import zoo

    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"),
                              dtype="float32")
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    trace_log = []
    # the decode loop must neither retrace per token nor block on a
    # device->host transfer of in-flight logits (the old eager greedy())
    with jax.transfer_guard_device_to_host("disallow"):
        gen = serve_batch(cfg, params, prompts, 5, trace_log=trace_log)
        gen.block_until_ready()
    assert len(trace_log) == 1, f"decode step traced {len(trace_log)}x"
    gen = np.asarray(gen)
    assert gen.shape == (2, 5) and gen.dtype == np.int32
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# satellite: prefetch producer thread exits on early drop
# ---------------------------------------------------------------------------

def test_prefetch_producer_thread_exits_on_early_drop():
    from repro.data.prefetch import prefetch_to_device

    def slow_src():
        for i in range(100):
            yield np.full((4,), i, np.float32)

    before = {t.ident for t in threading.enumerate()}
    it = prefetch_to_device(slow_src(), size=2)
    first = next(it)
    assert float(np.asarray(first)[0]) == 0.0
    it.close()                                 # early drop mid-stream
    leftover = [t for t in threading.enumerate()
                if t.ident not in before and t.name == "prefetch_to_device"]
    for t in leftover:
        t.join(timeout=2.0)
    assert not any(t.is_alive() for t in leftover), \
        "producer thread still alive after generator close"


# ---------------------------------------------------------------------------
# satellite: empty-curve efficiency + zero-round runs
# ---------------------------------------------------------------------------

def test_efficiency_empty_curve_is_zero():
    assert runner.efficiency([]) == 0.0


def test_run_scheme_zero_epochs_and_zero_rounds():
    views, labels = fixture_data()
    # epochs=0: no training, empty curve, efficiency 0.0 — not IndexError
    curve = runner.run_scheme("inl", views, labels, CFG, epochs=0,
                              batch_size=32)
    assert curve == []
    assert runner.efficiency(curve) == 0.0
    # a batch size so large that rounds-per-epoch floors to 0: the epoch
    # trains nothing but still evaluates — no crash, a well-formed point
    curve = runner.run_scheme("inl", views, labels, CFG, epochs=1,
                              batch_size=10_000)
    assert len(curve) == 1 and 0.0 <= curve[0].accuracy <= 1.0
    assert runner.efficiency(curve) >= 0.0
