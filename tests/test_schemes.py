"""Integration: the paper's three schemes (INL / FL / SL) on the synthetic
multi-view experiment — training works, metrics improve, and the measured
bandwidth matches the closed-form §III-C accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.paper_inl import SMOKE as CFG
from repro.core import bandwidth, fl, inl, paper_model, sl
from repro.data import multiview


@pytest.fixture(scope="module")
def data():
    imgs, labels = multiview.make_base_dataset(256, seed=0)
    views = multiview.make_views(imgs, CFG.noise_stds)
    return views, labels


@pytest.mark.slow
def test_inl_trains_above_chance(data):
    views, labels = data
    params, state = inl.init(CFG, jax.random.PRNGKey(0))
    opt = optim.adam(2e-3)
    opt_state = opt.init(params)
    step = inl.make_train_step(CFG, opt)
    rng = jax.random.PRNGKey(1)
    losses_seen = []
    for ep in range(4):
        for v, l in multiview.multiview_batches(views, labels, 64, seed=ep):
            rng, sub = jax.random.split(rng)
            params, state, opt_state, m = step(
                params, state, opt_state, jnp.asarray(v), jnp.asarray(l), sub)
        losses_seen.append(float(m["loss"]))
    acc = float(inl.evaluate(params, state, jnp.asarray(views),
                             jnp.asarray(labels)))
    assert acc > 0.3, f"INL train acc {acc} (chance 0.1)"
    assert losses_seen[-1] < losses_seen[0]


@pytest.mark.slow
def test_sl_trains(data):
    views, labels = data
    (client, server), state = sl.init(CFG, jax.random.PRNGKey(0))
    oc, os_ = optim.adam(2e-3), optim.adam(2e-3)
    oc_s, os_s = oc.init(client), os_.init(server)
    step = sl.make_train_step(oc, os_)
    rng = jax.random.PRNGKey(1)
    first = last = None
    for ep in range(3):
        for v, l in multiview.multiview_batches(views, labels, 64, seed=ep):
            rng, sub = jax.random.split(rng)
            client, server, state, oc_s, os_s, m = step(
                client, server, state, oc_s, os_s, jnp.asarray(v),
                jnp.asarray(l), sub)
            if first is None:
                first = float(m["loss"])
    last = float(m["loss"])
    assert last < first


@pytest.mark.slow
def test_fl_round_averages_weights(data):
    views, labels = data
    params, state = fl.init(CFG, jax.random.PRNGKey(0))
    opt = optim.adam(1e-3)
    opt_state = jax.vmap(opt.init)(params)
    round_fn = fl.make_round(CFG, opt, local_steps=1)
    J, B = CFG.num_clients, 32
    vs = np.stack([
        np.broadcast_to(views[j][:B][None, None],
                        (1, J, B) + views.shape[2:]).copy()
        for j in range(J)])
    ls = np.stack([labels[:B].reshape(1, B) for _ in range(J)])
    rngs = jax.random.split(jax.random.PRNGKey(2), J)
    new_params, _, _, m = round_fn(params, state, opt_state,
                                   jnp.asarray(vs), jnp.asarray(ls), rngs)
    # after aggregation every client holds identical weights
    for leaf in jax.tree.leaves(new_params):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[-1]),
                                   atol=1e-6)


def test_bandwidth_table1_reproduces_paper():
    for (net, q), want in bandwidth.PAPER_TABLE1.items():
        got = bandwidth.table1(q, net)
        for scheme, val in want.items():
            assert abs(got[scheme] - val) / val < 0.01, (net, q, scheme)


def test_scheme_bandwidth_ordering():
    """INL << SL < FL for the paper's constants — the headline claim."""
    t = bandwidth.table1(50_000, "vgg16")
    assert t["in_network"] < t["split"] < t["federated"]


@pytest.mark.slow
def test_measured_inl_bits_match_formula(data):
    views, labels = data
    params, state = inl.init(CFG, jax.random.PRNGKey(0))
    loss, (m, _) = inl.loss_fn(params, state, jnp.asarray(views[:, :64]),
                               jnp.asarray(labels[:64]),
                               jax.random.PRNGKey(3), CFG)
    p_total = CFG.num_clients * CFG.d_bottleneck
    want = 2 * 64 * p_total * CFG.link_bits
    assert float(m["bits_sent"]) == want


def test_fl_param_count_vs_formula():
    params, _ = paper_model.fl_model_init(jax.random.PRNGKey(0), CFG)
    n = sum(x.size for x in jax.tree.leaves(params))
    assert n == paper_model.fl_param_count(CFG)
    assert fl.round_bits(CFG, n) == 2 * n * CFG.num_clients * 32
