"""The transport layer's contracts (repro/transport + repro/chaos).

  * retry policy: capped exponential backoff, jitter only shortens,
    attempt 0 never waits;
  * circuit breaker: closed -> open after K consecutive failures ->
    half-open probe after the cooldown -> closes on success / re-opens on
    failure; short-circuited attempts are counted;
  * channels: loopback and socket both round-trip a fused-cutlayer
    fragment BIT for bit behind the same interface;
  * network transport: outcomes are pure functions of
    (seed, domain, tick, edge, attempt) — same seed, same story — and the
    ledger convention holds (every attempt re-offers the full charge,
    short-circuits offer nothing, delivered accrues per surviving payload);
  * chaos schedule: pure window queries, the seeded script replays.
"""
import numpy as np
import pytest

from repro.chaos import ChaosEvent, ChaosSchedule
from repro.core import topology as topology_lib
from repro.transport import (DEFAULT_RETRY, NO_RETRY, CircuitBreaker,
                             LoopbackChannel, NetworkTransport, NoBreaker,
                             RetryPolicy, SocketChannel, decode_fragment,
                             encode_fragment, make_channel)
from tests._schemes_common import CFG


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------

def test_backoff_grows_then_caps():
    p = RetryPolicy(max_attempts=6, base_backoff_ms=1.0, backoff_mult=2.0,
                    max_backoff_ms=4.0, jitter=0.0)
    assert p.backoff_ms(0, 0.5) == 0.0          # first attempt never waits
    assert p.backoff_ms(1, 0.5) == 1.0
    assert p.backoff_ms(2, 0.5) == 2.0
    assert p.backoff_ms(3, 0.5) == 4.0
    assert p.backoff_ms(5, 0.5) == 4.0          # capped

def test_jitter_only_shortens():
    p = RetryPolicy(max_attempts=3, base_backoff_ms=8.0, jitter=0.5)
    full = p.backoff_ms(1, 0.0)
    assert p.backoff_ms(1, 1.0) == pytest.approx(full * 0.5)
    assert 0.0 < p.backoff_ms(1, 0.7) < full

def test_timeout_marks_attempt_failed():
    p = RetryPolicy(max_attempts=2, timeout_ms=10.0)
    assert p.attempt_failed(11.0) and not p.attempt_failed(9.0)
    assert not NO_RETRY.attempt_failed(1e9)     # no timeout -> never late


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

def test_breaker_state_machine():
    b = CircuitBreaker(failure_threshold=3, cooldown=4)
    assert b.state == "closed"
    for t in range(3):
        assert b.allow(t)
        b.record_failure(t)
    assert b.state == "open" and b.opens == 1
    assert not b.allow(3)                       # short-circuit inside cooldown
    assert b.short_circuits == 1
    assert b.allow(2 + 4)                       # cooldown elapsed: probe
    assert b.state == "half_open" and b.probes == 1
    b.record_success()
    assert b.state == "closed"

def test_breaker_reopens_on_failed_probe():
    b = CircuitBreaker(failure_threshold=1, cooldown=2)
    b.allow(0)
    b.record_failure(0)
    assert b.state == "open"
    assert b.allow(2)                           # probe
    b.record_failure(2)
    assert b.state == "open" and b.opens == 2

def test_no_breaker_always_allows():
    b = NoBreaker()
    assert b.state == "disabled"
    assert b.allow(0) and b.allow(10**9)
    b.record_failure(0)
    b.record_success()
    assert b.allow(1)


# ---------------------------------------------------------------------------
# channels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["loopback", "socket"])
def test_channel_roundtrips_fragment_bit_exact(kind):
    chan = make_channel(kind)
    try:
        arr = np.random.default_rng(0).standard_normal((7, 8)).astype(
            np.float32)
        chan.send(encode_fragment(42, 3, arr))
        rid, j, got = decode_fragment(chan.recv())
        assert (rid, j) == (42, 3)
        assert got.dtype == arr.dtype and np.array_equal(got, arr)
    finally:
        chan.close()

def test_channel_kinds():
    assert isinstance(make_channel("loopback"), LoopbackChannel)
    assert isinstance(make_channel("socket"), SocketChannel)
    with pytest.raises(ValueError):
        make_channel("carrier-pigeon")

def test_loopback_recv_timeout_returns_none():
    chan = LoopbackChannel()
    assert chan.recv(timeout=0.01) is None


# ---------------------------------------------------------------------------
# network transport
# ---------------------------------------------------------------------------

def _lossy_topo(erasure=0.5):
    from repro.core import linkfault
    return topology_lib.resolve(
        linkfault.with_links(topology_lib.star(CFG.num_clients),
                             linkfault.LinkModel(erasure=erasure)), CFG)

def test_outcomes_deterministic_per_seed():
    masks = []
    for _ in range(2):
        tr = NetworkTransport(_lossy_topo(), CFG, seed=3,
                              policy=DEFAULT_RETRY)
        masks.append(np.stack([tr.round_outcome(t, 32).mask
                               for t in range(8)]))
        tr.close()
    assert np.array_equal(masks[0], masks[1])
    tr = NetworkTransport(_lossy_topo(), CFG, seed=4, policy=DEFAULT_RETRY)
    other = np.stack([tr.round_outcome(t, 32).mask for t in range(8)])
    tr.close()
    assert not np.array_equal(masks[0], other)  # different seed, new story

def _all_edges_down(topo, ticks=64):
    s = ChaosSchedule()
    for e in topo.edges:
        s = s.down_edge(e.key, 0, ticks)
    return s

def test_retries_reoffer_full_charge():
    # every edge chaos-down: every attempt fails -> offered =
    # max_attempts * charge, delivered = 0
    topo = topology_lib.resolve(None, CFG)
    tr = NetworkTransport(topo, CFG, seed=0,
                          policy=RetryPolicy(max_attempts=3), breaker=None,
                          chaos=_all_edges_down(topo))
    charges = {e.key: (100.0, 10.0) for e in tr.topo.edges}
    rep = tr.round_outcome(0, 32, charges=charges)
    assert not rep.mask.any()
    assert all(a == 3 for a in rep.attempts.values())
    assert tr.meter.total_bits == 3 * 100.0 * len(tr.topo.edges)
    assert tr.meter.delivered_bits == 0.0
    tr.close()

def test_breaker_short_circuits_offer_nothing():
    topo = topology_lib.resolve(None, CFG)
    tr = NetworkTransport(
        topo, CFG, seed=0, policy=NO_RETRY,
        breaker=lambda: CircuitBreaker(failure_threshold=1, cooldown=100),
        chaos=_all_edges_down(topo))
    charges = {e.key: (100.0, 10.0) for e in tr.topo.edges}
    tr.round_outcome(0, 32, charges=charges)    # every breaker opens
    before = tr.meter.total_bits
    rep = tr.round_outcome(1, 32, charges=charges)
    assert tr.meter.total_bits == before        # short-circuits: no offer
    assert all(a == 0 for a in rep.attempts.values())
    assert all(s == "open" for s in tr.breaker_states().values())
    tr.close()

def test_charge_false_replays_without_ledger():
    tr = NetworkTransport(_lossy_topo(), CFG, seed=3, policy=DEFAULT_RETRY)
    live = [tr.round_outcome(t, 32).mask for t in range(4)]
    spent = tr.meter.total_bits
    tr.close()
    tr2 = NetworkTransport(_lossy_topo(), CFG, seed=3, policy=DEFAULT_RETRY)
    replay = [tr2.round_outcome(t, 32, charge=False).mask for t in range(4)]
    assert np.array_equal(np.stack(live), np.stack(replay))
    assert tr2.meter.total_bits == 0.0 and spent > 0.0
    tr2.close()

def test_dead_node_fails_its_route_and_request_frames_arrive():
    chaos = ChaosSchedule().kill_node("m1", at=0, duration=2)
    topo = topology_lib.resolve(None, CFG)
    tr = NetworkTransport(topo, CFG, seed=0, chaos=chaos)
    views = np.random.default_rng(0).standard_normal(
        (CFG.num_clients, 16, 16, 3)).astype(np.float32)
    rep = tr.send_request(0, views)
    assert not rep.eventual[1] and rep.eventual[[0, 2, 3, 4]].all()
    assert rep.received[1] is None
    for j in (0, 2, 3, 4):
        assert np.array_equal(rep.received[j], views[j])  # bit-exact ride
    rep2 = tr.send_request(2, views)            # node rejoined
    assert rep2.eventual.all()
    tr.close()


# ---------------------------------------------------------------------------
# chaos schedule
# ---------------------------------------------------------------------------

def test_chaos_windows_and_flap():
    s = (ChaosSchedule()
         .down_edge("e", 2, 3)
         .flap_edge("f", start=0, stop=8, period=4, duty=2)
         .slow_edge("g", 1, 5, factor=10.0)
         .kill_node("n", at=3))
    assert [s.edge_down("e", t) for t in range(6)] == \
        [False, False, True, True, True, False]
    assert [s.edge_down("f", t) for t in range(9)] == \
        [True, True, False, False, True, True, False, False, False]
    assert s.slow_factor("g", 2) == 10.0 and s.slow_factor("g", 5) == 1.0
    assert not s.node_dead("n", 2) and s.node_dead("n", 10**6)

def test_chaos_event_validation():
    with pytest.raises(ValueError):
        ChaosEvent("tsunami", "e")
    with pytest.raises(ValueError):
        ChaosEvent("edge_down", "e", start=5, stop=5)
    with pytest.raises(ValueError):
        ChaosEvent("edge_flap", "e", period=2, duty=3)

def test_seeded_schedule_replays():
    kw = dict(edge_keys=["a", "b"], nodes=["n"], ticks=32)
    assert ChaosSchedule.seeded(7, **kw) == ChaosSchedule.seeded(7, **kw)
    assert ChaosSchedule.seeded(7, **kw) != ChaosSchedule.seeded(8, **kw)


# ---------------------------------------------------------------------------
# socket channel hardening: torn frames, clean EOF, TCP handshake
# ---------------------------------------------------------------------------

def _raw_pair():
    """A SocketChannel wrapping one end of a raw socketpair, with the OTHER
    end exposed raw — so tests can tear frames mid-byte."""
    import socket as socket_lib
    a, b = socket_lib.socketpair()
    return SocketChannel(sock=a), b

def test_peer_close_mid_header_raises_typed_error():
    from repro.transport import ChannelError
    chan, raw = _raw_pair()
    raw.sendall(b"\x07\x00")                     # 2 of the 4 prefix bytes
    raw.close()
    with pytest.raises(ChannelError, match="mid-header"):
        chan.recv(1.0)
    chan.close()

def test_peer_close_mid_frame_raises_typed_error():
    import struct
    from repro.transport import ChannelError
    chan, raw = _raw_pair()
    raw.sendall(struct.pack("<I", 100) + b"only a few body bytes")
    raw.close()
    with pytest.raises(ChannelError, match="mid-frame"):
        chan.recv(1.0)
    chan.close()

def test_clean_close_at_boundary_is_eof_not_error():
    import struct
    chan, raw = _raw_pair()
    raw.sendall(struct.pack("<I", 3) + b"abc")   # one whole frame, then gone
    raw.close()
    assert chan.recv(1.0) == b"abc"
    assert chan.recv(1.0) is None and chan.eof   # gone, not "nothing yet"
    chan.close()

def test_timeout_mid_prefix_keeps_partial_bytes_buffered():
    import struct
    chan, raw = _raw_pair()
    frame = struct.pack("<I", 4) + b"wxyz"
    raw.sendall(frame[:2])                       # half a length prefix
    assert chan.recv(0.05) is None               # timeout, NOT an error
    assert not chan.eof
    raw.sendall(frame[2:])
    assert chan.recv(1.0) == b"wxyz"             # nothing was lost
    raw.close()
    chan.close()

def test_send_on_closed_channel_raises():
    from repro.transport import ChannelError
    chan = SocketChannel()
    chan.close()
    with pytest.raises(ChannelError):
        chan.send(b"x")
    assert chan.recv(0.01) is None               # recv degrades quietly

def test_close_idempotent_and_safe_under_concurrency():
    import threading
    chan, raw = _raw_pair()
    done = threading.Event()
    def blocked_recv():
        try:
            chan.recv(5.0)                       # close() must unblock this
        except Exception:
            pass
        done.set()
    t = threading.Thread(target=blocked_recv)
    t.start()
    threads = [threading.Thread(target=chan.close) for _ in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    chan.close()                                 # and once more, for luck
    assert done.wait(5.0)
    t.join()
    raw.close()

def test_tcp_handshake_identifies_both_peers():
    import threading
    from repro.transport import TcpListener
    listener = TcpListener(name="fuse")
    server_chan = []
    t = threading.Thread(
        target=lambda: server_chan.append(listener.accept(timeout=5.0)))
    t.start()
    client = SocketChannel.connect(listener.host, listener.port,
                                   name="m0", expect_peer="fuse")
    t.join()
    server = server_chan[0]
    try:
        assert client.peer == "fuse" and server.peer == "m0"
        arr = np.random.default_rng(1).standard_normal((5, 6)).astype(
            np.float32)
        client.send(encode_fragment(7, 2, arr))
        rid, j, got = decode_fragment(server.recv(5.0))
        assert (rid, j) == (7, 2) and np.array_equal(got, arr)
        server.send(b"ack")
        assert client.recv(5.0) == b"ack"        # full duplex
    finally:
        client.close()
        server.close()
        listener.close()

def test_wrong_peer_name_is_fatal_handshake_error():
    import threading
    from repro.transport import HandshakeError, TcpListener
    listener = TcpListener(name="impostor")
    t = threading.Thread(target=lambda: listener.accept(timeout=5.0))
    t.start()
    with pytest.raises(HandshakeError) as exc:
        SocketChannel.connect(listener.host, listener.port,
                              name="m0", expect_peer="fuse")
    assert exc.value.fatal                       # reconnecting cannot fix it
    t.join()
    listener.close()

def test_version_mismatch_is_fatal_and_skips_the_retry_loop():
    import socket as socket_lib
    import struct
    import threading
    import time
    from repro.transport import HandshakeError
    from repro.transport.channel import _HELLO_MAGIC
    srv = socket_lib.socket(socket_lib.AF_INET, socket_lib.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]
    def bad_server():
        conn, _ = srv.accept()
        conn.recv(4096)                          # swallow the client hello
        body = struct.pack("<IHH", _HELLO_MAGIC, 999, 1) + b"x"
        conn.sendall(struct.pack("<I", len(body)) + body)
        time.sleep(0.2)
        conn.close()
    t = threading.Thread(target=bad_server)
    t.start()
    t0 = time.monotonic()
    with pytest.raises(HandshakeError, match="version") as exc:
        SocketChannel.connect("127.0.0.1", port, name="m0",
                              attempts=5, backoff_s=1.0)
    assert exc.value.fatal
    assert time.monotonic() - t0 < 1.0           # no 5-attempt backoff walk
    t.join()
    srv.close()

def test_bounded_reconnect_gives_up_with_channel_error():
    import socket as socket_lib
    import time
    from repro.transport import ChannelError
    probe = socket_lib.socket(socket_lib.AF_INET, socket_lib.SOCK_STREAM)
    probe.bind(("127.0.0.1", 0))
    dead_port = probe.getsockname()[1]
    probe.close()                                # nobody listens here now
    t0 = time.monotonic()
    with pytest.raises(ChannelError, match="could not connect"):
        SocketChannel.connect("127.0.0.1", dead_port, name="m0",
                              attempts=3, backoff_s=0.01, timeout=0.5)
    assert time.monotonic() - t0 < 5.0           # bounded, not forever
