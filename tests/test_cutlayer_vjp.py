"""The fused cut-layer megakernel's hand-written VJP vs ground truth.

Ground truth is plain `jax.grad` through `kernels/ref.cutlayer_ref` — the
unfused 3-pass formulation with `stop_gradient` straight-through quantizer
semantics.  The custom VJP (kernels/inl_bottleneck.py) must reproduce it:
the decoder-cotangent chunk delta[j] passed straight through the quantizer,
plus the local rate gradient (paper eq. 10), for both rate estimators,
across dtypes and odd (non-block-multiple) row counts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.kernels import ops, ref
from repro.kernels.inl_bottleneck import cutlayer_fused

GRAD_TOL = {jnp.float32: 1e-5, jnp.bfloat16: 5e-2}
FWD_TOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


def _data(T, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    mu = jax.random.normal(ks[0], (T, d), dtype)
    lv = (jax.random.normal(ks[1], (T, d)) * 0.4).astype(dtype)
    eps = jax.random.normal(ks[2], (T, d), dtype)
    cu = jax.random.normal(ks[3], (T, d))        # decoder cotangent delta[j]
    cr = jax.random.normal(ks[4], (T,))          # rate cotangent
    return mu, lv, eps, cu, cr


def _scalar(fn, cu, cr):
    def f(mu, lv, eps):
        u, rate = fn(mu, lv, eps)
        return (u.astype(jnp.float32) * cu).sum() + (rate * cr).sum()
    return f


@pytest.mark.parametrize("dtype", [
    jnp.float32,
    pytest.param(jnp.bfloat16, marks=pytest.mark.slow),  # fp32 is the 1e-5
])                                                       # tier-1 contract
@pytest.mark.parametrize("rate", ["sample", "analytic"])
@pytest.mark.parametrize("bits", [32, 8, 4])
@pytest.mark.parametrize("T", [257, 1000])          # odd / non-block rows
def test_custom_vjp_matches_ad_reference(T, bits, rate, dtype):
    d = 32
    mu, lv, eps, cu, cr = _data(T, d, dtype)
    fused = _scalar(lambda m, l, e: ops.cutlayer(
        m, l, e, link_bits=bits, rate_estimator=rate, backend="reference"),
        cu, cr)
    oracle = _scalar(lambda m, l, e: ref.cutlayer_ref(
        m, l, e, link_bits=bits, rate_estimator=rate), cu, cr)
    g_fused = jax.grad(fused, argnums=(0, 1, 2))(mu, lv, eps)
    g_ref = jax.grad(oracle, argnums=(0, 1, 2))(mu, lv, eps)
    tol = GRAD_TOL[dtype]
    for name, a, b in zip(("dmu", "dlogvar", "deps"), g_fused, g_ref):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=tol, rtol=tol, err_msg=f"{name} bits={bits} rate={rate}")


@pytest.mark.kernel_interpret
@pytest.mark.parametrize("rate", ["sample", "analytic"])
def test_pallas_vjp_matches_reference_vjp(rate):
    """Interpret-mode Pallas backward kernel == the jnp reference backward
    under the same custom_vjp wrapper (odd rows exercise the padding)."""
    T, d, bits = 97, 16, 6
    mu, lv, eps, cu, cr = _data(T, d, jnp.float32, seed=1)
    f_pal = _scalar(lambda m, l, e: cutlayer_fused(
        m, l, e, link_bits=bits, rate_estimator=rate, impl="pallas",
        block_t=64), cu, cr)
    f_ref = _scalar(lambda m, l, e: cutlayer_fused(
        m, l, e, link_bits=bits, rate_estimator=rate, impl="reference"),
        cu, cr)
    vp, gp = jax.value_and_grad(f_pal, argnums=(0, 1, 2))(mu, lv, eps)
    vr, gr = jax.value_and_grad(f_ref, argnums=(0, 1, 2))(mu, lv, eps)
    np.testing.assert_allclose(float(vp), float(vr), rtol=1e-5)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_client_axis_folds_into_rows():
    """(J, B, d) input == per-node calls stacked: one launch for all J."""
    J, B, d = 3, 40, 24
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    mu = jax.random.normal(ks[0], (J, B, d))
    lv = jax.random.normal(ks[1], (J, B, d)) * 0.3
    eps = jax.random.normal(ks[2], (J, B, d))
    u, rate = ops.cutlayer(mu, lv, eps, link_bits=8, backend="reference")
    assert u.shape == (J, B, d) and rate.shape == (J, B)
    for j in range(J):
        uj, rj = ops.cutlayer(mu[j], lv[j], eps[j], link_bits=8,
                              backend="reference")
        np.testing.assert_allclose(np.asarray(u[j]), np.asarray(uj),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(rate[j]), np.asarray(rj),
                                   atol=1e-4, rtol=1e-5)


def test_fused_rate_matches_bottleneck_estimators():
    """The kernel's rate == core/bottleneck's sampled / analytic rates."""
    from repro.core import bottleneck
    T, d = 64, 16
    mu, lv, eps, _, _ = _data(T, d, jnp.float32, seed=3)
    u, r_s = ops.cutlayer(mu, lv, eps, link_bits=32,
                          rate_estimator="sample", backend="reference")
    want = bottleneck.rate_sampled(u, mu, lv)
    np.testing.assert_allclose(np.asarray(r_s), np.asarray(want),
                               atol=1e-4, rtol=1e-5)
    _, r_a = ops.cutlayer(mu, lv, eps, link_bits=32,
                          rate_estimator="analytic", backend="reference")
    np.testing.assert_allclose(np.asarray(r_a),
                               np.asarray(bottleneck.rate_analytic(mu, lv)),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), T=st.sampled_from([31, 64, 130]),
       bits=st.sampled_from([4, 8, 32]))
def test_vjp_property_random_shapes(seed, T, bits):
    """Property pass: gradients match AD for arbitrary seeds / odd T."""
    mu, lv, eps, cu, cr = _data(T, 16, jnp.float32, seed=seed)
    fused = _scalar(lambda m, l, e: ops.cutlayer(
        m, l, e, link_bits=bits, rate_estimator="sample",
        backend="reference"), cu, cr)
    oracle = _scalar(lambda m, l, e: ref.cutlayer_ref(
        m, l, e, link_bits=bits, rate_estimator="sample"), cu, cr)
    g1 = jax.grad(fused, argnums=(0, 1))(mu, lv, eps)
    g2 = jax.grad(oracle, argnums=(0, 1))(mu, lv, eps)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_linkmodel_transmit_is_the_fused_entry():
    """linkmodel.transmit (the wire-side name) == bottleneck's fused
    sample+quantize+rate entry, key for key."""
    from repro.core import bottleneck, linkmodel
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    mu = jax.random.normal(ks[0], (3, 20, 16))
    lv = jax.random.normal(ks[1], (3, 20, 16)) * 0.3
    u1, r1 = linkmodel.transmit(key, mu, lv, bits=8, backend="reference")
    u2, r2 = bottleneck.fused_sample_rate(key, mu, lv, link_bits=8,
                                          backend="reference")
    np.testing.assert_array_equal(np.asarray(u1), np.asarray(u2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def _prior_data(d, seed=7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    pmu = jax.random.normal(ks[0], (d,)) * 0.5
    plv = jax.random.normal(ks[1], (d,)) * 0.3
    return pmu, plv


def _prior_scalar(fn, cu, cr):
    def f(mu, lv, eps, pmu, plv):
        u, rate = fn(mu, lv, eps, pmu, plv)
        return (u.astype(jnp.float32) * cu).sum() + (rate * cr).sum()
    return f


@pytest.mark.parametrize("rate", ["sample", "analytic"])
@pytest.mark.parametrize("bits", [32, 8])
def test_learned_prior_vjp_matches_ad_reference(bits, rate):
    """Fused learned-prior VJP == AD through the unfused stop-gradient
    reference, to 1e-5 in fp32 — including the prior's own gradients
    (dpmu, dplv), so learned priors train on the fused path with no
    fallback to the 3-pass estimator.  Odd T exercises the row padding."""
    T, d = 257, 16
    mu, lv, eps, cu, cr = _data(T, d, jnp.float32, seed=5)
    pmu, plv = _prior_data(d)
    fused = _prior_scalar(lambda m, l, e, pm, pv: ops.cutlayer(
        m, l, e, link_bits=bits, rate_estimator=rate, prior_mu=pm,
        prior_logvar=pv, backend="reference"), cu, cr)
    oracle = _prior_scalar(lambda m, l, e, pm, pv: ref.cutlayer_prior_ref(
        m, l, e, pm, pv, link_bits=bits, rate_estimator=rate), cu, cr)
    g_fused = jax.grad(fused, argnums=(0, 1, 2, 3, 4))(mu, lv, eps, pmu,
                                                       plv)
    g_ref = jax.grad(oracle, argnums=(0, 1, 2, 3, 4))(mu, lv, eps, pmu,
                                                      plv)
    for name, a, b in zip(("dmu", "dlogvar", "deps", "dprior_mu",
                           "dprior_logvar"), g_fused, g_ref):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5,
            err_msg=f"{name} bits={bits} rate={rate}")


def test_learned_prior_per_node_grid_matches_per_node_calls():
    """(J, B, d) latents with (J, d) per-node priors == independent
    per-node launches — the kernel's (J, row-blocks) prior grid."""
    J, B, d = 3, 40, 24
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    mu = jax.random.normal(ks[0], (J, B, d))
    lv = jax.random.normal(ks[1], (J, B, d)) * 0.3
    eps = jax.random.normal(ks[2], (J, B, d))
    pmu = jax.random.normal(ks[3], (J, d)) * 0.5
    plv = jax.random.normal(ks[4], (J, d)) * 0.3
    u, rate = ops.cutlayer(mu, lv, eps, link_bits=8,
                           rate_estimator="sample", prior_mu=pmu,
                           prior_logvar=plv, backend="reference")
    assert u.shape == (J, B, d) and rate.shape == (J, B)
    for j in range(J):
        uj, rj = ops.cutlayer(mu[j], lv[j], eps[j], link_bits=8,
                              rate_estimator="sample", prior_mu=pmu[j],
                              prior_logvar=plv[j], backend="reference")
        np.testing.assert_allclose(np.asarray(u[j]), np.asarray(uj),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(rate[j]), np.asarray(rj),
                                   atol=1e-4, rtol=1e-5)


def test_standard_normal_prior_params_reduce_to_no_prior_path():
    """Zero prior params == the (faster) no-prior kernel, value and grad."""
    T, d = 130, 16
    mu, lv, eps, cu, cr = _data(T, d, jnp.float32, seed=6)
    z = jnp.zeros((d,))
    with_p = _prior_scalar(lambda m, l, e, pm, pv: ops.cutlayer(
        m, l, e, link_bits=8, rate_estimator="sample", prior_mu=pm,
        prior_logvar=pv, backend="reference"), cu, cr)
    no_p = _scalar(lambda m, l, e: ops.cutlayer(
        m, l, e, link_bits=8, rate_estimator="sample",
        backend="reference"), cu, cr)
    vp, gp = jax.value_and_grad(with_p, argnums=(0, 1, 2))(mu, lv, eps,
                                                           z, z)
    vn, gn = jax.value_and_grad(no_p, argnums=(0, 1, 2))(mu, lv, eps)
    np.testing.assert_allclose(float(vp), float(vn), rtol=1e-6)
    for a, b in zip(gp, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("bits", [32, 8])
def test_deterministic_no_noise_mode_matches_ad(bits):
    """SL's non-stochastic cut: eps == 0 and rate_estimator="none" through
    the fused kernel == quantize(mu) with straight-through AD gradients
    (rate output identically zero)."""
    T, d = 257, 16
    mu, lv, _, cu, cr = _data(T, d, jnp.float32, seed=9)
    zero = jnp.zeros_like(mu)
    u, rate = ops.cutlayer(mu, lv, zero, link_bits=bits,
                           rate_estimator="none", backend="reference")
    np.testing.assert_allclose(np.asarray(u),
                               np.asarray(ref.quantize_value(mu, bits)),
                               atol=1e-6)
    assert float(jnp.abs(rate).max()) == 0.0
    fused = _scalar(lambda m, l, e: ops.cutlayer(
        m, l, e, link_bits=bits, rate_estimator="none",
        backend="reference"), cu, cr)
    oracle = _scalar(lambda m, l, e: ref.cutlayer_ref(
        m, l, e, link_bits=bits, rate_estimator="none"), cu, cr)
    g_fused = jax.grad(fused, argnums=(0, 1, 2))(mu, lv, zero)
    g_ref = jax.grad(oracle, argnums=(0, 1, 2))(mu, lv, zero)
    for name, a, b in zip(("dmu", "dlogvar", "deps"), g_fused, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, err_msg=name)
    # at eps == 0 the error vector passes straight through: dmu == delta
    np.testing.assert_allclose(np.asarray(g_fused[0]), np.asarray(cu),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(g_fused[1]),
                               np.zeros_like(np.asarray(g_fused[1])),
                               atol=1e-6)


@pytest.mark.kernel_interpret
@pytest.mark.parametrize("rate", ["sample", "analytic"])
def test_pallas_prior_vjp_matches_reference_vjp(rate):
    """Interpret-mode Pallas learned-prior kernels == the jnp reference
    under the same custom_vjp wrapper, including the accumulated per-node
    prior gradients (odd rows exercise the padding; J > 1 the prior grid)."""
    J, T, d, bits = 2, 97, 16, 6
    ks = jax.random.split(jax.random.PRNGKey(10), 7)
    mu = jax.random.normal(ks[0], (J, T, d))
    lv = jax.random.normal(ks[1], (J, T, d)) * 0.4
    eps = jax.random.normal(ks[2], (J, T, d))
    cu = jax.random.normal(ks[3], (J, T, d))
    cr = jax.random.normal(ks[4], (J, T))
    pmu = jax.random.normal(ks[5], (J, d)) * 0.5
    plv = jax.random.normal(ks[6], (J, d)) * 0.3
    f_pal = _prior_scalar(lambda m, l, e, pm, pv: cutlayer_fused(
        m, l, e, link_bits=bits, rate_estimator=rate, prior_mu=pm,
        prior_logvar=pv, impl="pallas", block_t=64), cu, cr)
    f_ref = _prior_scalar(lambda m, l, e, pm, pv: cutlayer_fused(
        m, l, e, link_bits=bits, rate_estimator=rate, prior_mu=pm,
        prior_logvar=pv, impl="reference"), cu, cr)
    vp, gp = jax.value_and_grad(f_pal, argnums=(0, 1, 2, 3, 4))(
        mu, lv, eps, pmu, plv)
    vr, gr = jax.value_and_grad(f_ref, argnums=(0, 1, 2, 3, 4))(
        mu, lv, eps, pmu, plv)
    np.testing.assert_allclose(float(vp), float(vr), rtol=1e-5)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("rate", ["sample", "analytic"])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_packed_wire_vjp_matches_ad_reference(bits, rate):
    """The packed wire path (wirefmt.cut_and_ship: pack-emitting fused
    forward -> unpack -> straight-through backward) must yield the SAME
    gradients plain AD produces through the unfused stop-gradient oracle —
    the wire re-encodes the latent, it must not touch eq. (10)."""
    from repro.core import wirefmt
    T, d = 257, 16
    mu, lv, eps, cu, cr = _data(T, d, jnp.float32, seed=11)

    def packed(m, l, e):
        u, rate_v, u_ship = wirefmt.cut_and_ship(
            None, m, l, eps=e, link_bits=bits, rate_estimator=rate,
            wire="packed", backend="reference")
        # the fusion center consumes the SHIPPED buffer
        return (u_ship.astype(jnp.float32) * cu).sum() + (rate_v * cr).sum()

    oracle = _scalar(lambda m, l, e: ref.cutlayer_ref(
        m, l, e, link_bits=bits, rate_estimator=rate), cu, cr)
    g_pk = jax.grad(packed, argnums=(0, 1, 2))(mu, lv, eps)
    g_ref = jax.grad(oracle, argnums=(0, 1, 2))(mu, lv, eps)
    for name, a, b in zip(("dmu", "dlogvar", "deps"), g_pk, g_ref):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5,
            err_msg=f"{name} bits={bits} rate={rate}")


@pytest.mark.kernel_interpret
def test_packed_wire_pallas_vjp_matches_reference():
    """Interpret-mode Pallas pack-emitting forward + fused backward under
    the wire wrapper == the jnp reference wire path."""
    from repro.core import wirefmt
    T, d, bits = 97, 16, 6
    mu, lv, eps, cu, cr = _data(T, d, jnp.float32, seed=12)

    def loss(backend):
        def f(m, l, e):
            u, rate_v, u_ship = wirefmt.cut_and_ship(
                None, m, l, eps=e, link_bits=bits, wire="packed",
                backend=backend, block_t=64)
            return ((u_ship.astype(jnp.float32) * cu).sum()
                    + (rate_v * cr).sum())
        return f
    vp, gp = jax.value_and_grad(loss("pallas"), argnums=(0, 1, 2))(mu, lv,
                                                                   eps)
    vr, gr = jax.value_and_grad(loss("reference"), argnums=(0, 1, 2))(mu, lv,
                                                                      eps)
    np.testing.assert_allclose(float(vp), float(vr), rtol=1e-5)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_cutlayer_dispatch_preserves_bf16(backend):
    """Dtype discipline: bf16 in -> bf16 latent and bf16 gradients out, the
    rate accumulated in fp32 — the dispatch (kernels/ops.cutlayer) enforces
    it, so a kernel regression cannot silently widen the hot path."""
    T, d = 64, 16
    mu, lv, eps, cu, cr = _data(T, d, jnp.bfloat16, seed=13)
    kw = dict(link_bits=8, rate_estimator="sample", backend=backend)
    if backend == "pallas":
        kw["block_t"] = 64
    u, rate = ops.cutlayer(mu, lv, eps, **kw)
    assert u.dtype == jnp.bfloat16
    assert rate.dtype == jnp.float32
    g = jax.grad(_scalar(lambda m, l, e: ops.cutlayer(m, l, e, **kw),
                         jnp.asarray(cu), jnp.asarray(cr)),
                 argnums=(0, 1, 2))(mu, lv, eps)
    assert all(x.dtype == jnp.bfloat16 for x in g)
    # the seed-compatible reparametrised draw keeps the latent dtype too
    from repro.core import bottleneck
    assert bottleneck.sample(jax.random.PRNGKey(0), mu,
                             lv).dtype == jnp.bfloat16


def test_quantized_forward_respects_link_capacity():
    """Fewer link bits -> coarser u (capacity ordering) and u stays in the
    quantizer's clip range."""
    T, d = 128, 32
    mu, lv, eps, _, _ = _data(T, d, jnp.float32, seed=4)
    u32, _ = ops.cutlayer(mu, lv, eps, link_bits=32, backend="reference")
    u8, _ = ops.cutlayer(mu, lv, eps, link_bits=8, backend="reference")
    u4, _ = ops.cutlayer(mu, lv, eps, link_bits=4, backend="reference")
    e8 = float(jnp.mean((u8 - u32) ** 2))
    e4 = float(jnp.mean((u4 - u32) ** 2))
    assert e4 > e8 > 0.0
    assert float(jnp.max(jnp.abs(u4))) <= ref.QUANT_RANGE + 1e-6
