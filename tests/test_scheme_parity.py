"""Scheme-parity harness: every REGISTERED scheme satisfies the unified
Scheme contract on the same fixture —

  * a jitted round on a fixed seed improves the training loss,
  * `predict` returns a probability distribution (rows sum to 1),
  * `bits_per_round` agrees EXACTLY with the closed-form §III-C / Table-I
    accounting in core/bandwidth.py (and, for INL, with the bits the train
    step itself meters),

so a newly registered scheme is covered by tier-1 the moment it registers,
and a refactor of any one scheme cannot silently leave the comparison
running on different substrates.  The deterministic trajectories are shared
with tests/test_scheme_golden.py via tests/_schemes_common.py (compiling
each scheme once per process).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _schemes_common import BATCH, CFG, fixture_data, trajectory

from repro.core import bandwidth, inl, paper_model, schemes

PAPER_SCHEMES = ("inl", "fl", "sl")


def test_registry_exposes_the_papers_three_schemes():
    names = schemes.available()
    assert set(PAPER_SCHEMES) <= set(names)
    assert names[0] == "inl"                    # the paper's ordering
    with pytest.raises(KeyError):
        schemes.get("no-such-scheme")


@pytest.mark.parametrize("name", PAPER_SCHEMES)
def test_round_improves_loss_on_fixed_seed(name):
    losses = trajectory(name)["losses"]
    assert np.mean(losses[-2:]) < losses[0], (name, losses)


@pytest.mark.parametrize("name", PAPER_SCHEMES)
def test_predict_is_a_distribution(name):
    views, labels = fixture_data()
    scheme = schemes.get(name)
    probs = scheme.predict(trajectory(name)["state"], views[:, :BATCH])
    assert probs.shape == (BATCH, CFG.num_classes)
    np.testing.assert_allclose(np.asarray(probs.sum(axis=-1)), 1.0,
                               atol=1e-5)
    assert float(probs.min()) >= 0.0


def test_bits_per_round_match_table1_closed_forms():
    key = jax.random.PRNGKey(0)
    p = CFG.num_clients * CFG.d_bottleneck
    N = paper_model.fl_param_count(CFG)
    J = CFG.num_clients

    s_inl = schemes.get("inl")
    st = trajectory("inl")["state"]
    assert s_inl.bits_per_round(CFG, st, BATCH) == \
        bandwidth.inl_epoch_bits(p, BATCH * J, J, CFG.link_bits)
    assert s_inl.epoch_overhead_bits(CFG, st) == 0.0

    s_fl = schemes.get("fl")
    st = trajectory("fl")["state"]
    assert s_fl.bits_per_round(CFG, st, BATCH) == \
        bandwidth.fl_round_bits(N, J, CFG.link_bits)
    assert s_fl.epoch_overhead_bits(CFG, st) == 0.0

    s_sl = schemes.get("sl")
    st = trajectory("sl")["state"]
    eta = s_sl.param_count(st["client"]) / N
    # per-round traffic + once-per-epoch hand-offs == the published formula
    assert (s_sl.bits_per_round(CFG, st, BATCH)
            + s_sl.epoch_overhead_bits(CFG, st)) == \
        bandwidth.sl_epoch_bits(p, BATCH, N, J, eta, CFG.link_bits)


def test_measured_wire_bytes_match_closed_forms():
    """The MEASURED ledger (actual wire-buffer nbytes, Scheme.
    wire_bytes_per_round via core/wirefmt.py) == the §III-C closed forms
    whenever the wire carries exactly what the formulas charge:

      * dense fp32 links at link_bits=32 (every scheme);
      * packed_duplex links at link_bits=q (INL/SL cut traffic: both
        directions as q-bit codewords — the paper's symmetric 2 b p s);
      * weight transfers always fp32 (FL rounds, SL hand-offs at s=32).
    """
    import dataclasses
    J = CFG.num_clients
    p = CFG.num_clients * CFG.d_bottleneck
    N = paper_model.fl_param_count(CFG)

    # dense @ 32-bit links: measured == accounted for all three schemes
    s_inl = schemes.get("inl")
    st = trajectory("inl")["state"]
    assert s_inl.wire_bytes_per_round(CFG, st, BATCH) * 8 == \
        s_inl.bits_per_round(CFG, st, BATCH)
    s_fl = schemes.get("fl")
    st_fl = trajectory("fl")["state"]
    assert s_fl.wire_bytes_per_round(CFG, st_fl, BATCH) * 8 == \
        bandwidth.fl_round_bits(N, J, 32)
    s_sl = schemes.get("sl")
    st_sl = trajectory("sl")["state"]
    assert s_sl.wire_bytes_per_round(CFG, st_sl, BATCH) * 8 == \
        bandwidth.sl_epoch_bits(p, BATCH, N, J, 0.0, 32)
    eta = s_sl.param_count(st_sl["client"]) / N
    assert s_sl.epoch_overhead_wire_bytes(CFG, st_sl) * 8 == \
        bandwidth.sl_epoch_bits(p, 0, N, J, eta, 32)

    # packed_duplex @ q-bit links: measured == the symmetric Table-I charge
    # whenever the codewords fill the uint32 lanes exactly; a d_bottleneck
    # too narrow for the lane (d*q < 32, e.g. 8 values at 2 bits) pays real
    # lane padding, and the measured ledger must report THAT, not the ideal
    from repro.kernels import ref as kref
    for bits in (2, 4, 8):
        cfg_q = dataclasses.replace(CFG, link_bits=bits)
        measured = s_inl.wire_bytes_per_round(cfg_q, st, BATCH,
                                              wire="packed_duplex") * 8
        lanes = kref.packed_width(CFG.d_bottleneck, bits)
        assert measured == 2 * BATCH * J * lanes * 32          # real lanes
        if (CFG.d_bottleneck * bits) % 32 == 0:                # lanes full
            assert measured == bandwidth.inl_epoch_bits(p, BATCH * J, J,
                                                        bits)
            assert s_sl.wire_bytes_per_round(
                cfg_q, st_sl, BATCH, wire="packed_duplex") * 8 == \
                bandwidth.sl_epoch_bits(p, BATCH, N, J, 0.0, bits)
    # forward-only packing: the client->server half shrinks by 32/q, the
    # dense backward half stays — the measured ledger reports the truth
    cfg8 = dataclasses.replace(CFG, link_bits=8)
    packed = s_inl.wire_bytes_per_round(cfg8, st, BATCH, wire="packed")
    dense = s_inl.wire_bytes_per_round(cfg8, st, BATCH, wire="dense")
    assert packed == dense / 2 * (1 + 8 / 32)


def test_runner_meters_measured_bytes():
    """schemes/runner.run_scheme accrues the measured ledger per round:
    with dense 32-bit links the curve's measured_gbits == its accounted
    gbits exactly (the satellite's 'today accounting is purely analytical'
    gap, closed)."""
    from repro.core.schemes import runner
    views, labels = fixture_data()
    views, labels = np.asarray(views[:, :64]), np.asarray(labels[:64])
    curve = runner.run_scheme("inl", views, labels, CFG, epochs=2,
                              batch_size=16, eval_n=32)
    assert curve[-1].measured_gbits > 0
    assert curve[-1].measured_gbits == curve[-1].gbits
    # a packed_duplex run at 8-bit links matches its (much smaller)
    # accounted charge exactly too
    import dataclasses
    cfg8 = dataclasses.replace(CFG, link_bits=8)
    curve8 = runner.run_scheme("inl", views, labels, cfg8, epochs=2,
                               batch_size=16, eval_n=32,
                               wire="packed_duplex")
    assert curve8[-1].measured_gbits == curve8[-1].gbits
    assert curve8[-1].gbits == curve[-1].gbits / 4     # 8 vs 32-bit links


def test_inl_metered_bits_equal_scheme_accounting():
    """The bits the INL train step itself reports == the registry's
    closed-form accounting (measured and published cannot drift)."""
    views, labels = fixture_data()
    params, state = inl.init(CFG, jax.random.PRNGKey(0))
    _, (m, _) = inl.loss_fn(params, state, views[:, :BATCH], labels[:BATCH],
                            jax.random.PRNGKey(3), CFG)
    scheme = schemes.get("inl")
    st = trajectory("inl")["state"]
    assert float(m["bits_sent"]) == scheme.bits_per_round(CFG, st, BATCH)


def test_learned_prior_scheme_state_trains():
    """cfg.learned_prior routes the INL scheme through the fused kernel's
    prior path end to end (no unfused fallback): prior params exist, get
    gradients, and the rounds still improve the loss."""
    rec = trajectory("inl", learned_prior=True)
    losses = rec["losses"]
    assert np.mean(losses[-2:]) < losses[0], losses
    priors = rec["state"]["params"].priors
    assert priors["mu"].shape == (CFG.num_clients, CFG.d_bottleneck)
    assert np.abs(np.asarray(priors["logvar"])).max() > 0.0
